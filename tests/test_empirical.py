"""Quantile-table empirical distributions + the per-cell "system"
(dist_id) coordinate.

Covers the fit contract (unit mean, closed-form variance, round-trip of
moments and tail through the table), the mixture variance pin, and the
heterogeneous mixed-grid engine path: every variant column of a mixed
SYSTEMS grid must be bit-identical to the same scenario run pure —
shared arrivals (CRN across systems), per-cell service-table routing
only."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributions as dists, queueing, scenario
from repro.core.scenario import Scenario

CFG = queueing.SimConfig(n_servers=5, n_arrivals=3_000)


def _pareto_fit(n_samples=400_000, alpha=2.1):
    key = jax.random.PRNGKey(0)
    samples = dists.pareto(alpha).sample(key, (n_samples,)) * 3.7  # ms-ish
    return samples, dists.empirical(samples, name="pareto_fit")


class TestEmpiricalFit:
    def test_roundtrip_mean_and_p99(self):
        samples, d = _pareto_fit()
        # the trapezoid mean of the table IS the sample mean -> scale
        assert d.scale == pytest.approx(float(jnp.mean(samples)), rel=1e-3)
        # resampling from the table reproduces mean and p99 of the data
        re = d.sample(jax.random.PRNGKey(1), (400_000,)) * d.scale
        assert float(jnp.mean(re)) == pytest.approx(
            float(jnp.mean(samples)), rel=0.02)
        assert float(jnp.percentile(re, 99)) == pytest.approx(
            float(jnp.percentile(samples, 99)), rel=0.05)

    def test_unit_mean_contract(self):
        _, d = _pareto_fit(n_samples=100_000)
        s = d.sample(jax.random.PRNGKey(2), (400_000,))
        assert float(jnp.mean(s)) == pytest.approx(1.0, rel=0.01)
        assert d.mean == 1.0

    def test_closed_form_variance_matches_sampled(self):
        _, d = _pareto_fit(n_samples=100_000)
        s = d.sample(jax.random.PRNGKey(3), (400_000,))
        assert d.variance == pytest.approx(float(jnp.var(s)), rel=0.05)

    def test_exceedance_matches_data_tail(self):
        samples, d = _pareto_fit()
        for x in (5.0, 10.0, 20.0):
            assert d.exceedance(x) == pytest.approx(
                float(jnp.mean(samples > x)), abs=0.005)
        assert d.exceedance(0.0) == 1.0
        assert d.exceedance(1e9) == 0.0

    def test_table_shape_and_monotone(self):
        _, d = _pareto_fit(n_samples=50_000)
        assert len(d.table) == 513  # n_quantiles + 1 knots
        t = np.asarray(d.table)
        assert np.all(np.diff(t) >= 0.0)
        assert t[0] >= 0.0

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError):
            dists.empirical([1.0])  # need >= 2 samples
        with pytest.raises(ValueError):
            dists.empirical([1.0, -2.0])  # negative
        with pytest.raises(ValueError):
            dists.empirical([1.0, jnp.inf])
        with pytest.raises(ValueError):
            dists.empirical([0.0, 0.0])  # zero mean

    def test_mixture_variance_pinned(self):
        # mixture() used to drop the component variances entirely
        m = dists.mixture([dists.exponential(), dists.deterministic()],
                          [0.5, 0.5])
        # E[X^2] = 0.5 * (1 + 1) + 0.5 * (0 + 1) = 1.5, mean 1 => var 0.5
        assert m.variance == pytest.approx(0.5)
        s = m.sample(jax.random.PRNGKey(4), (400_000,))
        assert float(jnp.var(s)) == pytest.approx(0.5, rel=0.05)


class TestFromTrace:
    def test_trace_fit_matches_empirical(self, tmp_path):
        samples, _ = _pareto_fit(n_samples=5_000)
        vals = np.asarray(samples, np.float64)
        p = tmp_path / "latency.trace"
        p.write_text("# latency samples, ms\n\n"
                     + "\n".join(f"{v:.9g}" for v in vals) + "\n")
        d = dists.EmpiricalDist.from_trace(p)
        ref = dists.empirical(np.asarray([float(f"{v:.9g}") for v in vals]))
        assert d.scale == pytest.approx(ref.scale, rel=1e-9)
        assert d.table == ref.table
        assert d.name == "trace:latency.trace[q512]"

    def test_trace_dist_rides_the_engine(self, tmp_path):
        p = tmp_path / "t.txt"
        rng = np.random.default_rng(0)
        p.write_text("\n".join(str(v) for v in rng.exponential(3.0, 500)))
        d = dists.EmpiricalDist.from_trace(p, n_quantiles=64)
        out = queueing.run(jax.random.PRNGKey(0),
                           Scenario(dists=d, ks=(1, 2)),
                           jnp.asarray([0.3]), CFG, n_seeds=1)
        assert bool(jnp.all(jnp.isfinite(out["mean"])))

    def test_trace_rejects_too_few(self, tmp_path):
        p = tmp_path / "bad.txt"
        p.write_text("# only comments\n1.5\n")
        with pytest.raises(ValueError, match="usable"):
            dists.EmpiricalDist.from_trace(p)

    def test_netsim_fct_quantile_table(self):
        from repro.core import netsim

        cfg = netsim.NetConfig(n_flows=120, load=0.25, replicate_first=0,
                               seed=3)
        d = netsim.empirical_fct_dist(cfg, n_quantiles=64)
        assert isinstance(d, dists.EmpiricalDist)
        assert d.mean == 1.0 and d.scale > 0.0
        # table tails agree with the raw short-flow FCTs it was fit from
        fct, _, short, _ = netsim.flow_completion_times(cfg)
        raw = fct[short]
        assert d.scale == pytest.approx(float(raw.mean()), rel=0.02)
        x = float(np.percentile(raw, 90))
        assert d.exceedance(x) == pytest.approx(
            float((raw > x).mean()), abs=0.05)


class TestSystemCoordinate:
    def test_combine_dedupes_union_and_assigns_dist_ids(self):
        a, b = dists.exponential(), dists.pareto(2.5)
        union, _, variants = scenario.combine(
            (Scenario(dists=a, ks=(1, 2)), Scenario(dists=b, ks=(1,)),
             Scenario(dists=a, ks=(2,))))
        assert union == (a, b)
        assert [v.dist_id for v in variants] == [0, 0, 1, 0]
        assert scenario.variant_dist_ids(variants) == [0, 0, 1, 0]
        assert scenario.any_dist_ids(variants)

    def test_homogeneous_grid_has_no_dist_ids(self):
        a = dists.exponential()
        _, _, variants = scenario.combine(
            (Scenario(dists=a, ks=(1,)), Scenario(dists=a, ks=(2,))))
        assert not scenario.any_dist_ids(variants)

    def test_heterogeneous_rejects_multidist_scenario(self):
        with pytest.raises(ValueError):
            scenario.combine(
                (Scenario(dists=(dists.exponential(), dists.pareto(2.5))),
                 Scenario(dists=dists.deterministic())))

    def test_mixed_grid_columns_bit_match_pure_runs(self):
        """THE heterogeneous engine contract: a mixed SYSTEMS grid keeps
        each scenario's cells on the same arrival stream (CRN across
        systems) and routes ONLY the service gather, so every variant
        column is bit-identical to the scenario run pure."""
        _, emp = _pareto_fit(n_samples=50_000)
        scn_a = Scenario(dists=dists.exponential(), ks=(1, 2))
        scn_b = Scenario(dists=emp, ks=(1, 2), client_overhead=0.05)
        key = jax.random.PRNGKey(5)
        rhos = jnp.asarray([0.2, 0.4])
        mixed = queueing.run(key, (scn_a, scn_b), rhos, CFG, n_seeds=2)
        pure_a = queueing.run(key, scn_a, rhos, CFG, n_seeds=2)
        pure_b = queueing.run(key, scn_b, rhos, CFG, n_seeds=2)
        for f in ("mean", "p50", "p99", "completed"):
            assert jnp.array_equal(mixed[f][:, :, :2], pure_a[f]), f
            assert jnp.array_equal(mixed[f][:, :, 2:], pure_b[f]), f

    def test_mixed_grid_scan_kernel_bit_identical(self):
        _, emp = _pareto_fit(n_samples=50_000)
        scns = (Scenario(dists=dists.exponential(), ks=(1, 2)),
                Scenario(dists=emp, ks=(1, 2)))
        key = jax.random.PRNGKey(6)
        rhos = jnp.asarray([0.3])
        off = queueing.run(key, scns, rhos, CFG, n_seeds=1, kernel="off")
        interp = queueing.run(key, scns, rhos, CFG, n_seeds=1,
                              kernel="interpret")
        for f in ("mean", "p50", "p99", "completed"):
            assert jnp.array_equal(off[f], interp[f]), f

    def test_empirical_rides_chunked_engine(self):
        # chunked streaming re-samples per chunk from the SAME table
        _, emp = _pareto_fit(n_samples=50_000)
        scns = (Scenario(dists=dists.exponential(), ks=(1,)),
                Scenario(dists=emp, ks=(1,)))
        key = jax.random.PRNGKey(7)
        out = queueing.run(key, scns, jnp.asarray([0.3]), CFG, n_seeds=1,
                           chunk_size=1024)
        assert bool(jnp.all(jnp.isfinite(out["mean"])))
        # unit-mean service at rho=0.3: response means sit above the
        # service mean for both systems (heavy-tailed queueing can push
        # the empirical column well past it — no upper sanity bound)
        assert bool(jnp.all(out["mean"] > 0.5))
