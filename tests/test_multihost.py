"""Multi-host sweep execution: mesh rules, per-host sampling, pipeline.

In-process tests pin the pieces the multi-host executor is assembled
from, each against the engine's bit-identity contract:

  * ``make_sweep_mesh`` divisibility validation (a clear ValueError
    instead of an opaque reshape error),
  * ``cellplan.device_row_maps``'s remap invariant
    ``x[rows[d]][local[c]] == x[idx[c]]``,
  * row-reduced sampling (``ChunkSampler.rows``) bit-identical to the
    full block for every sampler kind, and the fused jitted sampler
    bit-identical to the eager one,
  * the sampling/compute pipeline (``pipeline="on"``) bit-identical to
    the serial loop, on and off a mesh,
  * ambient mesh resolution (``use_sweep_mesh`` / ``resolve_mesh``).

The subprocess test is the tentpole's acceptance check: it launches a
REAL 2-process jax.distributed runtime (gloo collectives, 4 virtual CPU
devices per process — the ``test_sweep_shard`` idiom, XLA flags never
leaking into this process) against a single-process 8-device reference,
for both a divisible and a padded cell grid, and asserts summaries are
bit-for-bit equal while each host sampled only HALF the seed rows
(``chunkflow`` stats).
"""
import socket
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cellplan, chunkflow, distributions as dists, queueing
from repro.core.scenario import Scenario
from repro.launch import mesh as launch_mesh
from repro.launch.mesh import make_sweep_mesh, use_sweep_mesh

SRC = str(Path(__file__).resolve().parent.parent / "src")

CFG = queueing.SimConfig(n_servers=10, n_arrivals=6_000)
RHOS = jnp.asarray([0.1, 0.3])


class TestMakeSweepMeshValidation:
    def test_all_devices_default(self):
        mesh = make_sweep_mesh()
        assert mesh.axis_names == ("cells",)
        assert mesh.devices.size == jax.device_count()

    def test_rejects_non_divisor(self):
        # 3 devices requested of 1 visible: not a divisor
        with pytest.raises(ValueError, match="divide"):
            make_sweep_mesh(3)

    def test_rejects_non_positive(self):
        with pytest.raises(ValueError, match="divide"):
            make_sweep_mesh(0)

    def test_explicit_devices(self):
        devs = jax.devices()
        assert make_sweep_mesh(1, devices=devs).devices.size == 1
        with pytest.raises(ValueError):
            make_sweep_mesh(2, devices=devs[:1])


class TestDeviceRowMaps:
    def test_remap_invariant(self):
        idx = np.asarray([0, 0, 1, 1, 2, 2, 0, 2], np.int32)
        rows, local = cellplan.device_row_maps(idx, 4)
        assert rows.shape[0] == 4
        x = np.arange(3) * 10.0 + 7.0  # any global input block
        per = idx.size // 4
        for c in range(idx.size):
            d = c // per
            assert x[rows[d]][local[c]] == x[idx[c]], c

    def test_rows_sorted_unique_padded_to_common_width(self):
        idx = np.asarray([0, 2, 1, 1], np.int32)
        rows, local = cellplan.device_row_maps(idx, 2)
        assert rows.shape == (2, 2)
        np.testing.assert_array_equal(rows[0], [0, 2])
        np.testing.assert_array_equal(rows[1], [1, 1])  # edge-padded

    def test_rejects_non_divisible(self):
        with pytest.raises(ValueError, match="tile"):
            cellplan.device_row_maps(np.zeros(6, np.int32), 4)


class TestRowReducedSampling:
    """ChunkSampler.rows must return the exact bits of the corresponding
    full-block rows — per-seed determinism is what makes the per-host
    sampling reduction legal."""

    def _check(self, sampler, seed_rows, svc_rows, t=500):
        g, sv, svc = sampler(1, t)
        rg, rsv, rsvc = sampler.rows(1, t, seed_rows, svc_rows)
        sr, vr = jnp.asarray(seed_rows), jnp.asarray(svc_rows)
        assert jnp.array_equal(jnp.asarray(g)[sr], rg)
        assert jnp.array_equal(jnp.asarray(sv)[sr], rsv)
        assert jnp.array_equal(jnp.asarray(svc)[vr], rsvc)

    def test_single_kind(self):
        s = queueing._sweep_sampler(jax.random.PRNGKey(0),
                                    dists.exponential(), CFG, 2, 4, 500)
        self._check(s, (1, 3), (1, 3))
        self._check(s, (0, 1, 2, 3), (0, 1, 2, 3))  # full set == block

    def test_stacked_kind_tiled_rows(self):
        ds = (dists.exponential(), dists.pareto(2.5))
        s = queueing._sweep_dists_sampler(jax.random.PRNGKey(1), ds, CFG,
                                          2, 3, 500)
        # row r of the tiled seed space repeats seed r % n_seeds
        self._check(s, (0, 4), (0, 4))
        self._check(s, (2, 3, 5), (1, 2, 5))

    def test_tables_kind(self):
        ds = (dists.exponential(), dists.two_point(0.9))
        s = queueing._dist_table_sampler(jax.random.PRNGKey(2), ds, CFG,
                                         2, 3, 500)
        # seed space has 3 rows; svc space stacks 2 tables -> 6 rows
        self._check(s, (0, 2), (0, 2, 3, 5))

    def test_fused_equals_eager(self):
        s = queueing._sweep_sampler(jax.random.PRNGKey(3),
                                    dists.weibull(0.7), CFG, 2, 3, 500,
                                    with_shared=True, with_degr=True)
        for a, b in zip(s(2, 500), s.fused(2, 500)):
            assert jnp.array_equal(jnp.asarray(a), b)


class TestPipeline:
    def test_on_off_bit_identical(self):
        key = jax.random.PRNGKey(4)
        scn = Scenario.paper_default(dists.exponential(), ks=(1, 2))
        kw = dict(n_seeds=2, chunk_size=1_700)  # ragged final chunk
        off = queueing.run(key, scn, RHOS, CFG, pipeline="off", **kw)
        on = queueing.run(key, scn, RHOS, CFG, pipeline="on", **kw)
        auto = queueing.run(key, scn, RHOS, CFG, **kw)  # -> "on"
        for f in ("mean", "p50", "p99"):
            assert jnp.array_equal(off[f], on[f]), f
            assert jnp.array_equal(off[f], auto[f]), f
        st = chunkflow.last_stats()
        assert st is not None and st.enabled and st.n_chunks == 4
        # single process: the full block is this host's sampling set
        assert st.seed_rows_sampled == st.seed_rows_total == 2
        assert st.locality_factor == 1.0

    def test_on_off_bit_identical_sharded(self):
        key = jax.random.PRNGKey(5)
        scn = Scenario.paper_default(dists.pareto(2.5), ks=(1, 2))
        kw = dict(n_seeds=2, chunk_size=2_500, mesh=make_sweep_mesh(1))
        off = queueing.run(key, scn, RHOS, CFG, pipeline="off", **kw)
        on = queueing.run(key, scn, RHOS, CFG, pipeline="on", **kw)
        for f in ("mean", "p50", "p99"):
            assert jnp.array_equal(off[f], on[f]), f

    def test_auto_is_off_when_nothing_to_overlap(self):
        key = jax.random.PRNGKey(6)
        scn = Scenario.paper_default(dists.exponential(), ks=(1,))
        queueing.run(key, scn, RHOS, CFG, n_seeds=1)  # unchunked
        assert not chunkflow.last_stats().enabled

    def test_rejects_bad_mode(self):
        with pytest.raises(ValueError, match="pipeline"):
            queueing.run(jax.random.PRNGKey(0),
                         Scenario.paper_default(dists.exponential()),
                         RHOS, CFG, pipeline="maybe")

    def test_producer_error_surfaces(self):
        hits = []

        def produce(c):
            if c == 2:
                raise RuntimeError("boom")
            hits.append(c)
            return c

        with pytest.raises(RuntimeError, match="boom"):
            list(chunkflow.iter_staged(produce, 5))
        assert hits == [0, 1]

    def test_iter_staged_order_and_disabled(self):
        assert list(chunkflow.iter_staged(lambda c: c * c, 7)) == \
            [c * c for c in range(7)]
        assert list(chunkflow.iter_staged(lambda c: c, 4,
                                          enabled=False)) == [0, 1, 2, 3]


class TestAmbientMesh:
    def test_use_sweep_mesh_routes_and_restores(self):
        key = jax.random.PRNGKey(7)
        scn = Scenario.paper_default(dists.exponential(), ks=(1, 2))
        kw = dict(n_seeds=2, chunk_size=1_700)
        un = queueing.run(key, scn, RHOS, CFG, **kw)
        mesh = make_sweep_mesh(1)
        with use_sweep_mesh(mesh):
            assert launch_mesh.resolve_mesh() is mesh
            amb = queueing.run(key, scn, RHOS, CFG, **kw)
        assert launch_mesh.resolve_mesh() is None
        exp = queueing.run(key, scn, RHOS, CFG, mesh=mesh, **kw)
        for f in ("mean", "p50", "p99"):
            assert jnp.array_equal(un[f], amb[f]), f
            assert jnp.array_equal(un[f], exp[f]), f

    def test_explicit_beats_ambient(self):
        with use_sweep_mesh(make_sweep_mesh(1)):
            m = make_sweep_mesh(1)
            assert launch_mesh.resolve_mesh(m) is m

    def test_default_mesh_resolution(self):
        mesh = make_sweep_mesh(1)
        launch_mesh.set_default_sweep_mesh(mesh)
        try:
            assert launch_mesh.resolve_mesh() is mesh
        finally:
            launch_mesh.set_default_sweep_mesh(None)
        assert launch_mesh.resolve_mesh() is None

    def test_sharded_requires_chunk_sampler(self):
        from repro.distributed import sweep_shard

        with pytest.raises(TypeError, match="ChunkSampler"):
            sweep_shard._sweep_cells_sharded(
                lambda c, t: None, 1, RHOS, CFG,
                variants=Scenario.paper_default(dists.exponential(),
                                                ks=(1,)).variants,
                warmup_frac=0.1, percentiles=(), n_bins=64,
                chunk_size=1_000, mesh=make_sweep_mesh(1))


# --- the 2-process x 4-device acceptance test ---------------------------

# Reference leg: ONE process, 8 virtual devices. Computes both grids
# unsharded, and anchors the divisible grid to the 8-device sharded
# executor (they must agree bit-for-bit) before saving the summaries
# for the workers to diff against. The PADDED grid's single-process
# 8-device equality is pinned by test_sweep_shard's own subprocess
# test — repeating it here would just pay a second 8-way shard_map
# compile (the dominant cost of this script) for an already-pinned
# fact.
REF_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributions as dists, queueing
from repro.launch.mesh import make_sweep_mesh

assert jax.device_count() == 8
mesh = make_sweep_mesh(8)
cfg = queueing.SimConfig(n_servers=10, n_arrivals=5_000)
key = jax.random.PRNGKey(0)
rhos = jnp.asarray([0.15, 0.35])
scn = queueing.Scenario.paper_default(dists.exponential(), ks=(1, 2))

out = {}
for tag, n_seeds, chunk in (("div", 4, 2_000), ("pad", 3, 1_700)):
    un = queueing.run(key, scn, rhos, cfg, n_seeds=n_seeds,
                      chunk_size=chunk)
    for f in ("mean", "p50", "p99"):
        out[f"{tag}_{f}"] = np.asarray(un[f])
sh = queueing.run(key, scn, rhos, cfg, n_seeds=4, chunk_size=2_000,
                  mesh=mesh)
for f in ("mean", "p50", "p99"):
    assert jnp.array_equal(jnp.asarray(out[f"div_{f}"]), sh[f]), f
np.savez(sys.argv[1], **out)
print("REF_OK")
"""

# Worker leg: one of TWO processes, 4 virtual devices each, joined via
# multihost.initialize (which installs the ambient 8-device mesh — the
# runs below pass NO mesh argument). Asserts bit-equality against the
# reference and the per-host sampling reduction (2 of 4 seed rows).
WORKER_SCRIPT = r"""
import sys
port, pid, npz = sys.argv[1], int(sys.argv[2]), sys.argv[3]

from repro.distributed import multihost
joined = multihost.initialize(f"127.0.0.1:{port}", 2, pid,
                              local_device_count=4)
assert joined

import jax
import jax.numpy as jnp
import numpy as np

assert jax.process_count() == 2 and jax.process_index() == pid
assert jax.local_device_count() == 4 and jax.device_count() == 8

from repro.core import chunkflow, distributions as dists, queueing

cfg = queueing.SimConfig(n_servers=10, n_arrivals=5_000)
key = jax.random.PRNGKey(0)
rhos = jnp.asarray([0.15, 0.35])
scn = queueing.Scenario.paper_default(dists.exponential(), ks=(1, 2))
ref = np.load(npz)

for tag, n_seeds, chunk in (("div", 4, 2_000), ("pad", 3, 1_700)):
    out = queueing.run(key, scn, rhos, cfg, n_seeds=n_seeds,
                       chunk_size=chunk)  # ambient multi-process mesh
    for f in ("mean", "p50", "p99"):
        assert np.array_equal(np.asarray(out[f]), ref[f"{tag}_{f}"]), \
            (tag, f)
    st = chunkflow.last_stats()
    assert st.process_count == 2 and st.process_index == pid
    assert st.enabled  # chunked stream -> pipeline auto-on
    if tag == "div":
        # 16 cells, 2 per device: each host's 8 cells span HALF the
        # seed rows -> per-host sampling reduction = 2x in bytes
        assert st.seed_rows_sampled == 2 and st.seed_rows_total == 4
        assert st.locality_factor == 2.0
    else:
        # 12 cells padded to 16: host 0 owns seeds {0, 1}; host 1 owns
        # {2} plus {0} via the pad cells (pad aliases cell 0's seed) —
        # each host still samples 2 of 3 seed rows
        assert st.seed_rows_sampled == 2 and st.seed_rows_total == 3
    print(tag, "bit-identical; host sampled",
          st.seed_rows_sampled, "of", st.seed_rows_total, "seed rows",
          flush=True)
print("MULTIHOST_OK", pid, flush=True)

# Every assertion above passed. Tear down the distributed runtime
# explicitly, then skip interpreter teardown: the coordination
# service's atexit shutdown can race its peer and SIGABRT, which would
# turn a fully passing worker into a bogus failure (and eat its
# buffered stdout).
import os
try:
    jax.distributed.shutdown()
except Exception:
    pass
os._exit(0)
"""


@pytest.mark.slow
def test_two_process_bit_identical_to_single_process(tmp_path):
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"}
    npz = str(tmp_path / "ref.npz")
    ref = subprocess.run([sys.executable, "-c", REF_SCRIPT, npz],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert ref.returncode == 0, (ref.stdout[-1500:], ref.stderr[-2500:])
    assert "REF_OK" in ref.stdout

    with socket.socket() as s:  # free port for the coordinator
        s.bind(("127.0.0.1", 0))
        port = str(s.getsockname()[1])
    workers = [subprocess.Popen(
        [sys.executable, "-c", WORKER_SCRIPT, port, str(pid), npz],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True) for pid in (0, 1)]
    outs = [w.communicate(timeout=900) for w in workers]
    for pid, (w, (so, se)) in enumerate(zip(workers, outs)):
        assert w.returncode == 0, (pid, so[-1500:], se[-2500:])
        assert f"MULTIHOST_OK {pid}" in so
