def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: subprocess-spawning integration tests (multi-device / "
        "multi-process bit-identity); deselect with -m 'not slow'")
