"""Fused Pallas cell-update kernel: bit-identity against the scan body.

The contract (``repro.kernels.cell_update``): for the same inputs the
kernel path (``kernel="on"`` / ``"interpret"`` — on CPU both run the
Pallas interpreter, same jnp ops) and the ``lax.scan`` reference
(``kernel="off"``) agree BIT FOR BIT — every policy x service-model
code, mixed grids, pad cells, chunked and unchunked layouts, histogram
on and off. On CPU the kernel runs in interpret mode, which is exactly
why these tests can pin the contract in every tier-1 run; the sharded
job in ``test_sweep_shard.py`` pins it under ``shard_map`` at 8
devices.
"""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import cellplan, distributions as dists, queueing, threshold
from repro.core.scenario import (CANCEL_ON_COMPLETE, IID, REPLICATE_ALL,
                                 REPLICATE_TO_IDLE, SERVER_DEPENDENT,
                                 Scenario, combine, variant_codes)
from repro.kernels.cell_update import ops as cell_ops

CFG = queueing.SimConfig(n_servers=10, n_arrivals=3_000)
RHOS = jnp.asarray([0.1, 0.35])


def _assert_bits(a, b, fields=("mean", "p50", "p99")):
    assert a["count"] == b["count"]
    for f in fields:
        assert jnp.array_equal(a[f], b[f]), f


def _both(key, scn, rhos, cfg, **kw):
    off = queueing.run(key, scn, rhos, cfg, kernel="off", **kw)
    on = queueing.run(key, scn, rhos, cfg, kernel="on", **kw)
    return off, on


class TestKernelModeResolution:
    def test_auto_off_tpu_is_off(self):
        # this suite runs on CPU: auto must stay on the scan body
        assert cell_ops.resolve_kernel_mode("auto") in ("off", "on")
        if jax.devices()[0].platform != "tpu":
            assert cell_ops.resolve_kernel_mode("auto") == "off"
            assert cell_ops.resolve_kernel_mode("on") == "interpret"
        assert cell_ops.resolve_kernel_mode("off") == "off"
        assert cell_ops.resolve_kernel_mode("interpret") == "interpret"
        assert cell_ops.resolve_kernel_mode(None) == "off"
        assert cell_ops.resolve_kernel_mode(False) == "off"

    def test_rejects_unknown_mode(self):
        with pytest.raises(ValueError, match="kernel"):
            cell_ops.resolve_kernel_mode("sometimes")
        with pytest.raises(ValueError, match="kernel"):
            queueing.run(jax.random.PRNGKey(0),
                         Scenario.paper_default(dists.exponential()), RHOS,
                         CFG, kernel="sometimes")


class TestKernelParity:
    @pytest.mark.parametrize("policy", [REPLICATE_ALL, CANCEL_ON_COMPLETE,
                                        REPLICATE_TO_IDLE])
    @pytest.mark.parametrize("model", [IID, SERVER_DEPENDENT])
    def test_every_policy_model_code(self, policy, model):
        key = jax.random.PRNGKey(0)
        scn = Scenario(dists=dists.exponential(), policy=policy,
                       service_model=model,
                       mix=0.6 if model is SERVER_DEPENDENT else 0.0,
                       ks=(1, 2))
        off, on = _both(key, scn, RHOS, CFG, n_seeds=1, chunk_size=1_300)
        _assert_bits(off, on)

    def test_mixed_grid_chunked_ragged(self):
        # all policies and both models in ONE plan, ragged chunks
        key = jax.random.PRNGKey(1)
        d = dists.exponential()
        scns = (Scenario.paper_default(d, ks=(1, 2)),
                Scenario(dists=d, policy=CANCEL_ON_COMPLETE, ks=(2,)),
                Scenario(dists=d, policy=REPLICATE_TO_IDLE, ks=(2,)),
                Scenario(dists=d, service_model=SERVER_DEPENDENT, mix=0.7,
                         ks=(2,)))
        off, on = _both(key, scns, RHOS, CFG, n_seeds=2, chunk_size=1_300)
        _assert_bits(off, on)

    def test_unchunked_with_overhead(self):
        key = jax.random.PRNGKey(2)
        cfg = queueing.SimConfig(n_servers=7, n_arrivals=2_500,
                                 client_overhead=0.2)
        scn = Scenario.paper_default(dists.pareto(2.5), ks=(1, 3),
                                     client_overhead=0.2)
        off, on = _both(key, scn, RHOS, cfg, n_seeds=2)
        _assert_bits(off, on)

    def test_hist_off_kernel_padding_is_bit_noop(self):
        # percentiles=(): the scan body runs UNPADDED, the kernel pads
        # the chunk to a block multiple — identical mean bits proves
        # zero-weight padding steps are bitwise no-ops on the Kahan state
        key = jax.random.PRNGKey(3)
        scn = Scenario.paper_default(dists.exponential(), ks=(1, 2))
        off, on = _both(key, scn, RHOS, CFG, n_seeds=1, percentiles=(),
                        chunk_size=900)
        _assert_bits(off, on, fields=("mean",))

    def test_interpret_equals_on(self):
        key = jax.random.PRNGKey(4)
        scn = Scenario.paper_default(dists.weibull(0.7), ks=(1, 2))
        on = queueing.run(key, scn, RHOS, CFG, n_seeds=1, kernel="on")
        interp = queueing.run(key, scn, RHOS, CFG, n_seeds=1,
                              kernel="interpret")
        _assert_bits(on, interp)

    def test_threshold_bisect_kernel_identical(self):
        key = jax.random.PRNGKey(5)
        kw = dict(iters=3, n_seeds=1, chunk_size=1_500)
        t_off = threshold.threshold_bisect(key, dists.exponential(), CFG,
                                           kernel="off", **kw)
        t_on = threshold.threshold_bisect(key, dists.exponential(), CFG,
                                          kernel="on", **kw)
        assert t_off == t_on


class TestPadCellIsolation:
    def test_padded_plan_full_carry_bit_identity(self):
        # drive the chunk body directly on a plan with pad cells
        # (n_cells=6 padded to 8): EVERY carry component — free grid,
        # Kahan state, histogram rows, pad rows included — must match
        key = jax.random.PRNGKey(6)
        cfg = queueing.SimConfig(n_servers=7, n_arrivals=2_500)
        rhos = jnp.asarray([0.1, 0.25, 0.4])
        d = dists.pareto(2.5)
        _, _, variants = combine(Scenario.paper_default(d, ks=(1, 2)))
        pol, mdl = variant_codes(variants)
        plan = cellplan.make_cell_plan(1, 3, 2, pad_to=4, policies=pol,
                                       models=mdl)
        assert plan.n_padded > plan.n_cells
        (rates_c, k_mask_c, ovh_c, mix_c, pslow_c, sfac_c, pfail_c,
         delay_c) = queueing._plan_cell_params(plan, rhos, cfg, variants)
        free, ssum, comp, cnt, hist = queueing._init_cell_state(
            plan, cfg, queueing.DEFAULT_BINS, True)
        sampler = queueing._sweep_sampler(key, d, cfg, 2, 1, None)
        pad = (-cfg.n_arrivals) % 512
        inputs = queueing._pad_chunk_inputs(*sampler(0, cfg.n_arrivals),
                                            pad)
        args = (free, ssum, comp, cnt, hist, *inputs, jnp.asarray(0),
                jnp.asarray(cfg.n_arrivals), jnp.asarray(250),
                plan.seed_idx, rates_c, k_mask_c, ovh_c,
                plan.policy_code, plan.model_code, mix_c, pslow_c,
                sfac_c, pfail_c, delay_c)
        kw = dict(n_servers=cfg.n_servers, n_bins=queueing.DEFAULT_BINS,
                  block=512)
        out_off = queueing._sweep_chunk_cells(*args, use_kernel="off",
                                              **kw)
        out_on = queueing._sweep_chunk_cells(*args,
                                             use_kernel="interpret", **kw)
        for name, a, b in zip(("free", "ssum", "comp", "cnt", "hist"),
                              out_off, out_on):
            assert jnp.array_equal(a, b), name


class TestDeprecatedShims:
    """The legacy paper-default shims must warn AND stay bit-identical
    to ``run`` through the kernel path."""

    def test_sweep_warns_and_matches_run(self):
        key = jax.random.PRNGKey(7)
        with pytest.warns(DeprecationWarning, match="queueing.sweep"):
            shim = queueing.sweep(key, dists.exponential(), RHOS, CFG,
                                  ks=(1, 2), n_seeds=1, kernel="on")
        scn = Scenario.paper_default(dists.exponential(), ks=(1, 2))
        direct = queueing.run(key, scn, RHOS, CFG, n_seeds=1, kernel="on")
        _assert_bits(shim, direct)
        # and the kernel path equals the scan path through the shim too
        with pytest.warns(DeprecationWarning):
            off = queueing.sweep(key, dists.exponential(), RHOS, CFG,
                                 ks=(1, 2), n_seeds=1, kernel="off")
        _assert_bits(shim, off)

    def test_sweep_dists_warns_and_matches_run(self):
        key = jax.random.PRNGKey(8)
        ds = (dists.exponential(), dists.two_point(0.9))
        with pytest.warns(DeprecationWarning, match="sweep_dists"):
            shim = queueing.sweep_dists(key, ds, RHOS, CFG, ks=(1, 2),
                                        n_seeds=1, percentiles=(),
                                        kernel="on")
        scn = Scenario.paper_default(ds, ks=(1, 2))
        direct = queueing.run(key, scn, RHOS, CFG, n_seeds=1,
                              percentiles=(), kernel="on")
        assert jnp.array_equal(shim["mean"], direct["mean"])

    def test_replication_gain_warns_and_matches_scan(self):
        key = jax.random.PRNGKey(9)
        with pytest.warns(DeprecationWarning, match="replication_gain"):
            g_on = queueing.replication_gain(key, dists.exponential(),
                                             RHOS, CFG, n_seeds=1,
                                             kernel="on")
        with pytest.warns(DeprecationWarning):
            g_off = queueing.replication_gain(key, dists.exponential(),
                                              RHOS, CFG, n_seeds=1,
                                              kernel="off")
        assert jnp.array_equal(g_on, g_off)

    def test_mean_response_does_not_warn(self):
        # not a deprecated shim: must stay warning-free
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            queueing.mean_response(jax.random.PRNGKey(10),
                                   dists.exponential(), RHOS, CFG, k=1)
