"""Failure/straggler model + timed policies: the PR-7 engine contracts.

* Healthy-cell bit-identity: every pre-existing policy x service-model
  combination reproduces the pre-PR-7 golden capture
  (``tests/golden/pre_pr7.npz``) BIT FOR BIT across chunked/unchunked
  and scan/interpret paths — the degradation model and the timed-policy
  block cost healthy grids nothing, not even a ULP.
* CRN isolation: appending a degraded variant to a grid leaves the
  healthy cells' bits untouched (fault draws come from a dedicated
  ``fold_in`` stream).
* ``HEDGE_AFTER_DELAY(delay=0)`` is bit-identical to ``REPLICATE_ALL``
  (same dispatch set, exact min-folds), healthy and degraded.
* The new policy codes are bit-identical across the scan body, the
  interpreted kernel and the sharded executor.
* Physics pins: light-load means match the closed forms
  (``analytic.retry_mean_light`` / ``analytic.hedge_mean_light``),
  hedge-delay means are monotone in the delay, and completed-count
  semantics (blackholed requests drop out; TIMEOUT_RETRY's exempt last
  attempt always completes).
"""
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analytic, queueing
from repro.core.distributions import exponential
from repro.core.scenario import (CANCEL_ON_COMPLETE, REPLICATE_TO_IDLE,
                                 SERVER_DEPENDENT, Degradation, Policy,
                                 Scenario)

GOLD = Path(__file__).parent / "golden" / "pre_pr7.npz"
CFG = queueing.SimConfig(n_servers=6, n_arrivals=4096)
RHOS = jnp.asarray((0.3, 0.6))
KEY = jax.random.PRNGKey(7)


def _golden_scenarios():
    dist = exponential()
    return (
        Scenario.paper_default(dist, ks=(1, 2)),
        Scenario(dists=dist, policy=CANCEL_ON_COMPLETE, ks=(2,)),
        Scenario(dists=dist, policy=REPLICATE_TO_IDLE, ks=(2,),
                 client_overhead=0.25),
        Scenario(dists=dist, service_model=SERVER_DEPENDENT, mix=0.7,
                 ks=(2,)),
    )


def _timed_scenarios(dist):
    return [
        Scenario(dists=dist, policy=Policy.TIMEOUT_RETRY, delay=1.5,
                 ks=(2,)),
        Scenario(dists=dist, policy=Policy.HEDGE_AFTER_DELAY, delay=0.7,
                 ks=(2,)),
        Scenario(dists=dist, policy=Policy.HEDGE_AFTER_DELAY, delay=0.7,
                 service_model=SERVER_DEPENDENT, mix=0.7, ks=(2,),
                 degradation=Degradation(p_slow=0.1, slow_factor=3.0,
                                         p_fail=0.05)),
        Scenario(dists=dist, service_model=SERVER_DEPENDENT, mix=0.7,
                 ks=(1, 2)),
    ]


class TestHealthyBitIdentity:
    @pytest.mark.parametrize("run_name,kw", [
        ("unchunked_off", dict(chunk_size=None, kernel="off")),
        ("chunked_off", dict(chunk_size=1536, kernel="off")),
        ("unchunked_interp", dict(chunk_size=None, kernel="interpret")),
    ])
    def test_golden_capture(self, run_name, kw):
        gold = np.load(GOLD)
        out = queueing.run(KEY, _golden_scenarios(), RHOS, CFG, n_seeds=2,
                           percentiles=(50.0, 99.0), **kw)
        for stat in ("mean", "p50", "p99"):
            np.testing.assert_array_equal(
                np.asarray(out[stat]), gold[f"{run_name}/{stat}"],
                err_msg=f"{run_name}/{stat} drifted from pre-PR-7 bits")
        # healthy cells lose nothing: completed == static offered count
        np.testing.assert_array_equal(
            np.asarray(out["completed"]),
            np.broadcast_to(np.asarray(out["count"], np.float32),
                            np.asarray(out["completed"]).shape))

    def test_degraded_variant_leaves_healthy_cells_untouched(self):
        dist = exponential()
        healthy = [Scenario.paper_default(dist, ks=(1, 2))]
        mixed = healthy + [Scenario(
            dists=dist, ks=(2,),
            degradation=Degradation(p_slow=0.2, slow_factor=4.0,
                                    p_fail=0.1))]
        a = queueing.run(KEY, healthy, RHOS, CFG, n_seeds=2,
                         percentiles=(99.0,))
        b = queueing.run(KEY, mixed, RHOS, CFG, n_seeds=2,
                         percentiles=(99.0,))
        for stat in ("mean", "p99", "completed"):
            np.testing.assert_array_equal(
                np.asarray(a[stat]), np.asarray(b[stat])[:, :, :2],
                err_msg=f"degraded neighbour changed healthy {stat} bits")
        # and the degraded cell actually loses requests
        assert (np.asarray(b["completed"])[:, :, 2]
                < np.asarray(b["count"])).all()


class TestHedgeDelayZero:
    @pytest.mark.parametrize("mode", ["off", "interpret"])
    @pytest.mark.parametrize("degraded", [False, True])
    def test_bitwise_replicate_all(self, mode, degraded):
        dist = exponential()
        kw = ({"degradation": Degradation(p_slow=0.15, slow_factor=4.0,
                                          p_fail=0.1)}
              if degraded else {})
        scns = [
            Scenario(dists=dist, policy=Policy.HEDGE_AFTER_DELAY,
                     delay=0.0, ks=(2,), **kw),
            Scenario(dists=dist, policy=Policy.REPLICATE_ALL, ks=(2,),
                     **kw),
        ]
        out = queueing.run(jax.random.PRNGKey(11), scns, RHOS, CFG,
                           n_seeds=2, percentiles=(50.0, 99.0),
                           kernel=mode)
        for stat in ("mean", "p50", "p99", "completed"):
            s = np.asarray(out[stat])
            np.testing.assert_array_equal(
                s[:, :, 0], s[:, :, 1],
                err_msg=f"HEDGE(d=0) != REPLICATE_ALL on {stat} "
                        f"(mode={mode}, degraded={degraded})")


class TestTimedPolicyParity:
    def test_scan_vs_interpret_kernel(self):
        scns = _timed_scenarios(exponential())
        outs = {m: queueing.run(KEY, scns, RHOS, CFG, n_seeds=2,
                                percentiles=(50.0, 99.0), kernel=m)
                for m in ("off", "interpret")}
        for stat in ("mean", "p50", "p99", "completed"):
            np.testing.assert_array_equal(
                np.asarray(outs["off"][stat]),
                np.asarray(outs["interpret"][stat]),
                err_msg=f"scan vs kernel drift on {stat}")

    def test_sharded_parity(self):
        # 1-device "cells" mesh: full shard_map machinery in-process
        # (the test_sweep_shard idiom)
        scns = _timed_scenarios(exponential())
        cfg = queueing.SimConfig(n_servers=6, n_arrivals=2048)
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("cells",))
        base = queueing.run(KEY, scns, RHOS, cfg, n_seeds=2,
                            percentiles=(99.0,))
        shrd = queueing.run(KEY, scns, RHOS, cfg, n_seeds=2,
                            percentiles=(99.0,), mesh=mesh)
        for stat in ("mean", "p99", "completed"):
            np.testing.assert_array_equal(
                np.asarray(base[stat]), np.asarray(shrd[stat]),
                err_msg=f"sharded vs unsharded drift on {stat}")


class TestTimedPolicyPhysics:
    DELAYS = (0.0, 0.5, 1.0, 2.0)

    @pytest.fixture(scope="class")
    def light_load_means(self):
        dist = exponential()
        cfg = queueing.SimConfig(n_servers=10, n_arrivals=20_000)
        scns = [Scenario(dists=dist, policy=Policy.HEDGE_AFTER_DELAY,
                         delay=d, ks=(2,)) for d in self.DELAYS]
        scns += [
            Scenario(dists=dist, policy=Policy.TIMEOUT_RETRY, delay=1.0,
                     ks=(2,)),
            Scenario(dists=dist, policy=Policy.TIMEOUT_RETRY, delay=1.0,
                     ks=(2,), degradation=Degradation(p_fail=0.3)),
        ]
        out = queueing.run(jax.random.PRNGKey(5), scns,
                           jnp.asarray((0.01,)), cfg, n_seeds=4,
                           percentiles=())
        return out, np.asarray(out["mean"]).mean(axis=0)[0]

    def test_hedge_matches_closed_form(self, light_load_means):
        _, means = light_load_means
        for i, d in enumerate(self.DELAYS):
            np.testing.assert_allclose(
                means[i], float(analytic.hedge_mean_light(d)), rtol=0.04)

    def test_hedge_delay_monotone(self, light_load_means):
        _, means = light_load_means
        assert (np.diff(means[:len(self.DELAYS)]) > 0).all()

    def test_retry_matches_closed_form(self, light_load_means):
        _, means = light_load_means
        np.testing.assert_allclose(
            means[4], float(analytic.retry_mean_light(1.0, 0.0)),
            rtol=0.04)
        np.testing.assert_allclose(
            means[5], float(analytic.retry_mean_light(1.0, 0.3)),
            rtol=0.04)

    def test_retry_always_completes(self, light_load_means):
        # the last in-budget attempt is blackhole-exempt, so even a
        # faulty retry cell completes every request
        out, _ = light_load_means
        np.testing.assert_array_equal(
            np.asarray(out["completed"])[:, :, 5],
            np.broadcast_to(np.asarray(out["count"], np.float32),
                            np.asarray(out["completed"])[:, :, 5].shape))

    def test_blackhole_only_grid_loses_requests(self):
        # k=1 REPLICATE_ALL with p_fail: completed/count ~ 1 - p_fail
        dist = exponential()
        cfg = queueing.SimConfig(n_servers=6, n_arrivals=8192)
        scn = Scenario(dists=dist, ks=(1,),
                       degradation=Degradation(p_fail=0.25))
        out = queueing.run(jax.random.PRNGKey(2), [scn],
                           jnp.asarray((0.2,)), cfg, n_seeds=4,
                           percentiles=())
        frac = (np.asarray(out["completed"]).mean()
                / float(np.asarray(out["count"])))
        assert abs(frac - 0.75) < 0.03
        assert np.isfinite(np.asarray(out["mean"])).all()


class TestStragglers:
    def test_stragglers_inflate_tail_hedging_masks_them(self):
        # a 5% x8 straggler mix wrecks the k=1 p99; hedging with a
        # short delay recovers most of it (the paper's fault-masking
        # story at the engine level; with p_slow=0.05 a double-straggle
        # is 0.25% — beyond the p99 the hedge is judged on)
        dist = exponential()
        cfg = queueing.SimConfig(n_servers=10, n_arrivals=8192)
        deg = Degradation(p_slow=0.05, slow_factor=8.0)
        scns = [
            Scenario(dists=dist, ks=(1,)),
            Scenario(dists=dist, ks=(1,), degradation=deg),
            Scenario(dists=dist, policy=Policy.HEDGE_AFTER_DELAY,
                     delay=1.0, ks=(2,), degradation=deg),
        ]
        out = queueing.run(jax.random.PRNGKey(9), scns,
                           jnp.asarray((0.2,)), cfg, n_seeds=4,
                           percentiles=(99.0,))
        p99 = np.asarray(out["p99"]).mean(axis=0)[0]
        clean, straggled, hedged = p99
        assert straggled > 2.0 * clean
        assert hedged < 0.5 * straggled
