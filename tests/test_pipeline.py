"""Pipeline-parallelism-over-pod test (subprocess, fake devices)."""
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
import jax.numpy as jnp
import numpy as np
from repro.distributed.pipeline import pipeline_apply

mesh = jax.make_mesh((2,), ("pod",))

# two stages, each one dense layer
key = jax.random.PRNGKey(0)
k1, k2, kx = jax.random.split(key, 3)
w = jnp.stack([jax.random.normal(k1, (8, 8)) * 0.3,
               jax.random.normal(k2, (8, 8)) * 0.3])
params = {"w": w}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])

x = jax.random.normal(kx, (4, 3, 8))  # 4 microbatches of (3, 8)

out = jax.jit(lambda p, xx: pipeline_apply(stage_fn, p, xx, mesh))(params, x)

# reference: sequential stage application per microbatch
ref = jnp.tanh(jnp.tanh(x @ w[0]) @ w[1])
err = float(jnp.max(jnp.abs(out - ref)))
print("max err", err)
assert err < 1e-5
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_two_stage_pipeline_matches_sequential():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout[-800:], out.stderr[-2000:])
    assert "PIPELINE_OK" in out.stdout
