"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting shapes and finiteness; plus decode-path parity checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.configs import get_smoke_config, list_architectures
from repro.models import decode as dec
from repro.models import lm

BATCH, SEQ = 2, 32


def make_batch(cfg, key, seq=SEQ, batch=BATCH):
    kt, kp = jax.random.split(key)
    if cfg.family == "audio":
        toks = jax.random.randint(kt, (batch, seq + 1, cfg.n_codebooks), 0,
                                  cfg.vocab_size)
        return {"tokens": toks}
    if cfg.patch_stub is not None:
        toks = jax.random.randint(kt, (batch, seq + 1), 0, cfg.vocab_size)
        patches = jax.random.normal(
            kp, (batch, cfg.patch_stub.n_patches, cfg.patch_stub.embed_dim),
            dtype=jnp.float32)
        return {"tokens": toks, "patches": patches}
    toks = jax.random.randint(kt, (batch, seq + 1), 0, cfg.vocab_size)
    return {"tokens": toks}


ARCHS = list_architectures()


class TestRegistry:
    def test_all_ten_archs_registered(self):
        assert len(ARCHS) == 10

    def test_full_configs_match_assignment(self):
        spec = {
            "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256_000),
            "gemma2-2b": (26, 2304, 8, 4, 9216, 256_000),
            "command-r-35b": (40, 8192, 64, 8, 22528, 256_000),
            "gemma3-12b": (48, 3840, 16, 8, 15360, 262_144),
            "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129_280),
            "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49_155),
            "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256_000),
            "mamba2-370m": (48, 1024, 32, 32, 0, 50_280),
            "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
            "llava-next-34b": (60, 7168, 56, 8, 20480, 64_000),
        }
        for name, (nl, d, h, kv, ff, v) in spec.items():
            cfg = cfgbase.get_config(name)
            assert cfg.n_layers == nl, name
            assert cfg.d_model == d, name
            assert cfg.n_heads == h, name
            assert cfg.n_kv_heads == kv, name
            assert cfg.d_ff == ff, name
            assert cfg.vocab_size == v, name
            assert len(cfg.layer_kinds) == cfg.n_layers, name

    def test_param_counts_in_range(self):
        # sanity: the full configs land near their nameplate sizes
        expect = {"nemotron-4-15b": (12e9, 19e9),
                  "command-r-35b": (30e9, 40e9),
                  "deepseek-v3-671b": (550e9, 750e9),
                  "gemma2-2b": (2e9, 3.5e9),
                  "gemma3-12b": (9e9, 14e9),
                  "granite-moe-3b-a800m": (2.5e9, 4.5e9),
                  "recurrentgemma-9b": (7e9, 11e9),
                  "mamba2-370m": (0.25e9, 0.55e9),
                  "musicgen-large": (1.5e9, 3e9),
                  "llava-next-34b": (30e9, 40e9)}
        for name, (lo, hi) in expect.items():
            n = cfgbase.get_config(name).param_count
            assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"

    def test_moe_active_params_much_smaller(self):
        cfg = cfgbase.get_config("deepseek-v3-671b")
        assert cfg.active_param_count < 0.1 * cfg.param_count


@pytest.mark.parametrize("arch", ARCHS)
class TestSmokeForward:
    def test_forward_loss_finite(self, arch):
        cfg = get_smoke_config(arch)
        key = jax.random.PRNGKey(0)
        params = lm.init(key, cfg)
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        loss, metrics = jax.jit(
            lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)
        assert loss.shape == ()
        assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
        assert float(metrics["loss"]) > 0.0

    def test_train_step_grads_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg, jax.random.PRNGKey(1))

        def loss_of(p):
            return lm.loss_fn(p, cfg, batch)[0]

        grads = jax.jit(jax.grad(loss_of))(params)
        flat, _ = jax.tree_util.tree_flatten(grads)
        assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
                   for g in flat), f"{arch}: non-finite grads"
        # at least one nonzero grad leaf
        assert any(float(jnp.max(jnp.abs(g.astype(jnp.float32)))) > 0
                   for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
class TestDecodeParity:
    def test_prefill_plus_decode_matches_forward(self, arch):
        """Teacher-forced decode after prefill must reproduce the logits of
        the full forward pass (the core correctness invariant of the cache
        machinery, per layer family)."""
        cfg = get_smoke_config(arch)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        seq = SEQ
        batch = make_batch(cfg, jax.random.PRNGKey(1), seq=seq)
        toks = batch["tokens"]
        max_len = seq + 8 + (cfg.patch_stub.n_patches if cfg.patch_stub else 0)

        # reference: prefill over the whole prompt, compare against
        # prefill(prompt[:-1]) + one decode step of the last token.
        full_batch = dict(batch)
        full_batch["tokens"] = toks[:, :seq + 1]
        ref_logits, _ = jax.jit(
            lambda p, b: dec.prefill(p, cfg, b, max_len))(params, full_batch)

        short = dict(batch)
        short["tokens"] = toks[:, :seq]
        _, cache = jax.jit(
            lambda p, b: dec.prefill(p, cfg, b, max_len))(params, short)
        pos = seq + (cfg.patch_stub.n_patches if cfg.patch_stub else 0)
        last_tok = toks[:, seq:seq + 1]
        step_logits, _ = jax.jit(
            lambda p, c, t: dec.decode_step(p, cfg, c, t,
                                            jnp.int32(pos)))(
            params, cache, last_tok)

        np.testing.assert_allclose(
            np.asarray(step_logits, dtype=np.float32),
            np.asarray(ref_logits, dtype=np.float32),
            rtol=5e-2, atol=5e-2)
