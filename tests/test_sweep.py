"""Fused sweep-engine tests: CRN coupling, equivalence with the sequential
``simulate_grid`` path, batched-distribution sweeps, and the jit-cache
memoization contract of the distribution factories."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import distributions as dists, queueing, threshold

CFG = queueing.SimConfig(n_servers=10, n_arrivals=10_000)
RHOS = jnp.asarray([0.1, 0.3])


def _reference_summaries(key, dist, rhos, cfg, ks, n_seeds):
    """Pre-refactor path: one simulate_grid scan per (seed, k)."""
    keys = jax.random.split(key, n_seeds)
    mean = jnp.zeros((n_seeds, len(rhos), len(ks)))
    p99 = jnp.zeros_like(mean)
    for s in range(n_seeds):
        for j, k in enumerate(ks):
            r = queueing._warm(
                queueing.simulate_grid(keys[s], dist, rhos, cfg, k), cfg)
            mean = mean.at[s, :, j].set(jnp.mean(r, axis=-1))
            p99 = p99.at[s, :, j].set(jnp.percentile(r, 99.0, axis=-1))
    return mean, p99


class TestSweepEquivalence:
    def test_means_match_simulate_grid_path(self):
        key = jax.random.PRNGKey(0)
        out = queueing.sweep(key, dists.exponential(), RHOS, CFG, ks=(1, 2),
                             n_seeds=2)
        ref_mean, ref_p99 = _reference_summaries(
            key, dists.exponential(), RHOS, CFG, (1, 2), 2)
        # identical sample paths => float-tolerance agreement on the mean
        assert jnp.allclose(out["mean"], ref_mean, rtol=1e-4)
        # histogram-sketch percentiles: within half a log-bin (~0.5%)
        assert jnp.allclose(out["p99"], ref_p99, rtol=0.02)

    def test_replication_gain_matches_reference(self):
        key = jax.random.PRNGKey(1)
        g = queueing.replication_gain(key, dists.pareto(2.5), RHOS, CFG,
                                      n_seeds=2)
        ref_mean, _ = _reference_summaries(
            key, dists.pareto(2.5), RHOS, CFG, (1, 2), 2)
        ref_g = jnp.mean(ref_mean[:, :, 0] - ref_mean[:, :, 1], axis=0)
        assert jnp.allclose(g, ref_g, atol=1e-3)

    def test_threshold_grid_matches_reference(self):
        key = jax.random.PRNGKey(2)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=30_000)
        rhos = jnp.linspace(0.1, 0.45, 8)
        t_fused = threshold.threshold_grid(key, dists.exponential(), cfg,
                                           rhos=rhos, n_seeds=2)
        keys = jax.random.split(key, 2)
        gains = []
        for s in range(2):
            r1 = queueing.simulate_grid(keys[s], dists.exponential(), rhos,
                                        cfg, 1)
            r2 = queueing.simulate_grid(keys[s], dists.exponential(), rhos,
                                        cfg, 2)
            gains.append(jnp.mean(queueing._warm(r1, cfg), -1)
                         - jnp.mean(queueing._warm(r2, cfg), -1))
        t_ref = threshold._interp_crossing(rhos,
                                           jnp.mean(jnp.stack(gains), 0))
        assert t_fused == pytest.approx(t_ref, abs=0.01)

    def test_sweep_dists_stacks_cleanly(self):
        key = jax.random.PRNGKey(3)
        ds = [dists.exponential(), dists.two_point(0.9)]
        batched = queueing.sweep_dists(key, ds, RHOS, CFG, ks=(1, 2),
                                       n_seeds=2, percentiles=())
        assert batched["mean"].shape == (2, 2, 2, 2)
        for d_idx, d in enumerate(ds):
            single = queueing.sweep(key, d, RHOS, CFG, ks=(1, 2), n_seeds=2,
                                    percentiles=())
            assert jnp.allclose(batched["mean"][d_idx], single["mean"],
                                rtol=1e-5)


class TestSweepCRN:
    def test_k_slices_share_first_copy(self):
        # the k=1 slice and the k=2 slice of one sweep consume the same
        # first-copy server choice and service draw (CRN): at near-zero load
        # the k=2 mean can only be lower.
        key = jax.random.PRNGKey(4)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=5_000)
        out = queueing.sweep(key, dists.pareto(2.1), jnp.asarray([0.001]),
                             cfg, ks=(1, 2), n_seeds=1, percentiles=())
        m1, m2 = float(out["mean"][0, 0, 0]), float(out["mean"][0, 0, 1])
        assert m2 <= m1

    def test_sampled_inputs_prefix_property(self):
        # k=1 and k=2 share the first copy's server choice + service draw
        # under one key, for every seed of the batched sampler.
        key = jax.random.PRNGKey(5)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=200)
        d = dists.exponential()
        g1, s1, v1 = queueing._sample_sweep_inputs(key, d, cfg, 1, 3)
        g2, s2, v2 = queueing._sample_sweep_inputs(key, d, cfg, 2, 3)
        assert jnp.array_equal(g1, g2)
        assert jnp.array_equal(s1[:, :, 0], s2[:, :, 0])
        assert jnp.array_equal(v1[:, :, 0], v2[:, :, 0])
        # and the batched sampler matches the sequential per-seed sampler
        keys = jax.random.split(key, 3)
        for s in range(3):
            g_ref, s_ref, v_ref = queueing._sample_inputs(keys[s], d, cfg, 2)
            assert jnp.array_equal(g2[s], g_ref)
            assert jnp.array_equal(s2[s], s_ref)
            assert jnp.array_equal(v2[s], v_ref)


class TestChunkedSweep:
    """Chunk-streamed engine: agreement with the pre-sampled path,
    invariance to chunk_size, and the fold_in(key, chunk) reproducibility
    contract."""

    CFG = queueing.SimConfig(n_servers=10, n_arrivals=24_000)

    def test_chunked_matches_unchunked_within_tolerance(self):
        # different random streams (fold_in per chunk vs one pre-sample),
        # same process: summaries agree to Monte-Carlo tolerance.
        key = jax.random.PRNGKey(20)
        un = queueing.sweep(key, dists.exponential(), RHOS, self.CFG,
                            ks=(1, 2), n_seeds=2)
        ch = queueing.sweep(key, dists.exponential(), RHOS, self.CFG,
                            ks=(1, 2), n_seeds=2, chunk_size=4096)
        assert ch["count"] == un["count"]
        assert jnp.allclose(ch["mean"], un["mean"], rtol=0.08)
        assert jnp.allclose(ch["p99"], un["p99"], rtol=0.25)

    def test_chunk_size_invariance_statistical(self):
        # 1k vs 4k chunks, same key: different key consumption, same
        # process => statistically identical summaries.
        key = jax.random.PRNGKey(21)
        s1 = queueing.sweep(key, dists.exponential(), RHOS, self.CFG,
                            ks=(1, 2), n_seeds=2, chunk_size=1_000)
        s4 = queueing.sweep(key, dists.exponential(), RHOS, self.CFG,
                            ks=(1, 2), n_seeds=2, chunk_size=4_000)
        assert jnp.allclose(s1["mean"], s4["mean"], rtol=0.08)
        assert jnp.allclose(s1["p99"], s4["p99"], rtol=0.25)

    def test_chunked_rerun_bit_identical(self):
        # the chunked stream is a pure function of (key, chunk_size)
        key = jax.random.PRNGKey(22)
        a = queueing.sweep(key, dists.pareto(2.5), RHOS, self.CFG,
                           ks=(1, 2), n_seeds=1, chunk_size=3_000)
        b = queueing.sweep(key, dists.pareto(2.5), RHOS, self.CFG,
                           ks=(1, 2), n_seeds=1, chunk_size=3_000)
        assert jnp.array_equal(a["mean"], b["mean"])
        assert jnp.array_equal(a["p99"], b["p99"])

    def test_chunked_crn_pairing_across_k(self):
        # CRN holds inside every chunk: at near-zero load the k=2 slice
        # can only beat the k=1 slice (shared first-copy draws).
        key = jax.random.PRNGKey(23)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=6_000)
        out = queueing.sweep(key, dists.pareto(2.1), jnp.asarray([0.001]),
                             cfg, ks=(1, 2), n_seeds=1, percentiles=(),
                             chunk_size=1_000)
        assert float(out["mean"][0, 0, 1]) <= float(out["mean"][0, 0, 0])

    def test_ragged_final_chunk_and_odd_chunk_size(self):
        # chunk_size that divides neither n_arrivals nor the sketch block:
        # padding/masking must not distort the summaries.
        key = jax.random.PRNGKey(24)
        ch = queueing.sweep(key, dists.exponential(), RHOS, self.CFG,
                            ks=(1, 2), n_seeds=2, chunk_size=1_700)
        un = queueing.sweep(key, dists.exponential(), RHOS, self.CFG,
                            ks=(1, 2), n_seeds=2)
        assert jnp.allclose(ch["mean"], un["mean"], rtol=0.08)

    def test_chunked_sweep_dists_matches_single_sweeps(self):
        # the stacked-distribution driver shares each chunk's arrival
        # process across dists and matches per-dist chunked sweeps exactly
        key = jax.random.PRNGKey(25)
        ds = [dists.exponential(), dists.two_point(0.9)]
        batched = queueing.sweep_dists(key, ds, RHOS, CFG, ks=(1, 2),
                                       n_seeds=2, percentiles=(),
                                       chunk_size=2_500)
        assert batched["mean"].shape == (2, 2, 2, 2)
        for d_idx, d in enumerate(ds):
            single = queueing.sweep(key, d, RHOS, CFG, ks=(1, 2), n_seeds=2,
                                    percentiles=(), chunk_size=2_500)
            assert jnp.allclose(batched["mean"][d_idx], single["mean"],
                                rtol=1e-5)

    def test_threshold_grid_chunked_close_to_unchunked(self):
        key = jax.random.PRNGKey(26)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=30_000)
        rhos = jnp.linspace(0.1, 0.45, 8)
        t_un = threshold.threshold_grid(key, dists.exponential(), cfg,
                                        rhos=rhos, n_seeds=2)
        t_ch = threshold.threshold_grid(key, dists.exponential(), cfg,
                                        rhos=rhos, n_seeds=2,
                                        chunk_size=8_192)
        # within one grid step of each other (independent streams)
        assert abs(t_un - t_ch) <= float(rhos[1] - rhos[0])


class TestFactoryMemoization:
    def test_scalar_factories_are_memoized(self):
        assert dists.pareto(2.1) is dists.pareto(2.1)
        assert dists.weibull(0.7) is dists.weibull(0.7)
        assert dists.two_point(0.5) is dists.two_point(0.5)
        assert dists.exponential() is dists.exponential()
        assert dists.deterministic() is dists.deterministic()
        assert dists.scaled(dists.exponential(), 2.0) is dists.scaled(
            dists.exponential(), 2.0)

    def test_distinct_params_distinct_objects(self):
        assert dists.pareto(2.1) is not dists.pareto(2.2)

    def test_memoized_dist_hits_jit_cache(self):
        cfg = queueing.SimConfig(n_servers=5, n_arrivals=500)
        key = jax.random.PRNGKey(6)
        queueing.simulate(key, dists.pareto(3.3), jnp.float32(0.2), cfg, k=1)
        n0 = queueing.simulate._cache_size()
        # rebuilding the "same" distribution must not retrace
        queueing.simulate(key, dists.pareto(3.3), jnp.float32(0.2), cfg, k=1)
        assert queueing.simulate._cache_size() == n0
