"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention import ref as da_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rglru_scan import ops as rg_ops
from repro.kernels.rglru_scan import ref as rg_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.models import ssd as ssd_model


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-3, atol=2e-3)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [
        # (S, H, KV, hd)
        (64, 4, 2, 32),    # GQA
        (128, 2, 2, 64),   # MHA
        (96, 8, 1, 16),    # MQA, non-pow2 seq
        (256, 4, 4, 128),  # large block
    ])
    def test_matches_ref(self, shape, dtype):
        s, h, kv, hd = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, s, h, hd), dtype=dtype)
        k = jax.random.normal(ks[1], (2, s, kv, hd), dtype=dtype)
        v = jax.random.normal(ks[2], (2, s, kv, hd), dtype=dtype)
        out = fa_ops.flash_attention(q, k, v, interpret=True)
        expect = fa_ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   **tol(dtype))

    @pytest.mark.parametrize("window", [8, 32])
    @pytest.mark.parametrize("softcap", [None, 50.0])
    def test_window_and_softcap(self, window, softcap):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 64, 4, 32))
        k = jax.random.normal(ks[1], (1, 64, 2, 32))
        v = jax.random.normal(ks[2], (1, 64, 2, 32))
        out = fa_ops.flash_attention(q, k, v, window=window, softcap=softcap,
                                     interpret=True)
        expect = fa_ref.flash_attention_ref(q, k, v, window=window,
                                            softcap=softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-3, atol=2e-3)

    def test_matches_model_reference_path(self):
        # the kernel must agree with the model's _sdpa path end-to-end
        from repro.models import attention as attn
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, 32, 4, 16))
        k = jax.random.normal(ks[1], (2, 32, 2, 16))
        v = jax.random.normal(ks[2], (2, 32, 2, 16))
        mask = attn.causal_mask(32, None)
        expect = attn._sdpa(q, k, v, mask, None)
        out = fa_ops.flash_attention(q, k, v, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-3, atol=2e-3)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [
        # (L, H, KV, hd, pos)
        (64, 4, 2, 32, 40),
        (128, 8, 8, 64, 127),
        (96, 4, 1, 16, 5),
    ])
    def test_dense_cache(self, shape, dtype):
        length, h, kv, hd, pos = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, 1, h, hd), dtype=dtype)
        k = jax.random.normal(ks[1], (2, length, kv, hd), dtype=dtype)
        v = jax.random.normal(ks[2], (2, length, kv, hd), dtype=dtype)
        slot_pos = jnp.where(jnp.arange(length) <= pos,
                             jnp.arange(length), -1).astype(jnp.int32)
        out = da_ops.decode_attention(q, k, v, slot_pos, jnp.int32(pos),
                                      interpret=True)
        expect = da_ref.decode_attention_ref(q, k, v, slot_pos,
                                             jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   **tol(dtype))

    def test_ring_buffer_window(self):
        # ring cache of size w with wrapped positions + window mask
        w, h, kv, hd = 32, 4, 2, 16
        pos = 45  # cache holds positions 14..45 in wrapped slots
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 1, h, hd))
        k = jax.random.normal(ks[1], (1, w, kv, hd))
        v = jax.random.normal(ks[2], (1, w, kv, hd))
        slot_pos = jnp.asarray([(pos - ((pos - s) % w)) for s in range(w)],
                               dtype=jnp.int32)
        out = da_ops.decode_attention(q, k, v, slot_pos, jnp.int32(pos),
                                      window=w, interpret=True)
        expect = da_ref.decode_attention_ref(q, k, v, slot_pos,
                                             jnp.int32(pos), window=w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-3, atol=2e-3)


class TestSSDScan:
    @pytest.mark.parametrize("shape", [
        # (B, L, H, P, N, chunk)
        (2, 64, 4, 32, 16, 16),
        (1, 128, 2, 64, 32, 32),
        (2, 96, 4, 16, 8, 8),
    ])
    def test_matches_intra_ref(self, shape):
        b, length, h, p, n, q = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        nc = length // q
        xc = jax.random.normal(ks[0], (b, nc, q, h, p))
        bc = jax.random.normal(ks[1], (b, nc, q, n))
        cc = jax.random.normal(ks[2], (b, nc, q, n))
        dtc = jax.nn.softplus(jax.random.normal(ks[3], (b, nc, q, h)))
        a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(4), (h,)) * 0.2)
        cum = jnp.cumsum(dtc * a[None, None, None, :], axis=2)
        y_k, st_k = ssd_ops.ssd_intra_chunk(xc, bc, cc, dtc, cum,
                                            interpret=True)
        y_r, st_r = ssd_ref.ssd_intra_chunk_ref(xc, bc, cc, dtc, cum)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                                   rtol=5e-3, atol=5e-3)

    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_chunked_matches_sequential(self, chunk):
        # the full chunked algorithm (jnp path) == exact recurrence
        b, length, h, p, n = 2, 64, 2, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        xh = jax.random.normal(ks[0], (b, length, h, p))
        bb = jax.random.normal(ks[1], (b, length, n))
        cc = jax.random.normal(ks[2], (b, length, n))
        dt = jax.nn.softplus(jax.random.normal(ks[3], (b, length, h)))
        a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(5), (h,)) * 0.2)
        y_c, h_c = ssd_model.ssd_chunked(xh, bb, cc, dt, a, chunk)
        y_s, h_s = ssd_model.ssd_reference(xh, bb, cc, dt, a)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s),
                                   rtol=1e-3, atol=1e-3)

    def test_chunked_pallas_matches_sequential(self):
        b, length, h, p, n = 1, 64, 2, 32, 16
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        xh = jax.random.normal(ks[0], (b, length, h, p))
        bb = jax.random.normal(ks[1], (b, length, n))
        cc = jax.random.normal(ks[2], (b, length, n))
        dt = jax.nn.softplus(jax.random.normal(ks[3], (b, length, h)))
        a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(6), (h,)) * 0.2)
        y_c, h_c = ssd_model.ssd_chunked(xh, bb, cc, dt, a, 16, impl="pallas")
        y_s, h_s = ssd_model.ssd_reference(xh, bb, cc, dt, a)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s),
                                   rtol=2e-3, atol=2e-3)


class TestRGLRUScan:
    @pytest.mark.parametrize("shape", [
        (2, 64, 32), (1, 128, 256), (3, 96, 24),
    ])
    def test_matches_ref(self, shape):
        b, length, w = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, length, w)))
        bb = jax.random.normal(ks[1], (b, length, w))
        out = rg_ops.chunked_linear_scan(a, bb, interpret=True)
        expect = rg_ref.linear_scan_ref(a, bb)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_model_pallas_path_matches_ref_path(self):
        from repro.configs.base import RGLRUConfig
        from repro.models import rglru
        cfg = RGLRUConfig(lru_width=32, conv_width=4)
        p = rglru.init_rglru_block(jax.random.PRNGKey(0), 32, cfg,
                                   dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
        y_ref = rglru.rglru_block(p, x, cfg, impl="ref")
        y_pal = rglru.rglru_block(p, x, cfg, impl="pallas")
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=3e-3, atol=3e-3)
