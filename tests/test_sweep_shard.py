"""Sharded cell-plan executor: bit-identity with the unsharded engine.

The CRN contract (``queueing.py``) promises that for the same
``(key, chunk_size)`` the sharded and unsharded engines agree BIT FOR
BIT for any device count, because cell randomness derives from cell
coordinates, never device placement.

In-process tests run on a 1-device "cells" mesh — the full shard_map
machinery without real sharding, so they execute in every tier-1 run.
The subprocess test forces 8 host devices (the idiom of
``test_distributed_exec.py``: the XLA override must not leak into the
main test process) and checks cell counts both divisible and NOT
divisible by the device count (exercising the pad/mask path), the
dist-stacked driver, MIXED-policy scenario grids (policy/model codes
sharded as per-cell coordinates), HETEROGENEOUS mixed-dist grids (the
per-cell dist_id / svc_idx routing sharded the same way: scan ==
interpreted kernel == sharded), threshold bisection (bare dist
and Scenario forms), and the fused cell-update kernel (its per-cell
grid maps 1:1 onto the sharded axis, so kernel mode must preserve the
sharded==unsharded bit-identity too).
"""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.core import distributions as dists, queueing, threshold
from repro.core.scenario import (CANCEL_ON_COMPLETE, REPLICATE_TO_IDLE,
                                 SERVER_DEPENDENT, Scenario)
from repro.distributed import sweep_shard
from repro.launch.mesh import make_sweep_mesh

SRC = str(Path(__file__).resolve().parent.parent / "src")

CFG = queueing.SimConfig(n_servers=10, n_arrivals=6_000)
RHOS = jnp.asarray([0.1, 0.3])


def _assert_bit_identical(a, b, fields=("mean", "p50", "p99")):
    assert a["count"] == b["count"]
    for f in fields:
        assert jnp.array_equal(a[f], b[f]), f


class TestShardedSingleDeviceMesh:
    def test_chunked_bit_identical(self):
        key = jax.random.PRNGKey(0)
        kw = dict(ks=(1, 2), n_seeds=2, chunk_size=1_700)  # ragged chunks
        un = queueing.sweep(key, dists.exponential(), RHOS, CFG, **kw)
        sh = sweep_shard.sweep_sharded(key, dists.exponential(), RHOS, CFG,
                                       mesh=make_sweep_mesh(1), **kw)
        _assert_bit_identical(un, sh)

    def test_unchunked_bit_identical(self):
        key = jax.random.PRNGKey(1)
        kw = dict(ks=(1, 2), n_seeds=2)
        un = queueing.sweep(key, dists.pareto(2.5), RHOS, CFG, **kw)
        sh = sweep_shard.sweep_sharded(key, dists.pareto(2.5), RHOS, CFG,
                                       mesh=make_sweep_mesh(1), **kw)
        _assert_bit_identical(un, sh)

    def test_sweep_dists_bit_identical(self):
        key = jax.random.PRNGKey(2)
        ds = (dists.exponential(), dists.two_point(0.9))
        kw = dict(ks=(1, 2), n_seeds=2, percentiles=(), chunk_size=2_500)
        un = queueing.sweep_dists(key, ds, RHOS, CFG, **kw)
        sh = sweep_shard.sweep_dists_sharded(key, ds, RHOS, CFG,
                                             mesh=make_sweep_mesh(1), **kw)
        _assert_bit_identical(un, sh, fields=("mean",))
        assert sh["mean"].shape == (2, 2, 2, 2)

    def test_threshold_bisect_identical(self):
        key = jax.random.PRNGKey(3)
        kw = dict(iters=4, n_seeds=2, chunk_size=2_000)
        t_un = threshold.threshold_bisect(key, dists.exponential(), CFG,
                                          **kw)
        t_sh = threshold.threshold_bisect(key, dists.exponential(), CFG,
                                          mesh=make_sweep_mesh(1), **kw)
        assert t_un == t_sh

    def test_mixed_policy_grid_bit_identical(self):
        # a MIXED grid — paper cells, a cancellation cell, a
        # server-dependent cell — through run(mesh=...): the policy/model
        # codes shard with the plan, results bit-match the local engine.
        key = jax.random.PRNGKey(4)
        d = dists.exponential()
        scns = (Scenario.paper_default(d, ks=(1, 2)),
                Scenario(dists=d, policy=CANCEL_ON_COMPLETE, ks=(2,)),
                Scenario(dists=d, policy=REPLICATE_TO_IDLE, ks=(2,)),
                Scenario(dists=d, service_model=SERVER_DEPENDENT, mix=0.7,
                         ks=(2,)))
        kw = dict(n_seeds=2, chunk_size=1_700)
        un = queueing.run(key, scns, RHOS, CFG, **kw)
        sh = queueing.run(key, scns, RHOS, CFG, mesh=make_sweep_mesh(1),
                          **kw)
        _assert_bit_identical(un, sh)
        assert un["mean"].shape == (2, 2, 5)

    def test_mixed_dists_grid_bit_identical(self):
        # a HETEROGENEOUS grid — two systems via per-cell dist_id —
        # through run(mesh=...): svc_idx shards with the plan, results
        # bit-match the local engine (scan AND interpreted kernel).
        key = jax.random.PRNGKey(6)
        scns = (Scenario(dists=dists.exponential(), ks=(1, 2)),
                Scenario(dists=dists.pareto(2.5), ks=(1, 2),
                         client_overhead=0.05))
        kw = dict(n_seeds=2, chunk_size=1_700)
        un = queueing.run(key, scns, RHOS, CFG, **kw)
        sh = queueing.run(key, scns, RHOS, CFG, mesh=make_sweep_mesh(1),
                          **kw)
        _assert_bit_identical(un, sh)
        sh_kern = queueing.run(key, scns, RHOS, CFG, kernel="interpret",
                               mesh=make_sweep_mesh(1), **kw)
        _assert_bit_identical(un, sh_kern)
        assert un["mean"].shape == (2, 2, 4)

    def test_kernel_mode_bit_identical(self):
        # the fused cell-update kernel runs per shard on its local cells
        # (interpret mode on CPU): sharded kernel == unsharded kernel ==
        # unsharded scan, bit for bit
        key = jax.random.PRNGKey(5)
        scn = Scenario.paper_default(dists.exponential(), ks=(1, 2))
        kw = dict(n_seeds=2, chunk_size=1_700)
        un_scan = queueing.run(key, scn, RHOS, CFG, kernel="off", **kw)
        un_kern = queueing.run(key, scn, RHOS, CFG, kernel="on", **kw)
        sh_kern = queueing.run(key, scn, RHOS, CFG, kernel="on",
                               mesh=make_sweep_mesh(1), **kw)
        _assert_bit_identical(un_scan, un_kern)
        _assert_bit_identical(un_kern, sh_kern)

    def test_rejects_wrong_mesh_axes(self):
        mesh = jax.make_mesh((1,), ("data",))
        with pytest.raises(ValueError, match="cells"):
            sweep_shard.sweep_sharded(jax.random.PRNGKey(0),
                                      dists.exponential(), RHOS, CFG,
                                      mesh=mesh)


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp

from repro.core import distributions as dists, queueing, threshold
from repro.distributed import sweep_shard
from repro.launch.mesh import make_sweep_mesh

assert jax.device_count() == 8
mesh = make_sweep_mesh(8)
cfg = queueing.SimConfig(n_servers=10, n_arrivals=5_000)
key = jax.random.PRNGKey(0)

def check(label, un, sh, fields=("mean", "p50", "p99")):
    assert un["count"] == sh["count"], label
    for f in fields:
        assert jnp.array_equal(un[f], sh[f]), (label, f)
    print(label, "bit-identical")

# divisible: 2 seeds x 2 loads x 2 ks = 8 cells on 8 devices
rhos = jnp.asarray([0.15, 0.35])
kw = dict(ks=(1, 2), n_seeds=2, chunk_size=2_000)
check("divisible",
      queueing.sweep(key, dists.exponential(), rhos, cfg, **kw),
      sweep_shard.sweep_sharded(key, dists.exponential(), rhos, cfg,
                                mesh=mesh, **kw))

# NOT divisible: 1 seed x 3 loads x 2 ks = 6 cells -> padded to 8
rhos3 = jnp.asarray([0.1, 0.25, 0.4])
kw = dict(ks=(1, 2), n_seeds=1, chunk_size=1_700)  # ragged final chunk
check("non-divisible",
      queueing.sweep(key, dists.pareto(2.5), rhos3, cfg, **kw),
      sweep_shard.sweep_sharded(key, dists.pareto(2.5), rhos3, cfg,
                                mesh=mesh, **kw))

# unchunked, non-divisible
kw = dict(ks=(1, 2), n_seeds=1)
check("unchunked",
      queueing.sweep(key, dists.two_point(0.9), rhos3, cfg, **kw),
      sweep_shard.sweep_sharded(key, dists.two_point(0.9), rhos3, cfg,
                                mesh=mesh, **kw))

# dist-stacked, non-divisible: 2 dists x 1 seed x 3 loads x 2 ks = 12 -> 16
ds = (dists.exponential(), dists.weibull(0.7))
kw = dict(ks=(1, 2), n_seeds=1, percentiles=(), chunk_size=2_000)
check("sweep_dists",
      queueing.sweep_dists(key, ds, rhos3, cfg, **kw),
      sweep_shard.sweep_dists_sharded(key, ds, rhos3, cfg, mesh=mesh,
                                      **kw),
      fields=("mean",))

# MIXED-policy grid, non-divisible: 1 seed x 3 loads x 5 variants = 15 -> 16
from repro.core.scenario import (CANCEL_ON_COMPLETE, REPLICATE_TO_IDLE,
                                 SERVER_DEPENDENT, Scenario)
d = dists.exponential()
scns = (Scenario.paper_default(d, ks=(1, 2)),
        Scenario(dists=d, policy=CANCEL_ON_COMPLETE, ks=(2,)),
        Scenario(dists=d, policy=REPLICATE_TO_IDLE, ks=(2,)),
        Scenario(dists=d, service_model=SERVER_DEPENDENT, mix=0.7,
                 ks=(2,)))
kw = dict(n_seeds=1, chunk_size=1_700)
check("mixed-policy",
      queueing.run(key, scns, rhos3, cfg, **kw),
      queueing.run(key, scns, rhos3, cfg, mesh=mesh, **kw))

# HETEROGENEOUS (mixed-dist) grid, non-divisible: two systems x
# 1 seed x 3 loads x 2 ks = 12 cells -> padded to 16. The per-cell
# svc_idx shards with the plan: scan == interpreted kernel == sharded.
het = (Scenario(dists=d, ks=(1, 2)),
       Scenario(dists=dists.pareto(2.5), ks=(1, 2), client_overhead=0.05))
het_scan = queueing.run(key, het, rhos3, cfg, **kw)
het_kern = queueing.run(key, het, rhos3, cfg, kernel="interpret", **kw)
het_sh = queueing.run(key, het, rhos3, cfg, mesh=mesh, **kw)
check("mixed-dists scan vs kernel", het_scan, het_kern)
check("mixed-dists scan vs sharded", het_scan, het_sh)

# fused cell-update kernel (interpret mode off-TPU), sharded at 8
# devices: the kernel's per-cell grid maps 1:1 onto the sharded axis,
# so sharded-kernel == unsharded-kernel == unsharded-scan bits
scn = queueing.Scenario.paper_default(dists.exponential(), ks=(1, 2))
un_scan = queueing.run(key, scn, rhos, cfg, kernel="off",
                       n_seeds=2, chunk_size=2_000)
un_kern = queueing.run(key, scn, rhos, cfg, kernel="interpret",
                       n_seeds=2, chunk_size=2_000)
sh_kern = queueing.run(key, scn, rhos, cfg, kernel="interpret",
                       mesh=mesh, n_seeds=2, chunk_size=2_000)
check("kernel unsharded-scan vs unsharded-kernel", un_scan, un_kern)
check("kernel unsharded-kernel vs sharded-kernel", un_kern, sh_kern)

# threshold bisection: every probe batch rides the sharded cell axis —
# under a Scenario too (cancellation: replication helps everywhere, so
# both paths must return the bracket's hi)
kw = dict(iters=4, n_seeds=2, chunk_size=2_000)
t_un = threshold.threshold_bisect(key, dists.exponential(), cfg, **kw)
t_sh = threshold.threshold_bisect(key, dists.exponential(), cfg,
                                  mesh=mesh, **kw)
assert t_un == t_sh, (t_un, t_sh)
scn = Scenario(dists=d, policy=CANCEL_ON_COMPLETE)
t_un = threshold.threshold_bisect(key, scn, cfg, **kw)
t_sh = threshold.threshold_bisect(key, scn, cfg, mesh=mesh, **kw)
assert t_un == t_sh, (t_un, t_sh)
print("threshold bit-identical")
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_matches_unsharded_8_devices():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-2500:])
    assert "SHARDED_OK" in out.stdout
