"""Training substrate: data determinism, checkpoint/restart fault tolerance,
straggler-drop gradient aggregation, optimizer math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig
from repro.data.pipeline import (DataConfig, HedgedPrefetcher, MarkovSource,
                                 UniformSource)
from repro.training import grad_agg
from repro.training.optimizer import OptConfig, make_optimizer
from repro.training.train_loop import Trainer, TrainerConfig


def tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
        pattern=("global",), tie_embeddings=True, recipe="tp",
        long_context_ok=False)


class TestData:
    def test_batch_at_deterministic(self):
        cfg = tiny_cfg()
        d = DataConfig(seq_len=16, batch_size=4, seed=3)
        s1 = UniformSource(cfg, d)
        s2 = UniformSource(cfg, d)
        np.testing.assert_array_equal(s1.batch_at(7)["tokens"],
                                      s2.batch_at(7)["tokens"])
        assert not np.array_equal(s1.batch_at(7)["tokens"],
                                  s1.batch_at(8)["tokens"])

    def test_shards_disjoint_streams(self):
        cfg = tiny_cfg()
        a = UniformSource(cfg, DataConfig(seq_len=16, batch_size=4, shard=0,
                                          num_shards=2))
        b = UniformSource(cfg, DataConfig(seq_len=16, batch_size=4, shard=1,
                                          num_shards=2))
        assert not np.array_equal(a.batch_at(0)["tokens"],
                                  b.batch_at(0)["tokens"])

    def test_markov_source_structured(self):
        cfg = tiny_cfg()
        src = MarkovSource(cfg, DataConfig(seq_len=64, batch_size=8))
        toks = src.batch_at(0)["tokens"]
        # every transition must be one of the `branching` successors
        succ = src.successors
        for b in range(toks.shape[0]):
            for t in range(1, toks.shape[1]):
                assert toks[b, t] in succ[toks[b, t - 1]]

    def test_hedged_prefetcher_identical_batches(self):
        cfg = tiny_cfg()
        src = UniformSource(cfg, DataConfig(seq_len=16, batch_size=4))
        pf = HedgedPrefetcher(src, k=3)
        got = pf.get(0)
        np.testing.assert_array_equal(got["tokens"],
                                      src.batch_at(0)["tokens"])


class TestCheckpoint:
    def test_roundtrip_bf16(self, tmp_path):
        tree = {"a": jnp.ones((4, 3), jnp.bfloat16) * 1.5,
                "b": [jnp.arange(5, dtype=jnp.float32),
                      jnp.int32(7)]}
        ckpt.save(tmp_path, 3, tree)
        out = ckpt.restore(tmp_path, 3, tree)
        assert out["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        np.testing.assert_array_equal(out["b"][0], tree["b"][0])

    def test_latest_and_cleanup(self, tmp_path):
        tree = {"x": jnp.zeros(2)}
        for s in (1, 2, 3, 4):
            ckpt.save(tmp_path, s, tree, keep_last=2)
        assert ckpt.latest_step(tmp_path) == 4
        assert not (tmp_path / "step_00000001").exists()
        assert (tmp_path / "step_00000003").exists()

    def test_async_checkpointer(self, tmp_path):
        c = ckpt.AsyncCheckpointer(tmp_path)
        c.save(5, {"x": jnp.ones(3)})
        c.wait()
        out = ckpt.restore(tmp_path, 5, {"x": jnp.zeros(3)})
        np.testing.assert_array_equal(out["x"], np.ones(3))


class TestFaultTolerance:
    def test_crash_resume_bitwise_identical(self, tmp_path):
        cfg = tiny_cfg()
        dcfg = DataConfig(seq_len=16, batch_size=4, seed=1)

        def make(tdir, fail_at=None):
            return Trainer(cfg, dcfg,
                           TrainerConfig(ckpt_dir=str(tdir), ckpt_every=3,
                                         async_ckpt=False, log_every=100,
                                         fail_at_step=fail_at),
                           log_fn=lambda *_: None)

        # uninterrupted run
        straight = make(tmp_path / "a").run(8, seed=0)

        # crash at step 5 (after the step-3 checkpoint), then resume
        crashed = make(tmp_path / "b", fail_at=5)
        with pytest.raises(RuntimeError, match="injected failure"):
            crashed.run(8, seed=0)
        resumed = make(tmp_path / "b").run(8, seed=0)

        flat_a = jax.tree_util.tree_leaves(straight["params"])
        flat_b = jax.tree_util.tree_leaves(resumed["params"])
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_loss_decreases_on_markov_data(self, tmp_path):
        cfg = tiny_cfg()
        dcfg = DataConfig(seq_len=32, batch_size=8, seed=2)
        tr = Trainer(cfg, dcfg,
                     TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=1000,
                                   log_every=5, async_ckpt=False),
                     log_fn=lambda *_: None)
        out = tr.run(40, seed=0)
        hist = out["history"]
        assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


class TestGradAgg:
    def test_masked_mean_renormalizes(self):
        g = {"w": jnp.stack([jnp.ones((2, 2)), 3 * jnp.ones((2, 2)),
                             100 * jnp.ones((2, 2))])}
        mask = jnp.asarray([1.0, 1.0, 0.0])  # third microbatch straggled
        out = grad_agg.masked_grad_mean(g, mask)
        np.testing.assert_allclose(np.asarray(out["w"]), 2.0)

    def test_first_m_mask(self):
        order = jnp.asarray([2, 0, 3, 1])
        np.testing.assert_array_equal(
            np.asarray(grad_agg.first_m_mask(order, 2)), [0, 1, 0, 1])

    def test_backup_microbatch_unbiased(self):
        # with all microbatches included, masked mean == plain mean
        key = jax.random.PRNGKey(0)
        g = {"w": jax.random.normal(key, (4, 3, 3))}
        full = grad_agg.masked_grad_mean(g, jnp.ones(4))
        np.testing.assert_allclose(np.asarray(full["w"]),
                                   np.asarray(jnp.mean(g["w"], axis=0)),
                                   rtol=1e-6)


class TestOptimizers:
    def test_adamw_first_step_is_lr_sized(self):
        params = {"w": jnp.zeros((4,), jnp.float32)}
        opt = make_optimizer("adamw", lr=0.1, weight_decay=0.0)
        state = opt.init(params)
        grads = {"w": jnp.ones((4,), jnp.float32)}
        new_p, _ = opt.update(params, grads, state, jnp.int32(0))
        # adam first step: update = lr * g/|g| = lr
        np.testing.assert_allclose(np.asarray(new_p["w"]), -0.1, rtol=1e-4)

    def test_adafactor_factored_states_shapes(self):
        params = {"w": jnp.zeros((8, 4), jnp.float32),
                  "b": jnp.zeros((4,), jnp.float32)}
        opt = make_optimizer("adafactor", lr=0.01)
        state = opt.init(params)
        assert state["v_row"]["w"].shape == (8,)
        assert state["v_col"]["w"].shape == (4,)
        assert state["v_col"]["b"].shape == (4,)

    def test_adafactor_reduces_loss_direction(self):
        params = {"w": jnp.asarray([10.0, -10.0])}
        opt = make_optimizer("adafactor", lr=0.1, weight_decay=0.0)
        state = opt.init(params)
        grads = {"w": jnp.asarray([1.0, -1.0])}
        new_p, _ = opt.update(params, grads, state, jnp.int32(0))
        assert float(new_p["w"][0]) < 10.0
        assert float(new_p["w"][1]) > -10.0

    def test_grad_clip(self):
        from repro.training.optimizer import clip_by_global_norm
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["w"]))))
        assert total == pytest.approx(1.0, rel=1e-4)
