"""Golden tests: the chunk-streamed simulator pinned against closed forms.

These use arrival counts (1M+) that the pre-sampled engine would need
tens of MB of per-seed inputs for — the chunked engine streams them with
peak memory set by ``chunk_size``. At these sample sizes the Monte-Carlo
error on the mean is well under 1%, so the tolerances below genuinely pin
the simulator to the analytics:

  * M/M/1 mean response 1/(1-rho) at several loads (k=1, exponential),
  * the paper's min-of-two-M/M/1 approximation 1/(2(1-2rho)) for k=2,
  * the M/M/1 response-time p99 (Exp(1-rho) quantile) via the Pallas
    histogram sketch,
  * Theorem 1: the exponential k=2 threshold at rho = 1/3, and
  * the CANCEL_ON_COMPLETE policy (scenario API) against the
    M/M/1-with-cancellation analytic bounds: mean response sandwiched
    in (1/k, 1/(1-rho)) at every load — including loads where
    replicate-all is unstable — and -> E[min] = 1/k as rho -> 0.
"""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core import analytic, distributions as dists, queueing, threshold
from repro.core.scenario import CANCEL_ON_COMPLETE, Scenario

CHUNK = 8_192
N_ARRIVALS = 1_000_000
RHOS_K1 = (0.2, 0.5, 0.7)
RHOS_K2 = (0.1, 0.25)
RHOS_CANCEL = (0.02, 0.25, 0.6)


@pytest.fixture(scope="module")
def k1_summaries():
    cfg = queueing.SimConfig(n_servers=20, n_arrivals=N_ARRIVALS)
    return queueing.sweep(jax.random.PRNGKey(100), dists.exponential(),
                          jnp.asarray(RHOS_K1), cfg, ks=(1,), n_seeds=1,
                          percentiles=(99.0,), chunk_size=CHUNK)


@pytest.fixture(scope="module")
def k2_means():
    cfg = queueing.SimConfig(n_servers=20, n_arrivals=N_ARRIVALS)
    out = queueing.sweep(jax.random.PRNGKey(101), dists.exponential(),
                         jnp.asarray(RHOS_K2), cfg, ks=(2,), n_seeds=1,
                         percentiles=(), chunk_size=CHUNK)
    return out["mean"][0, :, 0]


class TestMM1Golden:
    @pytest.mark.parametrize("i,rho", enumerate(RHOS_K1))
    def test_mean_matches_closed_form(self, k1_summaries, i, rho):
        sim = float(k1_summaries["mean"][0, i, 0])
        expect = float(analytic.mm1_mean(rho))  # 1 / (1 - rho)
        assert sim == pytest.approx(expect, rel=0.02)

    @pytest.mark.parametrize("i,rho", enumerate(RHOS_K1))
    def test_p99_matches_exponential_response(self, k1_summaries, i, rho):
        # M/M/1 response ~ Exp(1 - rho) => p99 = ln(100) / (1 - rho);
        # read through the histogram sketch (one log-bin ~ 0.9% rel).
        sim = float(k1_summaries[f"p{99.0:g}"][0, i, 0])
        expect = math.log(100.0) / (1.0 - rho)
        assert sim == pytest.approx(expect, rel=0.05)


class TestReplicatedGolden:
    @pytest.mark.parametrize("i,rho", enumerate(RHOS_K2))
    def test_k2_mean_matches_min_of_two_mm1(self, k2_means, i, rho):
        # each copy ~ M/M/1 at load 2*rho; min of two independent
        # Exp(1-2rho) samples has mean 1/(2(1-2rho)). The independence
        # approximation holds to a few % at N=20 servers.
        sim = float(k2_means[i])
        expect = float(analytic.mm1_replicated_mean(rho, 2))
        assert sim == pytest.approx(expect, rel=0.05)


@pytest.fixture(scope="module")
def cancel_means():
    cfg = queueing.SimConfig(n_servers=20, n_arrivals=N_ARRIVALS)
    scn = Scenario(dists=dists.exponential(), policy=CANCEL_ON_COMPLETE,
                   ks=(2,))
    out = queueing.run(jax.random.PRNGKey(103), scn,
                       jnp.asarray(RHOS_CANCEL), cfg, n_seeds=1,
                       percentiles=(), chunk_size=CHUNK)
    return out["mean"][0, :, 0]


class TestCancellationGolden:
    """CANCEL_ON_COMPLETE (k=2, exponential) vs the M/M/1-with-cancellation
    analytic bounds (see ``analytic.mm1_cancel_bounds``)."""

    def test_low_load_approaches_min_of_two(self, cancel_means):
        # rho -> 0: both copies start immediately, the loser cancels at
        # the winner's finish => response -> min of two Exp(1), mean 1/2.
        assert float(cancel_means[0]) == pytest.approx(0.5, rel=0.03)

    @pytest.mark.parametrize("i,rho", enumerate(RHOS_CANCEL))
    def test_within_analytic_bounds(self, cancel_means, i, rho):
        lo, hi = (float(b) for b in analytic.mm1_cancel_bounds(rho, 2))
        sim = float(cancel_means[i])
        assert lo < sim < hi, (rho, lo, sim, hi)

    def test_stable_where_replicate_all_is_not(self, cancel_means):
        # rho = 0.6 > 1/2: replicate-all doubles utilization past 1 and
        # diverges; cancellation keeps the system stable and BETTER than
        # the unreplicated M/M/1 (redundancy never hurts for exp service).
        sim = float(cancel_means[-1])
        assert sim < float(analytic.mm1_mean(0.6))


class TestTheorem1Golden:
    def test_exponential_threshold_is_one_third(self):
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=300_000)
        est = threshold.threshold_bisect(
            jax.random.PRNGKey(102), dists.exponential(), cfg, iters=8,
            n_seeds=2, chunk_size=CHUNK)
        assert est == pytest.approx(analytic.THRESHOLD_EXPONENTIAL,
                                    abs=0.02)
