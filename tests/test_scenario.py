"""Scenario API tests: spec normalization, the paper-default bit-identity
contract (``run(Scenario.paper_default(...))`` == legacy ``sweep``), the
physics of the new replication policies and the server-dependent service
model, mixed-grid isolation, and the scenario-aware threshold estimators.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import analytic, distributions as dists, queueing, threshold
from repro.core.scenario import (CANCEL_ON_COMPLETE, IID, REPLICATE_ALL,
                                 REPLICATE_TO_IDLE, SERVER_DEPENDENT,
                                 Policy, Scenario, ServiceModel, Variant,
                                 combine, parse_policy, parse_service_model,
                                 provenance)

CFG = queueing.SimConfig(n_servers=10, n_arrivals=10_000)
RHOS = jnp.asarray([0.1, 0.3])


class TestScenarioSpec:
    def test_bare_dist_normalized_to_tuple(self):
        scn = Scenario(dists=dists.exponential())
        assert scn.dists == (dists.exponential(),)
        assert scn.ks == (1, 2)

    def test_paper_default(self):
        scn = Scenario.paper_default(ks=(1, 3))
        assert scn.dists == (dists.exponential(),)
        assert scn.policy is Policy.REPLICATE_ALL
        assert scn.service_model is ServiceModel.IID
        assert scn.ks == (1, 3)
        assert scn.k_max == 3

    def test_validation(self):
        d = dists.exponential()
        with pytest.raises(ValueError):
            Scenario(dists=())
        with pytest.raises(ValueError):
            Scenario(dists=d, ks=())
        with pytest.raises(ValueError):
            Scenario(dists=d, ks=(0,))
        with pytest.raises(ValueError):
            Scenario(dists=d, mix=1.5)
        with pytest.raises(ValueError):
            Scenario(dists=d, warmup_frac=1.0)

    def test_static_pytree_and_hashable(self):
        scn = Scenario.paper_default()
        assert jax.tree_util.tree_leaves(scn) == []  # static: no leaves
        assert hash(scn) == hash(Scenario.paper_default())
        assert scn == Scenario.paper_default()

    def test_variants(self):
        scn = Scenario(dists=dists.exponential(), policy=CANCEL_ON_COMPLETE,
                       service_model=SERVER_DEPENDENT, mix=0.7, ks=(1, 2),
                       client_overhead=0.25)
        v1, v2 = scn.variants()
        assert (v1.k, v2.k) == (1, 2)
        for v in (v1, v2):
            assert v.policy is Policy.CANCEL_ON_COMPLETE
            assert v.service_model is ServiceModel.SERVER_DEPENDENT
            assert v.mix == 0.7 and v.overhead == 0.25
            assert v.needs_shared_draw

    def test_combine_concatenates_variants(self):
        d = dists.exponential()
        scns = (Scenario.paper_default(d, ks=(1, 2)),
                Scenario(dists=d, policy=CANCEL_ON_COMPLETE, ks=(2,)))
        dlist, warmup, variants = combine(scns)
        assert dlist == (d,) and warmup == 0.1
        assert [v.k for v in variants] == [1, 2, 2]
        assert [v.policy for v in variants] == [
            REPLICATE_ALL, REPLICATE_ALL, CANCEL_ON_COMPLETE]

    def test_combine_rejects_mismatched_grids(self):
        d = dists.exponential()
        # Differing dists no longer reject — they form a heterogeneous
        # union (per-cell dist_id) — but each scenario of such a grid
        # must carry exactly ONE dist ("its system").
        with pytest.raises(ValueError, match="exactly one dist"):
            combine((Scenario(dists=(d, dists.pareto(2.5))),
                     Scenario(dists=dists.pareto(2.5))))
        with pytest.raises(ValueError, match="warmup"):
            combine((Scenario(dists=d),
                     Scenario(dists=d, warmup_frac=0.2)))

    def test_parse_helpers(self):
        assert parse_policy("cancel_on_complete") is CANCEL_ON_COMPLETE
        assert parse_policy(2) is REPLICATE_TO_IDLE
        assert parse_service_model("server_dependent") is SERVER_DEPENDENT
        assert parse_service_model("iid") is IID

    def test_provenance_is_json_friendly(self):
        import json
        scn = Scenario(dists=dists.exponential(),
                       service_model=SERVER_DEPENDENT, mix=0.5)
        p = provenance(scn)
        assert p["policy"] == "REPLICATE_ALL"
        assert p["service_model"] == "SERVER_DEPENDENT"
        assert p["mix"] == 0.5
        json.dumps(provenance((scn, Scenario.paper_default())))


class TestPaperDefaultBitIdentity:
    """run(Scenario.paper_default(...)) must be bit-identical to the legacy
    sweep/sweep_dists shims (which are themselves pinned by the golden /
    analytic / shard suites)."""

    def test_run_matches_sweep_unchunked_and_chunked(self):
        key = jax.random.PRNGKey(0)
        scn = Scenario.paper_default(dists.pareto(2.5), ks=(1, 2))
        for chunk in (None, 1_700):
            a = queueing.run(key, scn, RHOS, CFG, n_seeds=2,
                             chunk_size=chunk)
            b = queueing.sweep(key, dists.pareto(2.5), RHOS, CFG, ks=(1, 2),
                               n_seeds=2, chunk_size=chunk)
            for f in ("mean", "p50", "p99"):
                assert jnp.array_equal(a[f], b[f]), (f, chunk)

    def test_run_matches_sweep_dists(self):
        key = jax.random.PRNGKey(1)
        ds = (dists.exponential(), dists.two_point(0.9))
        a = queueing.run(key, Scenario.paper_default(ds), RHOS, CFG,
                         n_seeds=2, percentiles=(), chunk_size=2_500)
        b = queueing.sweep_dists(key, ds, RHOS, CFG, ks=(1, 2), n_seeds=2,
                                 percentiles=(), chunk_size=2_500)
        assert a["mean"].shape == (2, 2, 2, 2)
        assert jnp.array_equal(a["mean"], b["mean"])

    def test_single_dist_sweep_dists_keeps_leading_axis(self):
        key = jax.random.PRNGKey(2)
        out = queueing.sweep_dists(key, [dists.exponential()], RHOS, CFG,
                                   n_seeds=1, percentiles=())
        assert out["mean"].shape == (1, 1, 2, 2)

    def test_mixed_grid_leaves_paper_cells_untouched(self):
        # adding cancellation / server-dependent variants to a grid must
        # not perturb the paper cells by a single bit (CRN across
        # policies: all variants consume the same draws).
        key = jax.random.PRNGKey(3)
        d = dists.exponential()
        scns = (Scenario.paper_default(d, ks=(1, 2)),
                Scenario(dists=d, policy=CANCEL_ON_COMPLETE, ks=(2,)),
                Scenario(dists=d, service_model=SERVER_DEPENDENT, mix=0.9,
                         ks=(2,)))
        mixed = queueing.run(key, scns, RHOS, CFG, n_seeds=2,
                             chunk_size=1_700)
        pure = queueing.run(key, scns[0], RHOS, CFG, n_seeds=2,
                            chunk_size=1_700)
        assert mixed["mean"].shape == (2, 2, 4)
        for f in ("mean", "p50", "p99"):
            assert jnp.array_equal(mixed[f][:, :, :2], pure[f]), f

    def test_replication_gain_matches_run(self):
        key = jax.random.PRNGKey(4)
        g_shim = queueing.replication_gain(key, dists.exponential(), RHOS,
                                           CFG, n_seeds=2)
        out = queueing.run(key, Scenario.paper_default(dists.exponential()),
                           RHOS, CFG, n_seeds=2, percentiles=())
        m = out["mean"]
        g_run = jnp.mean(m[:, :, 0] - m[:, :, 1], axis=0)
        assert jnp.array_equal(g_shim, g_run)


class TestPolicyPhysics:
    CFG = queueing.SimConfig(n_servers=20, n_arrivals=60_000)

    @staticmethod
    def _means(key, rhos, *scns, n_seeds=2):
        out = queueing.run(key, scns, jnp.asarray(rhos), TestPolicyPhysics.CFG,
                           n_seeds=n_seeds, percentiles=(), chunk_size=8_192)
        return jnp.mean(out["mean"], axis=0)  # (B, V)

    def test_cancellation_dominates_replicate_all(self):
        # CRN-paired: losers vacating queue slots can only reduce
        # congestion, so at every load the cancel mean is below the
        # replicate-all mean (strictly, once queueing matters).
        key = jax.random.PRNGKey(10)
        d = dists.exponential()
        m = self._means(key, [0.25, 0.45],
                        Scenario.paper_default(d, ks=(2,)),
                        Scenario(dists=d, policy=CANCEL_ON_COMPLETE,
                                 ks=(2,)))
        assert float(m[0, 1]) < float(m[0, 0])
        assert float(m[1, 1]) < float(m[1, 0])

    def test_replicate_to_idle_between_k1_and_cancel(self):
        # At high load idle-only replication sends few copies: it avoids
        # replicate-all's overload (below it) but cannot beat paired
        # cancellation (above it).
        key = jax.random.PRNGKey(11)
        d = dists.exponential()
        m = self._means(key, [0.45],
                        Scenario.paper_default(d, ks=(2,)),
                        Scenario(dists=d, policy=REPLICATE_TO_IDLE, ks=(2,)),
                        Scenario(dists=d, policy=CANCEL_ON_COMPLETE,
                                 ks=(2,)))
        m_all, m_idle, m_cancel = (float(x) for x in m[0])
        assert m_cancel < m_idle < m_all

    def test_k1_immune_to_policy(self):
        # with a single copy there is nothing to cancel or withhold:
        # every policy's k=1 column is bit-identical.
        key = jax.random.PRNGKey(12)
        d = dists.pareto(2.5)
        out = queueing.run(
            key, tuple(Scenario(dists=d, policy=p, ks=(1,))
                       for p in Policy),
            RHOS, CFG, n_seeds=1, percentiles=())
        m = out["mean"]  # (1, B, 3)
        assert jnp.array_equal(m[:, :, 0], m[:, :, 1])
        assert jnp.array_equal(m[:, :, 0], m[:, :, 2])

    def test_raw_simulate_cancellation(self):
        # the raw-response path shares _step_cell: cancellation improves
        # the mean there too, pathwise CRN-paired with replicate-all.
        key = jax.random.PRNGKey(13)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=20_000)
        d = dists.exponential()
        scn = Scenario(dists=d, policy=CANCEL_ON_COMPLETE)
        r_all = queueing.simulate(key, d, jnp.float32(0.4), cfg, k=2)
        r_can = queueing.simulate(key, d, jnp.float32(0.4), cfg, k=2,
                                  scenario=scn)
        assert float(jnp.mean(r_can)) < float(jnp.mean(r_all))
        # cancellation can only help: no response gets slower
        assert bool(jnp.all(r_can <= r_all + 1e-5))


class TestServerDependentModel:
    CFG = queueing.SimConfig(n_servers=20, n_arrivals=100_000)

    def test_mix_zero_is_bitwise_iid(self):
        # svc = 0 * shared + 1 * draw + masked select => exactly the IID
        # path, even though the shared column is sampled.
        key = jax.random.PRNGKey(20)
        d = dists.exponential()
        a = queueing.run(key, Scenario(dists=d, service_model=IID, mix=0.0),
                         RHOS, CFG, n_seeds=1, percentiles=())
        b = queueing.run(key, Scenario(dists=d,
                                       service_model=SERVER_DEPENDENT,
                                       mix=0.0),
                         RHOS, CFG, n_seeds=1, percentiles=())
        assert jnp.array_equal(a["mean"], b["mean"])

    def test_shah_crossover_gain_monotone_in_mix(self):
        # Shah et al.'s headline: at a load below the paper's 1/3
        # threshold, replication helps under IID service but HURTS once
        # service is server-dependent — the paired gain decreases in the
        # request-component mix and flips sign.
        key = jax.random.PRNGKey(21)
        d = dists.exponential()
        scns = tuple(
            Scenario(dists=d, service_model=SERVER_DEPENDENT, mix=mx,
                     ks=(1, 2)) if mx else
            Scenario.paper_default(d, ks=(1, 2))
            for mx in (0.0, 0.5, 1.0))
        out = queueing.run(key, scns, jnp.asarray([0.3]), self.CFG,
                           n_seeds=3, percentiles=(), chunk_size=8_192)
        m = jnp.mean(out["mean"], axis=0)[0]  # (6,)
        g_iid, g_mid, g_dep = (float(m[2 * j] - m[2 * j + 1])
                               for j in range(3))
        assert g_iid > g_mid > g_dep
        assert g_iid > 0.0 > g_dep

    def test_shared_component_crn_across_entry_points_and_layouts(self):
        # the shared request component is drawn from a FIXED fold_in
        # index, so (a) run's variant j matches the raw simulate_grid
        # path bit-for-bit (the module CRN contract) and (b) the same
        # scenario embedded in grids with different k_max draws the same
        # shared component.
        key = jax.random.PRNGKey(23)
        cfg = queueing.SimConfig(n_servers=10, n_arrivals=4_000)
        d = dists.exponential()
        scn = Scenario(dists=d, service_model=SERVER_DEPENDENT, mix=1.0,
                       ks=(1, 2))
        out = queueing.run(key, scn, RHOS, cfg, n_seeds=1, percentiles=())
        keys = jax.random.split(key, 1)
        for j, k in enumerate(scn.ks):
            r = queueing.simulate_grid(keys[0], d, RHOS, cfg, k=k,
                                       scenario=scn)
            warm = queueing._warm(r, cfg)
            # streaming Kahan mean vs jnp.mean: same sample path, float
            # tolerance only
            assert jnp.allclose(out["mean"][0, :, j],
                                jnp.mean(warm, axis=-1), rtol=1e-5), k
        out3 = queueing.run(
            key, dataclasses.replace(scn, ks=(1, 2, 3)), RHOS, cfg,
            n_seeds=1, percentiles=())
        assert jnp.array_equal(out["mean"], out3["mean"][:, :, :2])

    def test_simulate_grid_accepts_scenario(self):
        key = jax.random.PRNGKey(22)
        cfg = queueing.SimConfig(n_servers=10, n_arrivals=2_000)
        d = dists.exponential()
        scn = Scenario(dists=d, service_model=SERVER_DEPENDENT, mix=1.0)
        r = queueing.simulate_grid(key, d, RHOS, cfg, k=2, scenario=scn)
        assert r.shape == (2, 2_000)
        assert bool(jnp.all(r > 0.0))


class TestScenarioThresholds:
    CFG = queueing.SimConfig(n_servers=20, n_arrivals=60_000)

    def test_bare_dist_unchanged(self):
        # the dist form stays bit-identical to the pre-scenario estimator
        # (pinned at 1/3 by test_queueing / the golden suite).
        key = jax.random.PRNGKey(30)
        t = threshold.threshold_bisect(key, dists.exponential(), self.CFG,
                                       iters=6, n_seeds=2)
        assert t == pytest.approx(analytic.THRESHOLD_EXPONENTIAL, abs=0.04)

    def test_cancellation_raises_threshold_past_bracket(self):
        # with cancellation, k=2 helps exponential service at EVERY load
        # below 1/2: the bisection bracket never sees a sign change and
        # reports hi.
        key = jax.random.PRNGKey(31)
        scn = Scenario(dists=dists.exponential(),
                       policy=CANCEL_ON_COMPLETE)
        t = threshold.threshold_bisect(key, scn, self.CFG, iters=5,
                                       n_seeds=2, chunk_size=8_192)
        assert t == pytest.approx(0.499)

    def test_server_dependence_lowers_threshold(self):
        key = jax.random.PRNGKey(32)
        scn = Scenario(dists=dists.exponential(),
                       service_model=SERVER_DEPENDENT, mix=1.0)
        t_dep = threshold.threshold_bisect(key, scn, self.CFG, iters=6,
                                           n_seeds=3, chunk_size=8_192)
        assert t_dep < analytic.THRESHOLD_EXPONENTIAL - 0.015

    def test_single_dist_estimators_reject_multi_dist_scenario(self):
        # a multi-dist scenario's summaries carry a leading dist axis the
        # single-threshold reductions cannot interpret — loud error, not
        # silent garbage.
        scn = Scenario(dists=(dists.exponential(), dists.pareto(2.5)))
        key = jax.random.PRNGKey(35)
        with pytest.raises(ValueError, match="threshold_grid_batch"):
            threshold.threshold_bisect(key, scn, self.CFG)
        with pytest.raises(ValueError, match="threshold_grid_batch"):
            threshold.scenario_gain(key, scn, RHOS, self.CFG)
        with pytest.raises(ValueError, match="threshold_grid_batch"):
            threshold.threshold_grid(key, scn, self.CFG)

    def test_grid_batch_accepts_scenario(self):
        key = jax.random.PRNGKey(33)
        scn = Scenario(dists=(dists.exponential(), dists.pareto(2.5)))
        ts = threshold.threshold_grid_batch(key, scn, self.CFG, n_seeds=2)
        assert len(ts) == 2
        for t in ts:
            assert 0.24 <= t <= 0.5

    def test_scenario_gain_matches_replication_gain(self):
        key = jax.random.PRNGKey(34)
        g_new = threshold.scenario_gain(key, dists.exponential(), RHOS,
                                        CFG, n_seeds=2)
        g_old = queueing.replication_gain(key, dists.exponential(), RHOS,
                                          CFG, n_seeds=2)
        assert jnp.array_equal(g_new, g_old)


class TestVariantPlumbing:
    def test_overhead_only_charged_when_replicated(self):
        assert queueing._overhead_when_replicated(0.25, 1) == 0.0
        assert queueing._overhead_when_replicated(0.25, 2) == 0.25

    def test_scenario_overhead_matches_cfg_overhead(self):
        # Scenario.client_overhead must reproduce the legacy SimConfig
        # knob exactly (the Fig 4 path).
        key = jax.random.PRNGKey(40)
        cfg_pen = dataclasses.replace(CFG, client_overhead=0.25)
        a = queueing.sweep(key, dists.exponential(), RHOS, cfg_pen,
                           ks=(1, 2), n_seeds=1, percentiles=())
        b = queueing.run(key, Scenario.paper_default(dists.exponential(),
                                                     client_overhead=0.25),
                         RHOS, CFG, n_seeds=1, percentiles=())
        assert jnp.array_equal(a["mean"], b["mean"])

    def test_legacy_ks_tuple_still_accepted_by_plan_params(self):
        from repro.core import cellplan
        plan = cellplan.make_cell_plan(1, 2, 2)
        cfg = dataclasses.replace(CFG, client_overhead=0.5)
        legacy = queueing._plan_cell_params(plan, RHOS, cfg, (1, 2))
        via_variants = queueing._plan_cell_params(
            plan, RHOS, cfg, (Variant(k=1, overhead=0.5),
                              Variant(k=2, overhead=0.5)))
        # 8 per-cell params: rates, k_mask, overhead, mix, p_slow,
        # slow_factor, p_fail, delay — identical either way
        assert len(legacy) == len(via_variants) == 8
        for a, b in zip(legacy, via_variants):
            assert jnp.array_equal(a, b)
