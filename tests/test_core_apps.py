"""Tests for analytic results, hedging runtime, storage + DNS models."""
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import analytic, dns, hedging, queueing, storage_sim, threshold


class TestAnalytic:
    def test_theorem1_closed_form(self):
        assert analytic.exponential_threshold() == pytest.approx(1 / 3)

    def test_overhead_shrinks_closed_form_threshold(self):
        t = [analytic.exponential_threshold(overhead=c)
             for c in (0.0, 0.1, 0.3, 0.6)]
        assert all(a > b for a, b in zip(t, t[1:]))
        # overhead >= mean service (=1): never helps
        assert analytic.exponential_threshold(overhead=1.0) == 0.0

    def test_tcp_mean_saving_at_least_25ms(self):
        m = analytic.TCPModel()
        assert analytic.handshake_mean_saving(m) >= 0.0246

    def test_tcp_monte_carlo_matches_first_order(self):
        m = analytic.TCPModel()
        key = jax.random.PRNGKey(0)
        t1 = analytic.handshake_times(key, m, 400_000, duplicated=False)
        t2 = analytic.handshake_times(key, m, 400_000, duplicated=True)
        saving = float(jnp.mean(t1) - jnp.mean(t2))
        assert saving == pytest.approx(analytic.handshake_mean_saving(m),
                                       rel=0.25)

    def test_tcp_tail_saving(self):
        # §3.1 claims an >=880 ms tail improvement. Under the stated model
        # P(>=1 timeout | duplicated) = 1-(1-0.0007)^3 ~= 0.21% which is
        # still > 0.1%, so the gap materializes at the percentile where
        # duplication crosses the timeout probability (p99.5-p99.8), not at
        # p99.9 exactly. We assert the paper's magnitude at p99.5 and that
        # the duplicated tail is never worse. (Documented in EXPERIMENTS.md.)
        m = analytic.TCPModel()
        key = jax.random.PRNGKey(1)
        t1 = analytic.handshake_times(key, m, 400_000, duplicated=False)
        t2 = analytic.handshake_times(key, m, 400_000, duplicated=True)
        gap995 = float(jnp.percentile(t1, 99.5) - jnp.percentile(t2, 99.5))
        assert gap995 > 0.88  # seconds — the paper's ">= 880 ms"
        for p in (99.0, 99.5, 99.9, 99.99):
            assert float(jnp.percentile(t2, p)) <= \
                float(jnp.percentile(t1, p)) + 1e-3


class TestHedging:
    def test_first_completion_wins(self):
        def slow():
            time.sleep(0.2); return "slow"

        def fast():
            time.sleep(0.01); return "fast"

        res = hedging.hedged_call([slow, fast], k=2)
        assert res.value == "fast"
        assert res.winner == 1
        assert res.latency < 0.15

    def test_k1_no_hedge(self):
        res = hedging.hedged_call([lambda: 7, lambda: 8], k=1)
        assert res.value == 7 and res.k == 1

    def test_failure_masked_by_redundancy(self):
        def boom():
            raise RuntimeError("replica died")

        def ok():
            time.sleep(0.02); return 42

        res = hedging.hedged_call([boom, ok], k=2)
        assert res.value == 42

    def test_all_fail_raises(self):
        def boom():
            raise RuntimeError("down")

        with pytest.raises(RuntimeError):
            hedging.hedged_call([boom, boom], k=2)

    def test_policy_threshold(self):
        p = hedging.HedgePolicy(max_k=2, threshold=0.3)
        assert p.k_for(0.1) == 2
        assert p.k_for(0.35) == 1

    def test_policy_max_k4_steps_down_with_load(self):
        # k_for must pick the LARGEST k whose k-fold load stays under the
        # threshold — the old loop tested a k-independent condition, so
        # any max_k > 2 collapsed straight to 1 instead of stepping
        # through the intermediate ks.
        p = hedging.HedgePolicy(max_k=4, threshold=0.5)
        assert p.k_for(0.1) == 4    # 4 * 0.1 < 0.5
        assert p.k_for(0.13) == 3   # 4 * 0.13 >= 0.5 > 3 * 0.13
        assert p.k_for(0.2) == 2
        assert p.k_for(0.3) == 1

    def test_policy_overhead_cutoff(self):
        p = hedging.HedgePolicy(max_k=2, threshold=0.3,
                                client_overhead_frac=0.9)
        assert p.k_for(0.01) == 1

    def test_load_meter_ewma(self):
        m = hedging.LoadMeter(alpha=0.5, init=0.0)
        m.update(1.0)
        assert m.utilization == pytest.approx(0.5)
        m.update(1.0)
        assert m.utilization == pytest.approx(0.75)


class TestStorageModel:
    def test_base_config_threshold_near_paper(self):
        # Paper §2.2: threshold ~30% for the 4KB disk-backed store.
        dist, _, ovh = storage_sim.service_dist(storage_sim.StorageConfig())
        assert ovh < 0.02  # client overhead ~1% of mean service
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=60_000,
                                 client_overhead=ovh)
        key = jax.random.PRNGKey(2)
        t = threshold.threshold_grid(key, dist, cfg, n_seeds=2)
        assert 0.25 <= t <= 0.45

    def test_unit_mean(self):
        dist, scale, _ = storage_sim.service_dist(storage_sim.StorageConfig())
        s = dist.sample(jax.random.PRNGKey(3), (200_000,))
        assert float(jnp.mean(s)) == pytest.approx(1.0, rel=0.05)
        assert scale == pytest.approx(
            storage_sim.mean_service_ms(storage_sim.StorageConfig()), rel=1e-6)

    @pytest.mark.parametrize("cv", [0.5, 1.0, 1.5, 3.0])
    def test_seek_nonnegative_with_pinned_moments(self, cv):
        # mean_file_kb=0 + no cache: the sampled service IS the seek.
        # The old shifted-exponential seek went negative whenever
        # cv > 1 (fig9's EC2 config uses 1.5); the gamma model must stay
        # non-negative at ANY cv while pinning mean and CV.
        cfg = storage_sim.StorageConfig(mean_file_kb=0.0,
                                        cache_disk_ratio=0.0, seek_cv=cv)
        s = storage_sim._sample_ms(cfg, jax.random.PRNGKey(11), (400_000,))
        assert float(jnp.min(s)) >= 0.0
        mean = float(jnp.mean(s))
        assert mean == pytest.approx(cfg.seek_ms, rel=0.05)
        assert float(jnp.std(s)) / mean == pytest.approx(cv, rel=0.05)

    def test_large_files_kill_replication(self):
        # Fig 10: 400 KB files => client overhead is a large fraction of
        # service time => replication stops helping at moderate load.
        cfg400 = storage_sim.StorageConfig(mean_file_kb=400.0)
        dist, _, ovh = storage_sim.service_dist(cfg400)
        assert ovh > 0.2
        sim = queueing.SimConfig(n_servers=20, n_arrivals=60_000,
                                 client_overhead=ovh)
        key = jax.random.PRNGKey(4)
        t400 = threshold.threshold_grid(key, dist, sim, n_seeds=2)
        base_dist, _, base_ovh = storage_sim.service_dist(
            storage_sim.StorageConfig())
        t4 = threshold.threshold_grid(
            key, base_dist,
            queueing.SimConfig(n_servers=20, n_arrivals=60_000,
                               client_overhead=base_ovh), n_seeds=2)
        assert t400 < t4

    def test_memcached_replication_hurts_at_10pct(self):
        # Fig 12: in-memory store, overhead ~9% of 0.18ms service =>
        # replication worsens mean latency at >= 10% load.
        dist, _, ovh = storage_sim.service_dist(storage_sim.MEMCACHED)
        assert ovh == pytest.approx(0.09, abs=0.03)
        sim = queueing.SimConfig(n_servers=20, n_arrivals=60_000,
                                 client_overhead=ovh)
        key = jax.random.PRNGKey(5)
        g = queueing.replication_gain(key, dist, jnp.asarray([0.1, 0.3]), sim,
                                      n_seeds=2)
        # low-variance near-deterministic service + overhead: tiny/no gain
        assert float(g[1]) < 0.0


class TestDNS:
    def test_replication_reduces_tail(self):
        pop = dns.DNSPopulation()
        key = jax.random.PRNGKey(6)
        ranking = dns.rank_servers(key, pop)
        lat = dns.sample_latencies(jax.random.PRNGKey(7), pop, 200_000)
        r1 = dns.replicated_response(lat, ranking, 1)
        r10 = dns.replicated_response(lat, ranking, 10)
        f1 = float(jnp.mean(r1 > 500.0))
        f10 = float(jnp.mean(r10 > 500.0))
        assert f10 < f1 / 3.0  # paper: 6.5x reduction
        assert float(jnp.mean(r10)) < float(jnp.mean(r1))

    def test_more_servers_monotone(self):
        pop = dns.DNSPopulation()
        key = jax.random.PRNGKey(8)
        ranking = dns.rank_servers(key, pop)
        lat = dns.sample_latencies(jax.random.PRNGKey(9), pop, 50_000)
        means = [float(jnp.mean(dns.replicated_response(lat, ranking, k)))
                 for k in range(1, 11)]
        assert all(a >= b for a, b in zip(means, means[1:]))

    def test_marginal_savings_positive_and_diminishing(self):
        pop = dns.DNSPopulation()
        key = jax.random.PRNGKey(10)
        ranking = dns.rank_servers(key, pop)
        lat = dns.sample_latencies(jax.random.PRNGKey(11), pop, 200_000)
        means = jnp.asarray(
            [float(jnp.mean(dns.replicated_response(lat, ranking, k)))
             for k in range(1, 11)])
        marg = dns.marginal_savings_ms_per_kb(means, pop)
        assert float(marg[0]) > analytic.BENEFIT_THRESHOLD_MS_PER_KB
        assert float(marg[0]) > float(marg[-1])
