"""Every benchmark module's entry point imports and runs at tiny sizes
(the same ``smoke=True`` path CI exercises via ``benchmarks/run.py
--smoke``), so a refactor of the engine API cannot silently strand a
figure reproduction."""
import importlib
import sys
from pathlib import Path

import pytest

# benchmarks/ is a repo-root package (not under src/); make it importable
# the same way benchmarks/run.py is invoked from the repo root.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# Fast modules run in full; the heavy simulators get a trimmed marker so a
# plain tier-1 run still covers every entry point without minutes of wall
# clock dominated by two modules.
MODULES = [
    "fig1_queueing",
    "fig2_threshold",
    "fig3_random",
    "fig4_overhead",
    "fig5_diskdb",
    "fig12_memcached",
    "fig15_dns",
    "tab_tcp",
    "serving_hedge",
    "roofline",
    "sweep_engine",
    "fig_policy_space",
    "fig14_network",
    "fig_fault_masking",
    "fig_cross_system",
]


@pytest.mark.parametrize("name", MODULES)
def test_benchmark_entry_runs_smoke(name):
    mod = importlib.import_module(f"benchmarks.{name}")
    rows = mod.run(smoke=True)
    assert isinstance(rows, list) and rows, name
    for row in rows:
        # rows may carry mesh-shape (4th) and scenario (5th) provenance
        label, us, derived = row[:3]
        assert isinstance(label, str) and label
        assert float(us) >= 0.0
        assert isinstance(derived, str)
        assert "ERROR" not in label, (label, derived)


def test_sweep_engine_sharded_rows_on_single_device_mesh():
    """The mesh-aware path emits sharded rows with mesh provenance even
    on a 1-device mesh (CI's multi-device job exercises 8)."""
    import benchmarks.sweep_engine as se
    from benchmarks.common import row_provenance
    from repro.launch.mesh import make_sweep_mesh
    rows = se.run(smoke=True, mesh=make_sweep_mesh(1))
    sharded = [r for r in rows if "sharded" in r[0]]
    assert sharded, [r[0] for r in rows]
    for row in sharded:
        mesh, _, _, _ = row_provenance(row)
        assert mesh == [1], row
        assert "bit_identical=True" in row[2], row


def test_fig_policy_space_scenario_provenance():
    """Every scenario row of the policy-space figure carries its policy /
    service-model / mix provenance (recorded per JSON row by run.py);
    the crossover summary row reports the Shah et al. sign flip."""
    import benchmarks.fig_policy_space as fps
    from benchmarks.common import row_provenance
    rows = fps.run(smoke=True)
    by_name = {r[0]: r for r in rows}
    _, scn, kernel, _ = row_provenance(by_name["fig_policy_space/iid"])
    assert scn["policy"] == "REPLICATE_ALL" and scn["mix"] == 0.0
    assert kernel in ("on", "off", "interpret")  # resolved, never "auto"
    _, scn, _, _ = row_provenance(by_name["fig_policy_space/server_dep_mix1"])
    assert scn["service_model"] == "SERVER_DEPENDENT" and scn["mix"] == 1.0
    _, scn, _, _ = row_provenance(by_name["fig_policy_space/cancel"])
    assert scn["policy"] == "CANCEL_ON_COMPLETE"
    assert "crossover=" in by_name["fig_policy_space/crossover"][2]


def test_sweep_engine_kernel_row():
    """The kernel on-vs-off row always exists, records the RESOLVED mode
    it measured, and holds a measured speedup + bit-identity flag in the
    derived field (the acceptance provenance for the fused kernel)."""
    import benchmarks.sweep_engine as se
    from benchmarks.common import row_provenance
    rows = se.run(smoke=True)
    by_name = {r[0]: r for r in rows}
    row = by_name["sweep_engine/kernel_on_vs_off"]
    _, _, kernel, _ = row_provenance(row)
    assert kernel in ("on", "interpret")  # never the scan fallback
    assert "bit_identical=True" in row[2], row
    assert "speedup=" in row[2] and "scan_s=" in row[2], row


def test_fig_fault_masking_chaos_acceptance():
    """The chaos demo's acceptance booleans (25% of replicas crashed
    mid-trace: hedged completes 100% within 2x its no-fault p99, the
    timeout-retry baseline degrades at least as much) hold even at
    smoke sizes — the JSON artifact records them per PR."""
    import benchmarks.fig_fault_masking as ffm
    rows = ffm.run(smoke=True)
    by_name = {r[0]: r for r in rows}
    chaos = by_name["fig_fault_masking/chaos"][2]
    assert "hedged_completes_all=True" in chaos, chaos
    assert "hedged_p99_within_2x=True" in chaos, chaos
    assert "retry_degrades_more=True" in chaos, chaos
    assert "masked=True" in chaos, chaos
    engine = by_name["fig_fault_masking/engine"][2]
    assert "retry_completes_all=True" in engine, engine
    assert "completion_order=True" in engine, engine


def test_serving_adaptive_vs_static_acceptance():
    """The adaptive-serving acceptance booleans hold at smoke sizes: a
    short deterministic diurnal replay where the controller's p99 is no
    worse than the best static k at every segment and strictly better
    on at least one (the 1M-request version is the slow-marked test in
    test_serving_adaptive.py). The policy-table row resolves its grid
    from ONE mixed-grid sweep."""
    import benchmarks.serving_hedge as sh
    from benchmarks.common import row_provenance
    rows = sh.run(smoke=True)
    by_name = {r[0]: r for r in rows}
    cmp = by_name["serving/adaptive_vs_static"][2]
    assert "no_worse=True" in cmp, cmp
    assert "strictly_better=True" in cmp, cmp
    _, scn, _, _ = row_provenance(by_name["serving/adaptive_vs_static"])
    assert scn["adaptive_no_worse"] is True
    assert scn["adaptive_strictly_better"] is True
    assert scn["controller"]["decisions"] > 0
    table = by_name["serving/policy_table"]
    assert "best@0.10=" in table[2] and "best@0.75=" in table[2]
    _, tab, _, _ = row_provenance(table)
    assert len(tab["k"]) == len(tab["delay"]) >= 2
    live = by_name["serving/batched_live"][2]
    assert "completions=" in live and "p99_ms=" in live


def test_fig_cross_system_crossover_row():
    """The cross-system figure's summary row reports one crossover load
    per system off a SINGLE mixed-grid gain call, the expected ordering
    (heavy-tailed disk and DNS cross later than overhead-dominated
    memcached), and a kernel-parity row pinning scan == kernel on the
    heterogeneous grid."""
    import benchmarks.fig_cross_system as fcs
    from benchmarks.common import row_provenance
    rows = fcs.run(smoke=True)
    by_name = {r[0]: r for r in rows}
    cross = by_name["fig_cross_system/crossover"][2]
    for system in ("disk", "memcached", "dns"):
        assert f"{system}=" in cross, cross
    assert "order=" in cross, cross
    assert cross.index("memcached=") > cross.index("disk="), cross
    _, scn, kernel, _ = row_provenance(by_name["fig_cross_system/disk"])
    assert scn["ks"] == [1, 2] and len(scn["dists"]) == 1
    assert kernel in ("on", "off", "interpret")
    parity = by_name["fig_cross_system/kernel_parity"][2]
    assert "bit_identical=True" in parity, parity


def test_fig12_accepts_chunked_engine_config():
    import benchmarks.fig12_memcached as fig12
    rows = fig12.run(smoke=True, chunk_size=1_024)
    assert rows and all("ERROR" not in r[0] for r in rows)


def test_run_harness_importable():
    import benchmarks.run as run_mod
    assert callable(run_mod.main)
