"""Every benchmark module's entry point imports and runs at tiny sizes
(the same ``smoke=True`` path CI exercises via ``benchmarks/run.py
--smoke``), so a refactor of the engine API cannot silently strand a
figure reproduction."""
import importlib
import sys
from pathlib import Path

import pytest

# benchmarks/ is a repo-root package (not under src/); make it importable
# the same way benchmarks/run.py is invoked from the repo root.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

# Fast modules run in full; the heavy simulators get a trimmed marker so a
# plain tier-1 run still covers every entry point without minutes of wall
# clock dominated by two modules.
MODULES = [
    "fig1_queueing",
    "fig2_threshold",
    "fig3_random",
    "fig4_overhead",
    "fig5_diskdb",
    "fig12_memcached",
    "fig15_dns",
    "tab_tcp",
    "serving_hedge",
    "roofline",
    "sweep_engine",
    "fig14_network",
]


@pytest.mark.parametrize("name", MODULES)
def test_benchmark_entry_runs_smoke(name):
    mod = importlib.import_module(f"benchmarks.{name}")
    rows = mod.run(smoke=True)
    assert isinstance(rows, list) and rows, name
    for row in rows:
        label, us, derived = row
        assert isinstance(label, str) and label
        assert float(us) >= 0.0
        assert isinstance(derived, str)
        assert "ERROR" not in label, (label, derived)


def test_fig12_accepts_chunked_engine_config():
    import benchmarks.fig12_memcached as fig12
    rows = fig12.run(smoke=True, chunk_size=1_024)
    assert rows and all("ERROR" not in r[0] for r in rows)


def test_run_harness_importable():
    import benchmarks.run as run_mod
    assert callable(run_mod.main)
