"""Integration tests for the multi-pod dry-run machinery.

The full 68-cell sweep runs via ``python -m repro.launch.dryrun --all``;
here we run one real cell end-to-end in a subprocess (512 host devices) and
unit-test the HLO analyzer + sharding rules in-process.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


class TestHLOAnalysis:
    def test_trip_count_scaling(self):
        import jax
        import jax.numpy as jnp
        from repro.launch import hlo_analysis as ha

        def body(c, _):
            return c @ c, None

        def f(x):
            return jax.lax.scan(body, x, None, length=10)[0]

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        hlo = jax.jit(f).lower(x).compile().as_text()
        res = ha.analyze(hlo)
        one_matmul = 2 * 128 * 128 * 128
        # the scan must count ~10 matmuls, not 1
        assert res["flops"] == pytest.approx(10 * one_matmul, rel=0.01)

    def test_collective_detection(self):
        """A cross-device reduction must surface in ``collective_bytes``.

        This branch needs real devices to compile a partitioned program;
        skipping must be LOUD (a silent pass here once hid the fact that
        no CI lane ever exercised collective detection — the multi-device
        job runs it under 8 virtual devices)."""
        import jax
        if jax.device_count() < 2:
            pytest.skip("collective-detection branch NOT exercised: needs "
                        ">= 2 devices (the multi-device CI job runs it "
                        "under XLA_FLAGS="
                        "--xla_force_host_platform_device_count=8)")
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlo_analysis as ha

        mesh = jax.make_mesh((2,), ("x",))
        x = jax.device_put(jnp.ones((256, 128)),
                           NamedSharding(mesh, P("x", None)))
        w = jax.device_put(jnp.ones((128, 128)),
                           NamedSharding(mesh, P(None, None)))
        # row-sharded lhs, replicated output: forces a cross-device reduce
        f = jax.jit(lambda a, b: (a @ b).sum(),
                    out_shardings=NamedSharding(mesh, P()))
        hlo = f.lower(x, w).compile().as_text()
        res = ha.analyze(hlo)
        assert sum(res["collective_bytes"].values()) > 0, \
            res["collective_bytes"]

    def test_shape_bytes(self):
        from repro.launch.hlo_analysis import _shape_bytes
        assert _shape_bytes("f32[2,3]{1,0}") == 24
        assert _shape_bytes("bf16[4,4]") == 32
        assert _shape_bytes("(f32[2], s32[3])") == 8 + 12


class TestShardingRules:
    def test_param_specs_divisible_all_archs(self):
        """Every param spec must divide its dim on the production mesh."""
        import jax
        from repro.configs import base as cfgbase
        from repro.distributed import sharding
        from repro.launch import specs as sp

        sizes = {"pod": 2, "data": 16, "model": 16}
        for arch in cfgbase.list_architectures():
            cfg = cfgbase.get_config(arch)
            params = sp.param_specs(cfg)
            flat, _ = jax.tree_util.tree_flatten_with_path(params)
            for inference in (False, True):
                ep = (sharding._decode_ep_axes(cfg, False) if inference
                      else ("model",))
                for path, leaf in flat:
                    pstr = sharding._path_str(path)
                    spec = sharding.param_spec(
                        pstr, leaf.shape, cfg, inference=inference,
                        ep_axes=ep)
                    for i, ax in enumerate(spec):
                        if ax is None:
                            continue
                        axes = (ax,) if isinstance(ax, str) else ax
                        size = 1
                        for a in axes:
                            size *= sizes[a]
                        assert leaf.shape[i] % size == 0, \
                            f"{arch} {pstr} {leaf.shape} {spec} (inf={inference})"

    def test_layouts_defined_for_all_cells(self):
        from repro.configs import base as cfgbase
        from repro.distributed import sharding
        for arch in cfgbase.list_architectures():
            cfg = cfgbase.get_config(arch)
            for shape in cfgbase.cells(arch):
                for mp in (False, True):
                    lay = sharding.make_layout(cfg, shape.kind, mp,
                                               shape.global_batch)
                    assert lay is not None
                    if shape.kind == "decode":
                        assert lay.kv_seq is not None

    def test_decode_ep_axes(self):
        from repro.configs import base as cfgbase
        from repro.distributed import sharding
        ds = cfgbase.get_config("deepseek-v3-671b")
        assert sharding._decode_ep_axes(ds, False) == ("model", "data")
        gr = cfgbase.get_config("granite-moe-3b-a800m")
        assert sharding._decode_ep_axes(gr, False) == ("model",)


@pytest.mark.slow
class TestDryRunSubprocess:
    def test_one_cell_end_to_end(self, tmp_path):
        """Compile a real cell against the 256-chip mesh in a subprocess
        (so the 512-host-device override cannot leak into this process)."""
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "mamba2-370m", "--shape", "decode_32k",
             "--mesh", "single", "--out", str(tmp_path)],
            env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                 "HOME": "/root"},
            capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        rec = json.loads(
            (tmp_path / "mamba2-370m_decode_32k_single.json").read_text())
        assert rec["ok"], rec.get("error")
        assert rec["devices"] == 256
        assert rec["scaled_flops"] > 0
        assert "collective_bytes" in rec
