"""Serving-layer tests: engine correctness, hedged scheduler semantics."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.hedging import HedgePolicy, LoadMeter
from repro.models import lm
from repro.serving.engine import InferenceEngine, SimulatedEngine
from repro.serving.scheduler import HedgedScheduler


def make_sim(mean_s=0.01, tail_s=0.3, tail_p=0.0, seed=0):
    rng = np.random.default_rng(seed)

    def sampler():
        if rng.random() < tail_p:
            return tail_s
        return mean_s * (0.5 + rng.random())

    return sampler


class TestEngine:
    def test_generate_deterministic(self):
        cfg = get_smoke_config("nemotron-4-15b")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        eng = InferenceEngine(cfg, params, max_len=64)
        prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
        out1 = eng.generate(prompt, max_new_tokens=4)
        out2 = eng.generate(prompt, max_new_tokens=4)
        assert out1.shape == (4,)
        np.testing.assert_array_equal(out1, out2)

    def test_generate_matches_prefill_extension(self):
        # greedy decode must equal repeated prefill argmax (teacher forcing)
        from repro.models import decode as dec
        import jax.numpy as jnp
        cfg = get_smoke_config("gemma3-12b")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        eng = InferenceEngine(cfg, params, max_len=64)
        prompt = (np.arange(12, dtype=np.int32) * 7) % cfg.vocab_size
        out = eng.generate(prompt, max_new_tokens=3)
        cur = list(prompt)
        for i in range(3):
            logits, _ = jax.jit(
                lambda p, b: dec.prefill(p, cfg, b, 64))(
                params, {"tokens": jnp.asarray(cur, dtype=jnp.int32)[None]})
            nxt = int(jnp.argmax(logits, axis=-1)[0])
            assert nxt == int(out[i]), f"step {i}"
            cur.append(nxt)


class TestHedgedScheduler:
    def test_first_wins_and_duplicate_can_win(self):
        # replica 0 is slow, replica 1 fast: hedged requests should complete
        # at the fast replica's latency.
        slow = SimulatedEngine(lambda: 0.25, name="slow")
        fast = SimulatedEngine(lambda: 0.01, name="fast")
        sched = HedgedScheduler([slow, fast],
                                policy=HedgePolicy(max_k=2, threshold=1.1),
                                seed=1)
        try:
            lat = []
            for _ in range(6):
                req = sched.submit(np.zeros(4, np.int32), max_new_tokens=2)
                lat.append(req.latency)
            # with k=2 every request touches both replicas: latency ~ fast
            assert np.median(lat) < 0.15
        finally:
            sched.shutdown()

    def test_policy_disables_hedging_at_high_load(self):
        eng = [SimulatedEngine(make_sim(0.005), name=f"s{i}")
               for i in range(4)]
        meter = LoadMeter(alpha=0.0, init=0.9)  # pinned: system is loaded
        sched = HedgedScheduler(
            eng, policy=HedgePolicy(max_k=2, threshold=0.25), meter=meter)
        try:
            sched.submit(np.zeros(2, np.int32))
            assert sched.stats["hedged"] == 0
        finally:
            sched.shutdown()

    def test_policy_enables_hedging_at_low_load(self):
        eng = [SimulatedEngine(make_sim(0.005), name=f"s{i}")
               for i in range(4)]
        meter = LoadMeter(alpha=0.0, init=0.0)
        sched = HedgedScheduler(
            eng, policy=HedgePolicy(max_k=2, threshold=0.25), meter=meter)
        try:
            sched.submit(np.zeros(2, np.int32))
            assert sched.stats["hedged"] == 1
        finally:
            sched.shutdown()

    def test_replica_failure_masked(self):
        class Boom:
            name = "boom"

            def generate(self, *a, **kw):
                raise RuntimeError("replica died")

        ok = SimulatedEngine(lambda: 0.01, name="ok")
        sched = HedgedScheduler([Boom(), ok],
                                policy=HedgePolicy(max_k=2, threshold=1.1),
                                seed=0)
        try:
            req = sched.submit(np.zeros(2, np.int32), timeout=5.0)
            assert req.completed_by == "ok"
        finally:
            sched.shutdown()

    def test_hedging_cuts_tail_latency(self):
        # The paper's core claim at the serving layer: with heavy-tailed
        # per-replica service, k=2 cuts the observed tail.
        def run(k):
            engines = [SimulatedEngine(make_sim(0.004, tail_s=0.12,
                                                tail_p=0.25, seed=i),
                                       name=f"s{i}") for i in range(4)]
            sched = HedgedScheduler(
                engines,
                policy=HedgePolicy(max_k=k, threshold=1.1), seed=2)
            try:
                lats = [sched.submit(np.zeros(2, np.int32)).latency
                        for _ in range(40)]
            finally:
                sched.shutdown()
            return np.asarray(lats)

        l1, l2 = run(1), run(2)
        assert np.percentile(l2, 90) < np.percentile(l1, 90)
        assert np.mean(l2) < np.mean(l1)
