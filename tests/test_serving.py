"""Serving-layer tests: engine correctness, hedged scheduler semantics,
fault injection and elastic chaos (replica killed mid-trace)."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.hedging import HedgePolicy, LoadMeter
from repro.models import lm
from repro.serving.engine import InferenceEngine, SimulatedEngine
from repro.serving.faults import FaultInjector, ReplicaCrashed
from repro.serving.scheduler import HedgedScheduler, RetryPolicy


def make_sim(mean_s=0.01, tail_s=0.3, tail_p=0.0, seed=0):
    rng = np.random.default_rng(seed)

    def sampler():
        if rng.random() < tail_p:
            return tail_s
        return mean_s * (0.5 + rng.random())

    return sampler


class TestEngine:
    def test_generate_deterministic(self):
        cfg = get_smoke_config("nemotron-4-15b")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        eng = InferenceEngine(cfg, params, max_len=64)
        prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
        out1 = eng.generate(prompt, max_new_tokens=4)
        out2 = eng.generate(prompt, max_new_tokens=4)
        assert out1.shape == (4,)
        np.testing.assert_array_equal(out1, out2)

    def test_generate_matches_prefill_extension(self):
        # greedy decode must equal repeated prefill argmax (teacher forcing)
        from repro.models import decode as dec
        import jax.numpy as jnp
        cfg = get_smoke_config("gemma3-12b")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        eng = InferenceEngine(cfg, params, max_len=64)
        prompt = (np.arange(12, dtype=np.int32) * 7) % cfg.vocab_size
        out = eng.generate(prompt, max_new_tokens=3)
        cur = list(prompt)
        for i in range(3):
            logits, _ = jax.jit(
                lambda p, b: dec.prefill(p, cfg, b, 64))(
                params, {"tokens": jnp.asarray(cur, dtype=jnp.int32)[None]})
            nxt = int(jnp.argmax(logits, axis=-1)[0])
            assert nxt == int(out[i]), f"step {i}"
            cur.append(nxt)


class TestHedgedScheduler:
    def test_first_wins_and_duplicate_can_win(self):
        # replica 0 is slow, replica 1 fast: hedged requests should complete
        # at the fast replica's latency.
        slow = SimulatedEngine(lambda: 0.25, name="slow")
        fast = SimulatedEngine(lambda: 0.01, name="fast")
        sched = HedgedScheduler([slow, fast],
                                policy=HedgePolicy(max_k=2, threshold=1.1),
                                seed=1)
        try:
            lat = []
            for _ in range(6):
                req = sched.submit(np.zeros(4, np.int32), max_new_tokens=2)
                lat.append(req.latency)
            # with k=2 every request touches both replicas: latency ~ fast
            assert np.median(lat) < 0.15
        finally:
            sched.shutdown()

    def test_policy_disables_hedging_at_high_load(self):
        eng = [SimulatedEngine(make_sim(0.005), name=f"s{i}")
               for i in range(4)]
        meter = LoadMeter(alpha=0.0, init=0.9)  # pinned: system is loaded
        sched = HedgedScheduler(
            eng, policy=HedgePolicy(max_k=2, threshold=0.25), meter=meter)
        try:
            sched.submit(np.zeros(2, np.int32))
            assert sched.stats["hedged"] == 0
        finally:
            sched.shutdown()

    def test_policy_enables_hedging_at_low_load(self):
        eng = [SimulatedEngine(make_sim(0.005), name=f"s{i}")
               for i in range(4)]
        meter = LoadMeter(alpha=0.0, init=0.0)
        sched = HedgedScheduler(
            eng, policy=HedgePolicy(max_k=2, threshold=0.25), meter=meter)
        try:
            sched.submit(np.zeros(2, np.int32))
            assert sched.stats["hedged"] == 1
        finally:
            sched.shutdown()

    def test_utilization_matches_worker_traversal(self):
        """The O(1) LoadTracker read and an O(n) walk of the workers'
        busy flags are the same signal — shed decisions and policy
        decisions must agree on load. Checked at quiesced points
        (0 busy, 1 busy held by a gate, 0 busy again)."""
        release = threading.Event()
        started = threading.Event()

        class Gated:
            name = "g0"

            def generate(self, prompt, max_new_tokens=2,
                         check_cancel=None):
                started.set()
                release.wait(5.0)
                return np.zeros(1, np.int32)

        sched = HedgedScheduler([Gated()],
                                policy=HedgePolicy(max_k=1), seed=0)

        def walk():
            return (sum(w.is_busy() for w in sched.workers)
                    / len(sched.workers))

        try:
            assert sched.utilization() == walk() == 0.0
            t = threading.Thread(
                target=lambda: sched.submit(np.zeros(2, np.int32),
                                            max_new_tokens=1))
            t.start()
            assert started.wait(5.0)
            assert sched.utilization() == walk() == 1.0
            release.set()
            t.join(5.0)
            assert sched.utilization() == walk() == 0.0
        finally:
            release.set()
            sched.shutdown()

    def test_replica_failure_masked(self):
        class Boom:
            name = "boom"

            def generate(self, *a, **kw):
                raise RuntimeError("replica died")

        ok = SimulatedEngine(lambda: 0.01, name="ok")
        sched = HedgedScheduler([Boom(), ok],
                                policy=HedgePolicy(max_k=2, threshold=1.1),
                                seed=0)
        try:
            req = sched.submit(np.zeros(2, np.int32), timeout=5.0)
            assert req.completed_by == "ok"
        finally:
            sched.shutdown()

    def test_hedging_cuts_tail_latency(self):
        # The paper's core claim at the serving layer: with heavy-tailed
        # per-replica service, k=2 cuts the observed tail.
        def run(k):
            engines = [SimulatedEngine(make_sim(0.004, tail_s=0.12,
                                                tail_p=0.25, seed=i),
                                       name=f"s{i}") for i in range(4)]
            sched = HedgedScheduler(
                engines,
                policy=HedgePolicy(max_k=k, threshold=1.1), seed=2)
            try:
                lats = [sched.submit(np.zeros(2, np.int32)).latency
                        for _ in range(40)]
            finally:
                sched.shutdown()
            return np.asarray(lats)

        l1, l2 = run(1), run(2)
        assert np.percentile(l2, 90) < np.percentile(l1, 90)
        assert np.mean(l2) < np.mean(l1)


class TestSchedulerRobustness:
    def test_shutdown_idempotent(self):
        sched = HedgedScheduler([SimulatedEngine(lambda: 0.01, name="a")])
        sched.shutdown()
        sched.shutdown()  # must be a no-op, not an error

    def test_retry_policy_resends_after_deadline(self):
        # first attempt lands on a stalled replica; the resend completes
        inj = FaultInjector()
        engines = [inj.wrap(SimulatedEngine(lambda: 0.01, name=f"s{i}"))
                   for i in range(2)]
        inj.stall("s0")
        sched = HedgedScheduler(engines, seed=3)
        try:
            # force the primary onto the stalled replica: retry with a
            # short deadline must fail over to the healthy one
            done = 0
            for _ in range(6):
                req = sched.submit(
                    np.zeros(2, np.int32), timeout=5.0,
                    retry=RetryPolicy(deadline=0.05, max_retries=2))
                assert req.completed_by == "s1"
                done += 1
            assert done == 6
            assert sched.stats["hedged"] == 0  # baseline never hedges
            # with 2 replicas and a stalled s0, roughly half the
            # primaries land on s0 and need a resend
            assert sched.stats["retries"] >= 1
        finally:
            sched.shutdown()
            inj.heal("s0")

    def test_hedge_after_delay_defers_duplicates(self):
        # fast primaries: with a generous hedge delay no duplicate is
        # ever issued; with delay 0 every request is hedged
        engines = [SimulatedEngine(lambda: 0.005, name=f"s{i}")
                   for i in range(3)]
        sched = HedgedScheduler(
            engines, policy=HedgePolicy(max_k=2, threshold=1.1),
            hedge_delay=0.5, seed=4)
        try:
            for _ in range(5):
                sched.submit(np.zeros(2, np.int32), timeout=5.0)
            assert sched.stats["hedged"] == 0
            for _ in range(5):
                sched.submit(np.zeros(2, np.int32), timeout=5.0,
                             hedge_delay=0.0)
            assert sched.stats["hedged"] == 5
        finally:
            sched.shutdown()

    def test_hedge_after_delay_rescues_straggler(self):
        # slow primary, short hedge delay: the duplicate fires and wins
        inj = FaultInjector()
        engines = [inj.wrap(SimulatedEngine(lambda: 0.01, name=f"s{i}"))
                   for i in range(2)]
        inj.slow("s0", 100.0)
        sched = HedgedScheduler(
            engines, policy=HedgePolicy(max_k=2, threshold=1.1),
            hedge_delay=0.05, tied_cancel=True, seed=5)
        try:
            lats = [sched.submit(np.zeros(2, np.int32), timeout=5.0).latency
                    for _ in range(6)]
            # every request completes well under the 1 s straggled time
            assert max(lats) < 0.5
        finally:
            sched.shutdown()
            inj.heal("s0")

    def test_shed_watermark_disables_duplicates(self):
        engines = [SimulatedEngine(lambda: 0.005, name=f"s{i}")
                   for i in range(2)]
        sched = HedgedScheduler(
            engines, policy=HedgePolicy(max_k=2, threshold=1.1),
            shed_watermark=0.0, seed=6)   # always above the watermark
        try:
            sched.submit(np.zeros(2, np.int32), timeout=5.0)
            assert sched.stats["shed"] == 1
            assert sched.stats["hedged"] == 0
        finally:
            sched.shutdown()

    def test_remove_replica_requeues_pending_work(self):
        # fill a worker's queue while it is stalled, then remove it:
        # the queued copies must land on the survivor and complete
        inj = FaultInjector()
        engines = [inj.wrap(SimulatedEngine(lambda: 0.005, name=f"s{i}"))
                   for i in range(2)]
        inj.stall("s0")
        sched = HedgedScheduler(
            engines, policy=HedgePolicy(max_k=2, threshold=1.1), seed=7)
        try:
            reqs, threads = [], []

            def go():
                reqs.append(sched.submit(np.zeros(2, np.int32),
                                         timeout=10.0))

            for _ in range(4):
                t = threading.Thread(target=go)
                t.start()
                threads.append(t)
            time.sleep(0.2)      # let copies queue up behind the stall
            assert sched.remove_replica("s0")
            for t in threads:
                t.join(timeout=10.0)
            assert len(reqs) == 4
            assert all(r.completed_by == "s1" for r in reqs)
        finally:
            sched.shutdown()
            inj.heal("s0")


class TestFaultInjector:
    def test_crash_raises_and_heal_restores(self):
        inj = FaultInjector()
        eng = inj.wrap(SimulatedEngine(lambda: 0.005, name="x"))
        inj.crash("x")
        with pytest.raises(ReplicaCrashed):
            eng.generate(np.zeros(2, np.int32), 2)
        inj.heal("x")
        assert eng.generate(np.zeros(2, np.int32), 2) is not None

    def test_slow_inflates_service_time(self):
        inj = FaultInjector()
        eng = inj.wrap(SimulatedEngine(lambda: 0.02, name="x"))
        t0 = time.monotonic()
        eng.generate(np.zeros(2, np.int32), 2)
        base = time.monotonic() - t0
        inj.slow("x", 5.0)
        t0 = time.monotonic()
        eng.generate(np.zeros(2, np.int32), 2)
        slowed = time.monotonic() - t0
        assert slowed > 2.0 * base

    def test_scheduled_crash_fires_later(self):
        inj = FaultInjector()
        eng = inj.wrap(SimulatedEngine(lambda: 0.001, name="x"))
        inj.crash("x", after=0.15)
        assert eng.generate(np.zeros(2, np.int32), 2) is not None
        time.sleep(0.3)
        with pytest.raises(ReplicaCrashed):
            eng.generate(np.zeros(2, np.int32), 2)


class TestElasticChaos:
    @pytest.mark.parametrize("tied_cancel", [False, True])
    def test_replica_killed_mid_trace(self, tied_cancel):
        # 4 replicas, a trace of requests; mid-trace one replica is
        # crashed AND removed. Every request must complete: in-flight
        # copies on the victim are masked by their hedged sibling,
        # queued copies are requeued by remove_replica.
        inj = FaultInjector()
        engines = [inj.wrap(SimulatedEngine(make_sim(0.01, seed=i),
                                            name=f"s{i}"))
                   for i in range(4)]
        sched = HedgedScheduler(
            engines, policy=HedgePolicy(max_k=2, threshold=1.1),
            tied_cancel=tied_cancel, seed=8)
        try:
            reqs = []
            for i in range(30):
                if i == 10:
                    inj.crash("s1")          # dies with work in flight
                    assert sched.remove_replica("s1")
                reqs.append(sched.submit(np.zeros(2, np.int32),
                                         timeout=10.0))
            assert len(reqs) == 30
            assert all(r.done_event.is_set() for r in reqs)
            assert all(r.completed_by != "s1" for r in reqs[10:])
        finally:
            sched.shutdown()
