"""Regenerate the pre-PR-7 healthy-cell capture (``pre_pr7.npz``).

Run at the PR-6 tree (commit 1c31482) — i.e. BEFORE the degradation
model landed — this records ``queueing.run`` summaries for a mixed grid
of every pre-existing policy x service-model combination, across
chunked/unchunked and scan/interpret-kernel paths. The PR-7 acceptance
contract (tests/test_faults.py::TestHealthyBitIdentity) is that healthy
cells (``p_slow = p_fail = 0``) reproduce these bits exactly after the
failure/straggler model landed.

Usage: PYTHONPATH=src python tests/golden/make_pre_pr7.py
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import queueing
from repro.core.distributions import exponential
from repro.core.scenario import (CANCEL_ON_COMPLETE, REPLICATE_TO_IDLE,
                                 SERVER_DEPENDENT, Scenario)

CFG = queueing.SimConfig(n_servers=6, n_arrivals=4096)
RHOS = (0.3, 0.6)
KEY_SEED = 7
PERCENTILES = (50.0, 99.0)


def scenarios():
    dist = exponential()
    return (
        Scenario.paper_default(dist, ks=(1, 2)),
        Scenario(dists=dist, policy=CANCEL_ON_COMPLETE, ks=(2,)),
        Scenario(dists=dist, policy=REPLICATE_TO_IDLE, ks=(2,),
                 client_overhead=0.25),
        Scenario(dists=dist, service_model=SERVER_DEPENDENT, mix=0.7,
                 ks=(2,)),
    )


def capture():
    key = jax.random.PRNGKey(KEY_SEED)
    rhos = jnp.asarray(RHOS)
    out = {}
    runs = {
        "unchunked_off": dict(chunk_size=None, kernel="off"),
        "chunked_off": dict(chunk_size=1536, kernel="off"),
        "unchunked_interp": dict(chunk_size=None, kernel="interpret"),
    }
    for name, kw in runs.items():
        res = queueing.run(key, scenarios(), rhos, CFG,
                           n_seeds=2, percentiles=PERCENTILES, **kw)
        for stat in ("mean", "p50", "p99"):
            out[f"{name}/{stat}"] = np.asarray(res[stat])
    return out


if __name__ == "__main__":
    path = os.path.join(os.path.dirname(__file__), "pre_pr7.npz")
    np.savez(path, **capture())
    print(f"wrote {path}")
