"""Pallas hist_sketch kernel: interpret-mode parity vs the jnp reference
(bit-exact bin counts) and sketch-quantile accuracy vs exact quantiles."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.hist_sketch import kernel, ops, ref


def _rand_idx(seed: int, t: int, c: int, n_bins: int) -> jax.Array:
    """Random indices including skip markers (-1) and both edge bins."""
    key = jax.random.PRNGKey(seed)
    idx = jax.random.randint(key, (t, c), -1, n_bins)
    # force edge coverage
    idx = idx.at[0, 0].set(0).at[-1, -1].set(n_bins - 1)
    return idx


class TestKernelParity:
    @pytest.mark.parametrize("t,c,n_bins", [
        (1024, 5, 2048),
        (512, 1, 128),
        (768, 16, 256),
    ])
    def test_bit_exact_vs_ref(self, t, c, n_bins):
        idx = _rand_idx(t + c, t, c, n_bins)
        out = ops.hist_accum(idx, n_bins=n_bins, interpret=True)
        expect = ref.hist_accum_ref(idx, n_bins=n_bins)
        assert out.shape == (c, n_bins)
        assert jnp.array_equal(out, expect)

    def test_non_multiple_block_t_padded(self):
        # T = 777 is not a multiple of any block size; ops pads with skips
        idx = _rand_idx(7, 777, 3, 256)
        out = ops.hist_accum(idx, n_bins=256, interpret=True)
        assert jnp.array_equal(out, ref.hist_accum_ref(idx, n_bins=256))

    def test_skip_entries_add_nothing(self):
        idx = jnp.full((512, 4), -1, jnp.int32)
        out = ops.hist_accum(idx, n_bins=128, interpret=True)
        assert float(out.sum()) == 0.0

    def test_total_mass_equals_valid_entries(self):
        idx = _rand_idx(3, 640, 6, 512)
        out = ops.hist_accum(idx, n_bins=512, interpret=True)
        assert float(out.sum()) == float((idx >= 0).sum())

    def test_kernel_direct_matches_ref(self):
        # exercise the jitted kernel wrapper without the ops padding layer
        idx = _rand_idx(11, 1024, 2, 1024)
        out = kernel.hist_accum_tc(idx, n_bins=1024, block_t=256,
                                   interpret=True)
        assert jnp.array_equal(out, ref.hist_accum_ref(idx, n_bins=1024))

    def test_non_lane_divisible_bins_falls_back_to_ref(self):
        # n_bins not divisible by the 128 lane width cannot use the kernel
        idx = _rand_idx(5, 300, 2, 100)
        out = ops.hist_accum(idx, n_bins=100, interpret=True)
        assert jnp.array_equal(out, ref.hist_accum_ref(idx, n_bins=100))

    def test_warm_weights_encoded_as_skips(self):
        vals = jax.random.exponential(jax.random.PRNGKey(0), (600, 3)) + 1e-3
        warm = (jnp.arange(600) >= 100).astype(jnp.float32)
        h = ops.hist_sketch(vals, warm[:, None], n_bins=256, interpret=True)
        assert float(h.sum()) == 500 * 3
        h_all = ops.hist_sketch(vals, None, n_bins=256, interpret=True)
        assert float(h_all.sum()) == 600 * 3


class TestSketchQuantileAccuracy:
    """Property: sketch quantiles are within one log-bin width of the exact
    sample quantile, for random samples from several distribution shapes."""

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("family", ["exponential", "lognormal", "pareto"])
    def test_quantile_error_within_one_log_bin(self, seed, family):
        key = jax.random.PRNGKey(seed)
        n = 40_000
        if family == "exponential":
            s = jax.random.exponential(key, (n,)) + 1e-3
        elif family == "lognormal":
            s = jnp.exp(jax.random.normal(key, (n,)) * 1.5)
        else:  # pareto tail index 2.1
            u = jax.random.uniform(key, (n,),
                                   minval=jnp.finfo(jnp.float32).tiny)
            s = 0.5 * u ** (-1.0 / 2.1)
        n_bins = ops.DEFAULT_BINS
        hist = ops.hist_sketch(s[:, None], n_bins=n_bins, interpret=True)
        qs = jnp.asarray([50.0, 90.0, 99.0, 99.9])
        sketch = ops.sketch_quantiles(hist, qs)[:, 0]
        log_bin = (math.log(ops.HIST_HI) - math.log(ops.HIST_LO)) / (n_bins - 1)
        for qi, p in enumerate([0.5, 0.9, 0.99, 0.999]):
            exact = float(jnp.quantile(s, p))
            err = abs(math.log(float(sketch[qi])) - math.log(exact))
            assert err <= log_bin * 1.001 + 1e-6, (family, p, err, log_bin)

    def test_clamped_outliers_land_in_edge_bins(self):
        s = jnp.asarray([1e-9, 1e9, 1.0])[:, None]
        h = ops.hist_sketch(s, n_bins=256, interpret=True)
        assert float(h[0, 0]) == 1.0 and float(h[0, -1]) == 1.0
        assert float(h.sum()) == 3.0
