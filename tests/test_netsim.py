"""Fat-tree replication simulator (§2.4) tests."""
import dataclasses

import numpy as np
import pytest

from repro.core import netsim


class TestTopology:
    def test_path_shapes(self):
        # intra-edge: 2 hops; intra-pod: 4; inter-pod: 6
        assert len(netsim._links_for_path(0, 1, 0, 0)) == 2
        assert len(netsim._links_for_path(0, 4, 0, 0)) == 4
        assert len(netsim._links_for_path(0, 53, 1, 2)) == 6

    def test_link_ids_in_range(self):
        for src in (0, 13, 53):
            for dst in (1, 27, 52):
                if src == dst:
                    continue
                for u1 in range(3):
                    for u2 in range(3):
                        for l in netsim._links_for_path(src, dst, u1, u2):
                            assert 0 <= l < netsim.N_LINKS

    def test_alt_path_differs(self):
        p1 = netsim._links_for_path(0, 53, 0, 0)
        p2 = netsim._links_for_path(0, 53, 1, 0)
        assert p1 != p2
        # first and last links (host access) are shared
        assert p1[0] == p2[0] and p1[-1] == p2[-1]


class TestSimulation:
    def test_all_delivered_at_low_load(self):
        cfg = netsim.NetConfig(n_flows=60, load=0.05, replicate_first=0,
                               seed=0)
        fct, sizes, short, undelivered = netsim.flow_completion_times(cfg)
        assert undelivered.sum() == 0
        # minimum possible FCT: size packets paced 1/slot + path latency
        assert np.all(fct >= sizes)

    def test_censored_fct_units_consistent(self):
        # Truncate the horizon so some flows cannot finish: censored
        # FCTs must be the RELATIVE bound n_slots - start (same units
        # as delivered last - start + 1), never the absolute horizon —
        # the old mixed-unit censoring inflated every censored FCT by
        # its start slot.
        cfg = netsim.NetConfig(n_flows=80, load=0.4, replicate_first=0,
                               seed=2)
        *_, starts = netsim.build_workload(cfg)
        n_slots = int(starts.max()) + 5
        fct, sizes, _, undelivered = netsim.flow_completion_times(
            cfg, n_slots=n_slots)
        assert undelivered.any()  # the truncation must actually censor
        np.testing.assert_array_equal(
            fct[undelivered], (float(n_slots) - starts)[undelivered])
        # censoring is a LOWER bound in consistent units: every censored
        # FCT still fits inside the horizon, and delivered flows do too
        assert np.all(fct[undelivered] <= n_slots)
        assert np.all(fct[~undelivered] <= n_slots)
        assert np.all(fct >= 0.0)

    def test_replication_never_hurts_uncongested(self):
        base = netsim.NetConfig(n_flows=60, load=0.05, replicate_first=0,
                                seed=1)
        rep = dataclasses.replace(base, replicate_first=8)
        f0, _, sh0, _ = netsim.flow_completion_times(base)
        f1, _, sh1, _ = netsim.flow_completion_times(rep)
        # duplicates are strictly low priority: same workload, FCTs can only
        # improve or stay equal (up to tie-breaking jitter)
        assert np.mean(f1[sh1]) <= np.mean(f0[sh0]) * 1.02

    def test_replication_helps_at_intermediate_load(self):
        base = netsim.NetConfig(n_flows=400, load=0.45, replicate_first=0,
                                elephant_frac=0.12, elephant_pkts=400,
                                seed=3)
        rep = dataclasses.replace(base, replicate_first=8)
        f0, _, sh0, _ = netsim.flow_completion_times(base)
        f1, _, sh1, _ = netsim.flow_completion_times(rep)
        assert np.mean(f1[sh1]) < np.mean(f0[sh0])
        assert np.percentile(f1[sh1], 90) <= np.percentile(f0[sh0], 90)

    def test_elephants_unaffected(self):
        base = netsim.NetConfig(n_flows=300, load=0.4, replicate_first=0,
                                elephant_frac=0.12, elephant_pkts=300,
                                seed=4)
        rep = dataclasses.replace(base, replicate_first=8)
        f0, s0, sh0, _ = netsim.flow_completion_times(base)
        f1, s1, sh1, _ = netsim.flow_completion_times(rep)
        e0, e1 = f0[~sh0], f1[~sh1]
        # paper: statistically-insignificant effect on large flows
        assert abs(np.mean(e1) - np.mean(e0)) / np.mean(e0) < 0.05
