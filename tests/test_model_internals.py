"""Focused unit tests for model building blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, MoEConfig
from repro.models import attention, layers, mla, moe


class TestSoftcap:
    def test_bounded(self):
        x = jnp.linspace(-1000, 1000, 101)
        y = layers.softcap(x, 50.0)
        assert float(jnp.max(jnp.abs(y))) <= 50.0
        # near-identity around zero
        np.testing.assert_allclose(np.asarray(layers.softcap(x, 50.0))[50],
                                   0.0, atol=1e-6)

    def test_none_is_identity(self):
        x = jnp.asarray([1.0, -3.0])
        np.testing.assert_array_equal(np.asarray(layers.softcap(x, None)),
                                      np.asarray(x))


class TestRoPE:
    def test_rotation_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
        y = layers.apply_rope(x, pos, 10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    def test_relative_property(self):
        # <rope(q,i), rope(k,j)> depends only on i - j
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))

        def score(i, j):
            qi = layers.apply_rope(q, jnp.full((1, 1), i), 10_000.0)
            kj = layers.apply_rope(k, jnp.full((1, 1), j), 10_000.0)
            return float(jnp.sum(qi * kj))

        assert score(3, 1) == pytest.approx(score(7, 5), rel=1e-4)
        assert score(3, 1) != pytest.approx(score(3, 2), rel=1e-3)

    def test_position_zero_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 2, 16))
        y = layers.apply_rope(x, jnp.zeros((1, 1), jnp.int32), 10_000.0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


class TestMoEUnit:
    CFG = MoEConfig(n_experts=10, top_k=2, d_expert=16, capacity_factor=8.0)

    def _params(self, d=8):
        return moe.init_moe(jax.random.PRNGKey(0), d, self.CFG, gated=True,
                            dtype=jnp.float32)

    def test_padded_experts_never_routed(self):
        p = self._params()
        x = jax.random.normal(jax.random.PRNGKey(1), (40, 8))
        idx, w, token_mask, aux = moe.route(p["router"]["w"], x, self.CFG)
        assert int(jnp.max(idx)) < self.CFG.n_experts  # 10..15 are padding

    def test_local_slice_sums_to_full(self):
        # sum of per-slice outputs over disjoint expert ranges == full MoE
        p = self._params()
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 6, 8))
        full, aux_full = moe.moe_mlp(p, x, self.CFG, "silu")
        part = jnp.zeros_like(full)
        e_pad = self.CFG.padded_experts
        for start in range(0, e_pad, 4):
            p_slice = dict(p)
            for k in ("w_up", "w_gate", "w_out"):
                p_slice[k] = p[k][start:start + 4]
            y, _ = moe.moe_mlp(p_slice, x, self.CFG, "silu",
                               e_start=start, e_local=4)
            # subtract the shared expert added by every slice call
            if "shared" in p:
                y = y - layers.mlp(p["shared"], x, "silu")
            part = part + y
        if "shared" in p:
            part = part + layers.mlp(p["shared"], x, "silu")
        np.testing.assert_allclose(np.asarray(part), np.asarray(full),
                                   rtol=2e-3, atol=2e-3)

    def test_combine_weights_normalized(self):
        p = self._params()
        x = jax.random.normal(jax.random.PRNGKey(3), (16, 8))
        _, w, _, _ = moe.route(p["router"]["w"], x, self.CFG)
        np.testing.assert_allclose(np.asarray(jnp.sum(w, axis=-1)), 1.0,
                                   rtol=1e-5)

    def test_capacity_drops_tokens(self):
        cfg = MoEConfig(n_experts=4, top_k=1, d_expert=8,
                        capacity_factor=1.0)
        p = moe.init_moe(jax.random.PRNGKey(0), 8, cfg, gated=False,
                         dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(4), (32, 8))
        y_small, _ = moe.moe_mlp(p, x, cfg, "silu", capacity=1)
        y_big, _ = moe.moe_mlp(p, x, cfg, "silu", capacity=32)
        # with capacity 1 most tokens are dropped -> many zero rows
        zero_rows = float(jnp.mean(jnp.all(y_small == 0.0, axis=-1)))
        assert zero_rows > 0.5
        assert float(jnp.mean(jnp.all(y_big == 0.0, axis=-1))) < 0.2


class TestMLAUnit:
    def test_absorbed_decode_matches_expanded(self):
        """The absorbed decode path must equal the expanded attention on a
        one-token query (the identity the 57x cache shrink relies on)."""
        cfg = MLAConfig(q_lora_rank=16, kv_lora_rank=12, qk_nope_dim=8,
                        qk_rope_dim=4, v_head_dim=8)
        d, h, s, b = 32, 2, 6, 2
        p = mla.init_mla(jax.random.PRNGKey(0), d, h, cfg,
                         dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5

        # expanded full-sequence attention, last position
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        y_full = mla.mla_attention(p, x, pos, n_heads=h, cfg=cfg,
                                   rope_theta=10_000.0)

        # absorbed: prefill s-1 into the cache then decode token s-1
        cache = mla.init_mla_cache(b, s + 2, cfg, dtype=jnp.float32)
        for t in range(s - 1):
            c_t, r_t = mla._latents(p, x[:, t:t + 1],
                                    jnp.full((b, 1), t), cfg, 10_000.0,
                                    1e-6)
            cache["c_kv"] = cache["c_kv"].at[:, t].set(c_t[:, 0])
            cache["k_rope"] = cache["k_rope"].at[:, t].set(r_t[:, 0])
        y_dec, _ = mla.mla_decode(p, x[:, s - 1:s], cache,
                                  jnp.int32(s - 1), n_heads=h, cfg=cfg,
                                  rope_theta=10_000.0)
        np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                                   np.asarray(y_full[:, -1]),
                                   rtol=2e-3, atol=2e-3)


class TestAttentionMasks:
    def test_local_window_strict(self):
        m = attention.causal_mask(8, window=3)[0]
        for i in range(8):
            for j in range(8):
                expect = (j <= i) and (j > i - 3)
                assert bool(m[i, j]) == expect

    def test_gqa_head_mapping_matches_repeat(self):
        # GQA == MHA with kv heads repeated
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (1, 8, 4, 16))
        k = jax.random.normal(ks[1], (1, 8, 2, 16))
        v = jax.random.normal(ks[2], (1, 8, 2, 16))
        mask = attention.causal_mask(8)
        out_gqa = attention._sdpa(q, k, v, mask, None)
        out_mha = attention._sdpa(q, jnp.repeat(k, 2, axis=2),
                                  jnp.repeat(v, 2, axis=2), mask, None)
        np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                                   rtol=1e-5, atol=1e-5)
