"""Cell-plan tests: (S, B, K) <-> cell-axis round trips, padding mask
correctness, per-cell scenario policy/model codes, and isolation of
masked pad cells (they must never touch a real cell's Kahan mean or
hist_sketch bins — including in MIXED-policy grids)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import cellplan, distributions as dists, queueing, scenario
from repro.core.scenario import (CANCEL_ON_COMPLETE, SERVER_DEPENDENT,
                                 Variant)


class TestPlanCoordinates:
    def test_c_order_coords(self):
        plan = cellplan.make_cell_plan(2, 3, 2)
        assert plan.n_cells == plan.n_padded == 12
        assert plan.stacked_shape == (2, 3, 2)
        for c in range(12):
            s, b, k = c // 6, (c // 2) % 3, c % 2
            assert (int(plan.seed_idx[c]), int(plan.load_idx[c]),
                    int(plan.k_idx[c])) == (s, b, k)
        assert bool(plan.valid.all())

    def test_flatten_unflatten_roundtrip(self):
        plan = cellplan.make_cell_plan(2, 3, 2, pad_to=8)  # 12 -> 16
        x = jnp.arange(2 * 3 * 2 * 4.0).reshape(2, 3, 2, 4)
        flat = cellplan.flatten(plan, x)
        assert flat.shape == (16, 4)
        assert jnp.array_equal(cellplan.unflatten(plan, flat), x)

    def test_roundtrip_scalar_cells(self):
        plan = cellplan.make_cell_plan(3, 2, 2, pad_to=5)  # 12 -> 15
        x = jnp.arange(12.0).reshape(3, 2, 2)
        assert jnp.array_equal(
            cellplan.unflatten(plan, cellplan.flatten(plan, x)), x)

    def test_padding_mask(self):
        plan = cellplan.make_cell_plan(1, 3, 2, pad_to=8)  # 6 -> 8
        assert (plan.n_cells, plan.n_padded) == (6, 8)
        assert int(plan.valid.sum()) == 6
        assert not bool(plan.valid[6:].any())
        # pad cells alias cell 0's coordinates: finite, indexable work
        assert jnp.array_equal(plan.seed_idx[6:], jnp.zeros(2, jnp.int32))
        assert jnp.array_equal(plan.load_idx[6:], jnp.zeros(2, jnp.int32))
        assert jnp.array_equal(plan.k_idx[6:], jnp.zeros(2, jnp.int32))

    def test_divisible_needs_no_padding(self):
        plan = cellplan.make_cell_plan(2, 2, 2, pad_to=8)
        assert plan.n_cells == plan.n_padded == 8
        assert bool(plan.valid.all())

    def test_rejects_degenerate_axes(self):
        with pytest.raises(ValueError):
            cellplan.make_cell_plan(0, 3, 2)
        with pytest.raises(ValueError):
            cellplan.make_cell_plan(1, 1, 1, pad_to=0)

    def test_default_codes_are_paper(self):
        plan = cellplan.make_cell_plan(2, 3, 2)
        assert not bool(plan.policy_code.any())  # REPLICATE_ALL
        assert not bool(plan.model_code.any())   # IID

    def test_per_variant_codes_gather_and_pad(self):
        # 2 variants: paper (0,0) and cancel+server-dependent (1,1);
        # cells inherit their variant slot's codes, pad cells cell 0's.
        plan = cellplan.make_cell_plan(1, 3, 2, pad_to=8,  # 6 -> 8
                                       policies=[0, int(CANCEL_ON_COMPLETE)],
                                       models=[0, int(SERVER_DEPENDENT)])
        assert jnp.array_equal(plan.policy_code[:6], plan.k_idx[:6])
        assert jnp.array_equal(plan.model_code[:6], plan.k_idx[:6])
        assert not bool(plan.policy_code[6:].any())  # pad aliases cell 0
        assert not bool(plan.model_code[6:].any())

    def test_rejects_wrong_code_length(self):
        with pytest.raises(ValueError):
            cellplan.make_cell_plan(1, 2, 2, policies=[0])

    def test_default_dist_ids_zero(self):
        # homogeneous grids: every cell reads dist union slot 0
        plan = cellplan.make_cell_plan(2, 3, 2)
        assert not bool(plan.dist_id.any())

    def test_per_variant_dist_ids_gather_and_pad(self):
        # heterogeneous grid: variant slot j carries its system's
        # dist_id; cells inherit their slot's id, pads cell 0's.
        plan = cellplan.make_cell_plan(1, 3, 2, pad_to=8,  # 6 -> 8
                                       dist_ids=[0, 1])
        assert jnp.array_equal(plan.dist_id[:6], plan.k_idx[:6])
        assert not bool(plan.dist_id[6:].any())  # pad aliases cell 0

    def test_rejects_wrong_dist_id_length(self):
        with pytest.raises(ValueError):
            cellplan.make_cell_plan(1, 2, 2, dist_ids=[0])


class TestPadCellIsolation:
    @staticmethod
    def _run_padded_vs_unpadded(variants, with_shared=False,
                                dist_ids=None):
        """Run the chunk body with an unpadded (pad_to=1) and a padded
        (pad_to=8) plan for the same variants; return both end states."""
        cfg = queueing.SimConfig(n_servers=5, n_arrivals=1024)
        key = jax.random.PRNGKey(0)
        rhos = jnp.asarray([0.2, 0.3, 0.4])
        k_max = max(v.k if isinstance(v, Variant) else v for v in variants)
        gaps, servers, services = queueing._sample_sweep_inputs(
            key, dists.exponential(), cfg, k_max, 1,
            with_shared=with_shared)
        has_dists = dist_ids is not None
        if has_dists:
            # second system's service table stacks below the first
            services = jnp.concatenate(
                [services,
                 queueing._sample_sweep_services(key, dists.pareto(2.5),
                                                 cfg, k_max, 1,
                                                 with_shared, False)],
                axis=0)

        policies, models = scenario.variant_codes(variants)
        outs = {}
        for pad_to in (1, 8):  # 6 cells -> unpadded vs padded to 8
            plan = cellplan.make_cell_plan(1, 3, len(variants),
                                           pad_to=pad_to,
                                           policies=policies,
                                           models=models,
                                           dist_ids=dist_ids)
            (rates, k_mask, ovh, mix, pslow, sfac, pfail,
             delay) = queueing._plan_cell_params(plan, rhos, cfg,
                                                 variants)
            svc_idx = (plan.dist_id * 1 + plan.seed_idx if has_dists
                       else None)
            state = queueing._init_cell_state(plan, cfg, 128, True)
            state = queueing._sweep_chunk_cells(
                *state, gaps, servers, services, jnp.asarray(0),
                jnp.asarray(1024), jnp.asarray(100), plan.seed_idx,
                rates, k_mask, ovh, plan.policy_code, plan.model_code,
                mix, pslow, sfac, pfail, delay, svc_idx,
                n_servers=5, n_bins=128, block=512, has_dists=has_dists)
            outs[pad_to] = state
        return outs

    def _assert_valid_cells_match(self, outs):
        for i, name in enumerate(("free", "ssum", "comp", "cnt",
                                  "hist")):
            a, b = outs[1][i], outs[8][i][:6]
            assert jnp.array_equal(a, b), name

    def test_pad_cells_never_contribute(self):
        """Running the chunk body with a padded plan must leave every
        valid cell's Kahan state and histogram rows bit-identical to the
        unpadded run — pad cells do their (masked-off) work in their own
        rows only."""
        self._assert_valid_cells_match(self._run_padded_vs_unpadded((1, 2)))

    def test_pad_cells_never_contribute_mixed_policy(self):
        """Same isolation guarantee for a MIXED grid: a cancellation cell
        and a server-dependent cell next to a paper cell, with the extra
        shared-component service column sampled."""
        variants = (Variant(k=1),
                    Variant(k=2, policy=CANCEL_ON_COMPLETE,
                            service_model=SERVER_DEPENDENT, mix=0.7))
        self._assert_valid_cells_match(
            self._run_padded_vs_unpadded(variants, with_shared=True))

    def test_pad_cells_never_contribute_mixed_dists(self):
        """Same isolation guarantee for a HETEROGENEOUS grid: the k=2
        variant routes its service gather to a second system's table
        via the per-cell ``dist_id`` coordinate (pad cells alias cell
        0's dist_id, so they can never read past the dist union)."""
        variants = (Variant(k=1), Variant(k=2, dist_id=1))
        self._assert_valid_cells_match(
            self._run_padded_vs_unpadded(variants, dist_ids=[0, 1]))

    def test_finalize_drops_pad_cells(self):
        plan = cellplan.make_cell_plan(1, 3, 2, pad_to=8)
        ssum = jnp.arange(8.0)
        # poison the pad rows: they must not reach the summary
        ssum = ssum.at[6:].set(jnp.inf)
        hist = jnp.zeros((8, 128)).at[:, 3].set(10.0)
        hist = hist.at[6:].set(jnp.nan)
        cnt = jnp.full((8,), 10.0).at[6:].set(jnp.nan)  # poisoned pads
        out = queueing._finalize_summary(plan, ssum, cnt, hist, 10,
                                         (99.0,))
        assert out["mean"].shape == (1, 3, 2)
        assert bool(jnp.all(jnp.isfinite(out["mean"])))
        assert bool(jnp.all(jnp.isfinite(out["p99"])))
        assert jnp.array_equal(out["mean"],
                               (jnp.arange(6.0) / 10).reshape(1, 3, 2))
