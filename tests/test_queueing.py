"""Queueing-model validation against the paper's §2.1 results."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import analytic, distributions as dists, queueing, threshold

CFG = queueing.SimConfig(n_servers=20, n_arrivals=60_000)


def _mean(key, dist, rho, k, cfg=CFG, n_seeds=2):
    return float(queueing.mean_response(key, dist, jnp.asarray([rho]), cfg,
                                        k, n_seeds=n_seeds)[0])


class TestMM1:
    """k=1 exponential service must match the M/M/1 closed form."""

    @pytest.mark.parametrize("rho", [0.2, 0.5, 0.7])
    def test_mm1_mean(self, rho):
        key = jax.random.PRNGKey(0)
        sim = _mean(key, dists.exponential(), rho, k=1, n_seeds=3)
        expect = float(analytic.mm1_mean(rho))
        assert sim == pytest.approx(expect, rel=0.08)

    @pytest.mark.parametrize("rho", [0.1, 0.25])
    def test_replicated_mean_matches_min_of_two_mm1(self, rho):
        # Paper's approximation: each copy ~ M/M/1 at load 2*rho; response =
        # min of two samples => mean 1/(2(1-2rho)). Holds to ~few % at N=20.
        key = jax.random.PRNGKey(1)
        sim = _mean(key, dists.exponential(), rho, k=2, n_seeds=3)
        expect = float(analytic.mm1_replicated_mean(rho, 2))
        assert sim == pytest.approx(expect, rel=0.08)


class TestTheorem1:
    def test_exponential_threshold_is_one_third(self):
        key = jax.random.PRNGKey(2)
        est = threshold.threshold_bisect(key, dists.exponential(), CFG,
                                         iters=9, n_seeds=3)
        assert est == pytest.approx(analytic.THRESHOLD_EXPONENTIAL, abs=0.025)

    def test_replication_helps_below_threshold(self):
        key = jax.random.PRNGKey(3)
        g = queueing.replication_gain(key, dists.exponential(),
                                      jnp.asarray([0.15]), CFG, n_seeds=2)
        assert float(g[0]) > 0.0

    def test_replication_hurts_above_threshold(self):
        key = jax.random.PRNGKey(4)
        g = queueing.replication_gain(key, dists.exponential(),
                                      jnp.asarray([0.45]), CFG, n_seeds=2)
        assert float(g[0]) < 0.0


class TestConjecture1:
    def test_deterministic_threshold_near_paper_value(self):
        # Paper: ~25.82% for deterministic service under Poisson arrivals.
        key = jax.random.PRNGKey(5)
        est = threshold.threshold_bisect(key, dists.deterministic(), CFG,
                                         iters=9, n_seeds=3)
        assert est == pytest.approx(analytic.THRESHOLD_DETERMINISTIC, abs=0.02)

    @pytest.mark.parametrize("dist", [
        dists.exponential(),
        dists.pareto(2.5),
        dists.weibull(0.7),
        dists.two_point(0.5),
    ])
    def test_threshold_in_paper_band(self, dist):
        # Conjecture 1 + trivial upper bound: threshold in (~0.25, 0.5).
        key = jax.random.PRNGKey(6)
        est = threshold.threshold_grid(key, dist, CFG, n_seeds=2)
        assert 0.24 <= est <= 0.5


class TestVarianceMonotonicity:
    def test_heavier_tail_raises_threshold(self):
        # Fig 2(c): the two-point family's threshold grows with variance.
        key = jax.random.PRNGKey(7)
        lo = threshold.threshold_grid(key, dists.two_point(0.1), CFG)
        hi = threshold.threshold_grid(key, dists.two_point(0.9), CFG)
        assert hi > lo

    def test_tail_improvement_exceeds_mean_improvement(self):
        # "Replication improves the mean, but provides the greatest benefit
        # in the tail" (Fig 1b).
        key = jax.random.PRNGKey(8)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=120_000)
        r1 = queueing.simulate_grid(key, dists.pareto(2.1),
                                    jnp.asarray([0.2]), cfg, 1)
        r2 = queueing.simulate_grid(key, dists.pareto(2.1),
                                    jnp.asarray([0.2]), cfg, 2)
        s1 = queueing.summarize(r1, cfg)
        s2 = queueing.summarize(r2, cfg)
        mean_ratio = float(s1["mean"][0] / s2["mean"][0])
        tail_ratio = float(s1["p99.9"][0] / s2["p99.9"][0])
        assert mean_ratio > 1.0
        assert tail_ratio > mean_ratio


class TestClientOverhead:
    def test_overhead_lowers_threshold(self):
        key = jax.random.PRNGKey(9)
        base = queueing.SimConfig(n_servers=20, n_arrivals=60_000)
        pen = queueing.SimConfig(n_servers=20, n_arrivals=60_000,
                                 client_overhead=0.25)
        t0 = threshold.threshold_grid(key, dists.exponential(), base)
        t1 = threshold.threshold_grid(key, dists.exponential(), pen)
        assert t1 < t0
        # closed form for exponential: 1/(2(1-2r)) + c = 1/(1-r)
        expect = analytic.exponential_threshold(k=2, overhead=0.25)
        assert t1 == pytest.approx(expect, abs=0.03)

    def test_overhead_equal_to_mean_service_never_helps(self):
        # Fig 4 boundary: overhead = mean service time => no mean benefit at
        # any load, for any distribution.
        key = jax.random.PRNGKey(10)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=60_000,
                                 client_overhead=1.0)
        g = queueing.replication_gain(key, dists.pareto(2.1),
                                      jnp.asarray([0.05, 0.2, 0.4]), cfg)
        assert bool(jnp.all(g < 0.0))


class TestSimulatorInvariants:
    def test_response_at_least_service_min(self):
        key = jax.random.PRNGKey(11)
        cfg = queueing.SimConfig(n_servers=10, n_arrivals=5_000)
        resp = queueing.simulate(key, dists.exponential(), jnp.float32(0.3),
                                 cfg, k=2)
        assert bool(jnp.all(resp > 0.0))

    def test_crn_coupling_first_copy(self):
        # With the same key, k=1 and k=2 share arrivals + the first copy's
        # server/service draws. At near-zero load queueing interactions are
        # rare, so k=2 responses are (almost) pathwise <= k=1 responses —
        # a duplicate can only hurt a request via queueing behind OTHER
        # requests' duplicates, which vanishes as load -> 0.
        key = jax.random.PRNGKey(12)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=5_000)
        r1 = queueing.simulate(key, dists.pareto(2.1), jnp.float32(0.001),
                               cfg, k=1)
        r2 = queueing.simulate(key, dists.pareto(2.1), jnp.float32(0.001),
                               cfg, k=2)
        violations = float(jnp.mean(r2 > r1 + 1e-5))
        assert violations < 0.01
        assert float(jnp.mean(r2)) < float(jnp.mean(r1))

    def test_inputs_coupled_across_k(self):
        key = jax.random.PRNGKey(13)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=100)
        d = dists.exponential()
        g1, s1, v1 = queueing._sample_inputs(key, d, cfg, 1)
        g2, s2, v2 = queueing._sample_inputs(key, d, cfg, 3)
        assert bool(jnp.all(g1 == g2))
        assert bool(jnp.all(s1[:, 0] == s2[:, 0]))
        assert bool(jnp.all(v1[:, 0] == v2[:, 0]))
        # copies are distinct servers
        assert bool(jnp.all(s2[:, 0] != s2[:, 1]))
        assert bool(jnp.all(s2[:, 1] != s2[:, 2]))
        assert bool(jnp.all(s2[:, 0] != s2[:, 2]))
