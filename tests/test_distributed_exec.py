"""Distributed-execution numerics: the sharded model (shard_map MoE EP,
activation constraints, TP param shardings) must match single-device math.

Runs in a subprocess with 8 fake CPU devices (the XLA host-device override
must not leak into the main test process, whose other tests assume 1).
"""
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.distributed.ctx import ShardCtx
from repro.models import lm

assert jax.device_count() == 8
mesh = jax.make_mesh((2, 4), ("data", "model"))

for arch in ("deepseek-v3-671b", "granite-moe-3b-a800m"):
    cfg = get_smoke_config(arch)
    # pad experts to the 4-way model axis
    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}

    # single-device reference
    ref_loss, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)

    # sharded: batch over data, EP over model (tp recipe ctx)
    ctx = ShardCtx(mesh=mesh, batch=("data",), seq=None, kv_seq=None,
                   ep_axes=("model",), recipe="tp")
    # MoE expert weights must be sharded over model for the shard_map
    def spec_of(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        if any(n in ("w_up", "w_gate", "w_out") for n in names):
            stacked = "blocks" in names
            nd = leaf.ndim
            s = [None] * nd
            s[1 if stacked else 0] = "model"
            return NamedSharding(mesh, P(*s))
        return NamedSharding(mesh, P())
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    shardings = jax.tree_util.tree_unflatten(
        treedef, [spec_of(p, l) for p, l in flat])
    params_sh = jax.device_put(params, shardings)
    batch_sh = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    sh_loss, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b, ctx=ctx))(
        params_sh, batch_sh)

    err = abs(float(ref_loss) - float(sh_loss))
    rel = err / max(1.0, abs(float(ref_loss)))
    print(f"{arch}: ref={float(ref_loss):.5f} sharded={float(sh_loss):.5f} "
          f"rel_err={rel:.2e}")
    # bf16 reduction-order noise + per-shard capacity accounting: allow a
    # small relative tolerance
    assert rel < 2e-3, f"{arch} mismatch"

# smoke configs pad experts (deepseek 8 % 4 == 0; granite 10 -> 16 % 4 == 0)
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_sharded_moe_matches_single_device():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-2500:])
    assert "DISTRIBUTED_OK" in out.stdout
