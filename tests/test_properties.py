"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import analytic, distributions as dists, queueing
from repro.core.hedging import HedgePolicy
from repro.data.pipeline import DataConfig, UniformSource
from repro.training import grad_agg

SMALL = settings(max_examples=20, deadline=None,
                 suppress_health_check=list(HealthCheck))


class TestDistributionInvariants:
    @SMALL
    @given(alpha=st.floats(min_value=1.5, max_value=8.0))
    def test_pareto_unit_mean(self, alpha):
        d = dists.pareto(alpha)
        s = d.sample(jax.random.PRNGKey(0), (400_000,))
        # heavy tails converge slowly; generous tolerance scaled by alpha
        tol = 0.25 if alpha < 2.2 else 0.05
        assert abs(float(jnp.mean(s)) - 1.0) < tol
        assert bool(jnp.all(s > 0))

    @SMALL
    @given(p=st.floats(min_value=0.0, max_value=0.98))
    def test_two_point_unit_mean_exact(self, p):
        d = dists.two_point(p)
        s = d.sample(jax.random.PRNGKey(1), (100_000,))
        assert abs(float(jnp.mean(s)) - 1.0) < 0.02
        vals = np.unique(np.asarray(s))
        assert len(vals) <= 2

    @SMALL
    @given(k=st.floats(min_value=0.3, max_value=3.0))
    def test_weibull_positive_unit_mean(self, k):
        d = dists.weibull(k)
        s = d.sample(jax.random.PRNGKey(2), (200_000,))
        assert abs(float(jnp.mean(s)) - 1.0) < 0.1
        assert bool(jnp.all(s >= 0))


class TestQueueInvariants:
    @SMALL
    @given(rho=st.floats(min_value=0.05, max_value=0.45),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_responses_positive_and_at_least_service_floor(self, rho, seed):
        cfg = queueing.SimConfig(n_servers=10, n_arrivals=2_000)
        resp = queueing.simulate(jax.random.PRNGKey(seed),
                                 dists.deterministic(), jnp.float32(rho),
                                 cfg, k=2)
        # with unit deterministic service, every response >= 1 (service
        # time) up to float32 rounding of the arrival-time cumsum
        assert bool(jnp.all(resp >= 1.0 - 1e-3))

    @SMALL
    @given(rho=st.floats(min_value=0.05, max_value=0.3),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_more_replicas_lower_mean_at_low_load(self, rho, seed):
        # below the k=3 stability region, k=2 should not be worse than k=1
        # in the mean (CRN-paired, low load)
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=20_000)
        key = jax.random.PRNGKey(seed)
        r1 = queueing.simulate(key, dists.pareto(2.1), jnp.float32(rho),
                               cfg, k=1)
        r2 = queueing.simulate(key, dists.pareto(2.1), jnp.float32(rho),
                               cfg, k=2)
        assert float(jnp.mean(r2)) <= float(jnp.mean(r1)) * 1.05

    @SMALL
    @given(rho=st.floats(min_value=0.05, max_value=0.9))
    def test_mm1_mean_formula(self, rho):
        assert float(analytic.mm1_mean(rho)) >= 1.0
        # closed form is monotone in rho
        assert float(analytic.mm1_mean(rho)) <= float(
            analytic.mm1_mean(min(rho + 0.05, 0.95)))


class TestPolicyInvariants:
    @SMALL
    @given(util=st.floats(min_value=0.0, max_value=1.0),
           thr=st.floats(min_value=0.05, max_value=0.5),
           max_k=st.integers(min_value=1, max_value=4))
    def test_k_bounded_and_monotone_in_utilization(self, util, thr, max_k):
        p = HedgePolicy(max_k=max_k, threshold=thr)
        k = p.k_for(util)
        assert 1 <= k <= max_k
        # higher utilization can never increase k
        assert p.k_for(min(util + 0.2, 1.0)) <= k

    @SMALL
    @given(frac=st.floats(min_value=0.5, max_value=2.0))
    def test_large_overhead_disables_hedging(self, frac):
        p = HedgePolicy(max_k=3, threshold=0.4, client_overhead_frac=frac)
        assert p.k_for(0.0) == 1


class TestDataInvariants:
    @SMALL
    @given(step=st.integers(min_value=0, max_value=10_000),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_batch_pure_function_of_step(self, step, seed):
        from repro.configs import get_smoke_config
        cfg = get_smoke_config("nemotron-4-15b")
        d = DataConfig(seq_len=8, batch_size=2, seed=seed)
        a = UniformSource(cfg, d).batch_at(step)["tokens"]
        b = UniformSource(cfg, d).batch_at(step)["tokens"]
        np.testing.assert_array_equal(a, b)
        assert a.min() >= 0 and a.max() < cfg.vocab_size


class TestGradAggInvariants:
    @SMALL
    @given(n=st.integers(min_value=1, max_value=6),
           m=st.integers(min_value=1, max_value=6),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_masked_mean_bounded_by_extremes(self, n, m, seed):
        m = min(m, n)
        g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (n, 4))}
        order = jnp.asarray(np.random.default_rng(seed).permutation(n))
        mask = grad_agg.first_m_mask(order, m)
        out = grad_agg.masked_grad_mean(g, mask)
        lo = jnp.min(g["w"], axis=0) - 1e-5
        hi = jnp.max(g["w"], axis=0) + 1e-5
        assert bool(jnp.all(out["w"] >= lo)) and bool(jnp.all(out["w"] <= hi))
