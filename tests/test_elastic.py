"""Elastic scaling + hierarchical collectives tests."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.distributed import elastic

SRC = str(Path(__file__).resolve().parent.parent / "src")


class TestElasticPlanning:
    def test_full_pod(self):
        plan = elastic.plan_for(256)
        assert plan.mesh_shape == (16, 16)
        assert plan.dropped_devices == 0
        assert plan.global_batch_scale == 1.0

    def test_one_host_down(self):
        # lose 8 chips (one host): keep TP=16, shrink data to 15
        plan = elastic.plan_for(248)
        assert plan.mesh_shape == (15, 16)
        assert plan.dropped_devices == 8
        assert plan.global_batch_scale == pytest.approx(240 / 256)

    def test_heavy_degradation_halves_tp(self):
        assert elastic.best_mesh_shape(8, model_degree=16) == (1, 8)

    def test_monotone_in_health(self):
        scales = [elastic.plan_for(n).global_batch_scale
                  for n in (64, 128, 192, 256)]
        assert scales == sorted(scales)


class TestElasticReshard:
    def test_checkpoint_resharded_onto_smaller_mesh(self, tmp_path):
        """Save under 8 fake devices / (4,2) mesh, restore under (2,2) —
        the elastic-restart path (subprocess for the device override)."""
        script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import ckpt

mesh_a = jax.make_mesh((4, 2), ("data", "model"))
x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                   NamedSharding(mesh_a, P("data", "model")))
ckpt.save("{tmp_path}", 1, {{"x": x}})

mesh_b = jax.make_mesh((2, 2), ("data", "model"))
out = ckpt.restore("{tmp_path}", 1, {{"x": x}},
                   shardings={{"x": NamedSharding(mesh_b, P("model", None))}})
np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
assert out["x"].sharding.mesh.shape["data"] == 2
print("ELASTIC_OK")
"""
        out = subprocess.run(
            [sys.executable, "-c", script],
            env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
            capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "ELASTIC_OK" in out.stdout


class TestHierarchicalReduce:
    def test_matches_flat_mean(self):
        script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.collectives import hierarchical_grad_reduce

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

# per-(pod,data) distinct gradients, replicated over model
def per_rank_grads(pod, data):
    return {"w": jnp.full((3, 5), float(pod * 10 + data)),
            "b": jnp.arange(7, dtype=jnp.float32) * (pod + data + 1)}

# build the replicated-but-distinct array via shard_map-free device_put:
# simulate by computing inside shard_map from axis indices
def make_and_reduce():
    def f(_):
        p = jax.lax.axis_index("pod")
        d = jax.lax.axis_index("data")
        g = {"w": jnp.full((3, 5), (p * 10 + d).astype(jnp.float32)),
             "b": jnp.arange(7, dtype=jnp.float32) * (p + d + 1).astype(jnp.float32)}
        return g
    g = jax.shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P(),
                      check_vma=False)(jnp.zeros(1))
    return hierarchical_grad_reduce(g, mesh)

out = jax.jit(make_and_reduce)()
# expected flat mean over the 4 (pod, data) pairs
ws = [float(p * 10 + d) for p in range(2) for d in range(2)]
expect_w = np.full((3, 5), np.mean(ws))
expect_b = np.arange(7) * np.mean([p + d + 1 for p in range(2)
                                   for d in range(2)])
np.testing.assert_allclose(np.asarray(out["w"]), expect_w, rtol=1e-6)
np.testing.assert_allclose(np.asarray(out["b"]), expect_b, rtol=1e-6)
print("REDUCE_OK")
"""
        out = subprocess.run(
            [sys.executable, "-c", script],
            env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
            capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, (out.stdout[-800:], out.stderr[-2000:])
        assert "REDUCE_OK" in out.stdout


class TestElasticServing:
    def test_add_remove_replica_runtime(self):
        from repro.core.hedging import HedgePolicy
        from repro.serving.engine import SimulatedEngine
        from repro.serving.scheduler import HedgedScheduler
        sched = HedgedScheduler(
            [SimulatedEngine(lambda: 0.01, name="a")],
            policy=HedgePolicy(max_k=2, threshold=1.1))
        try:
            sched.add_replica(SimulatedEngine(lambda: 0.01, name="b"))
            assert len(sched.workers) == 2
            req = sched.submit(np.zeros(2, np.int32))
            assert req.completed_by in ("a", "b")
            assert sched.remove_replica("a")
            req = sched.submit(np.zeros(2, np.int32))
            assert req.completed_by == "b"
            assert not sched.remove_replica("nope")
        finally:
            sched.shutdown()
