"""Adaptive batched serving: policy-table interpolation, controller
adaptation physics (load steps move k across the crossing), hysteresis
bounds, deterministic trace replay, the batched service, and the
million-request acceptance run (marked slow)."""
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.core.hedging import LoadTracker
from repro.serving import replay
from repro.serving.controller import AdaptiveController, PolicyTable
from repro.serving.engine import SimulatedEngine
from repro.serving.metrics import TailSketch, Telemetry
from repro.serving.service import BatchedHedgedService, TransferBufferPool


def crossing_table(lo_tail=(5.0, 2.0), hi_tail=(5.0, 20.0)):
    """Two-variant (k=1, k=2) table with a crossing between rho 0.1
    and rho 0.5: k=2 wins low, k=1 wins high."""
    return PolicyTable(rhos=[0.1, 0.5], k=[1, 2], delay=[0.0, 0.0],
                       tail=[list(lo_tail), list(hi_tail)])


class TestPolicyTable:
    def test_interpolation_roundtrip(self):
        """Grid points read back exactly; midpoints are linear mixes;
        off-grid loads clamp to the edges."""
        t = PolicyTable(rhos=[0.1, 0.3, 0.7], k=[1, 2], delay=[0.0, 1.0],
                        tail=[[10.0, 4.0], [8.0, 6.0], [6.0, 30.0]])
        for i, rho in enumerate([0.1, 0.3, 0.7]):
            np.testing.assert_allclose(t.predict_tail(rho), t.tail[i])
        np.testing.assert_allclose(t.predict_tail(0.2),
                                   (t.tail[0] + t.tail[1]) / 2)
        np.testing.assert_allclose(t.predict_tail(0.0), t.tail[0])
        np.testing.assert_allclose(t.predict_tail(0.99), t.tail[2])
        assert t.best(0.1) == 1 and t.best(0.7) == 0
        assert t.entry(1) == (2, 1.0)

    def test_json_roundtrip(self):
        t = crossing_table()
        j = t.to_json()
        t2 = PolicyTable(j["rhos"], j["k"], j["delay"], j["tail"],
                         percentile=j["percentile"])
        np.testing.assert_array_equal(t.tail, t2.tail)
        assert t2.best(0.1) == t.best(0.1)

    def test_validation(self):
        with pytest.raises(ValueError):
            PolicyTable(rhos=[0.5, 0.1], k=[1], delay=[0.0], tail=[[1], [2]])
        with pytest.raises(ValueError):
            PolicyTable(rhos=[0.1], k=[1, 2], delay=[0.0], tail=[[1.0]])

    def test_from_engine_sweep(self):
        """The ONE mixed-grid queueing.run sweep wraps into a table
        whose variant axis is (k=1,) + one delayed-hedge per delay."""
        import jax
        from repro.core import distributions as dists
        from repro.core import queueing, threshold
        cfg = queueing.SimConfig(n_servers=4, n_arrivals=600)
        d = threshold.policy_table(jax.random.PRNGKey(0),
                                   dists.exponential(), cfg,
                                   rhos=[0.1, 0.5], ks=(1, 2),
                                   delays=(0.0, 1.0), n_seeds=1)
        t = PolicyTable.from_sweep(d)
        assert list(t.k) == [1, 2, 2]
        assert list(t.delay) == [0.0, 0.0, 1.0]
        assert t.tail.shape == (2, 3)
        assert np.all(np.isfinite(t.tail)) and np.all(t.tail > 0)


def drive(ctl, t0, n, gap_s, busy, k_dispatch):
    """Feed ``n`` arrivals spaced ``gap_s`` apart with a constant
    sampled busy fraction; returns the time after the last arrival."""
    t = t0
    for _ in range(n):
        k, _ = ctl.on_arrival(t, busy_fraction=busy)
        ctl.note_dispatch(k_dispatch if k_dispatch else k, t)
        t += gap_s
    return t


class TestAdaptiveController:
    def test_adaptation_physics(self):
        """Load step past the crossing -> k steps down within a window;
        step back -> k recovers."""
        ctl = AdaptiveController(crossing_table(), n_replicas=4,
                                 mean_service_s=1.0, window_s=50.0,
                                 hysteresis=0.1, decision_stride=8,
                                 initial_rho=0.1)
        assert ctl.current()[0] == 2
        # offered = rate * 1.0 / 4 = 0.1 at one arrival per 2.5 s
        t = drive(ctl, 0.0, 40, 2.5, busy=0.2, k_dispatch=2)
        assert ctl.current()[0] == 2
        # step up: one arrival per 0.5 s -> offered 0.5, past the
        # crossing; must step down within ~a window of the step
        t_step = t
        t = drive(ctl, t, 300, 0.5, busy=0.5, k_dispatch=1)
        assert ctl.current()[0] == 1
        down = next(h for h in ctl.history if h.k == 1)
        assert down.t - t_step <= 2 * 50.0
        # step back down -> recovers k=2
        t_back = t
        t = drive(ctl, t, 60, 2.5, busy=0.2, k_dispatch=2)
        assert ctl.current()[0] == 2
        up = next(h for h in ctl.history if h.t > t_back and h.k == 2)
        assert up.t - t_back <= 2 * 50.0

    def test_busy_spike_does_not_flip_policy(self):
        """One instantaneous full-pool snapshot among a stride of calm
        samples must not push rho_hat across the crossing (the busy
        term is stride-averaged, not sampled)."""
        ctl = AdaptiveController(crossing_table(), n_replicas=4,
                                 mean_service_s=1.0, window_s=50.0,
                                 hysteresis=0.1, decision_stride=16,
                                 initial_rho=0.1)
        t = 0.0
        for i in range(64):
            spike = 1.0 if i % 16 == 7 else 0.2
            ctl.on_arrival(t, busy_fraction=spike)
            ctl.note_dispatch(2, t)
            t += 2.5
        assert ctl.current()[0] == 2
        assert ctl.switches == 0

    def test_hysteresis_blocks_near_ties(self):
        """A candidate only ~5% better than the incumbent never causes
        a switch at 15% hysteresis; at 0 hysteresis it does."""
        # k=1 predicted 5% better than k=2 everywhere
        t = PolicyTable(rhos=[0.1, 0.5], k=[1, 2], delay=[0.0, 0.0],
                        tail=[[9.5, 10.0], [9.5, 10.0]])
        for hyst, expect_switch in ((0.15, False), (0.0, True)):
            ctl = AdaptiveController(t, n_replicas=4, mean_service_s=1.0,
                                     window_s=50.0, hysteresis=hyst,
                                     decision_stride=8, initial_rho=0.1)
            assert ctl.current()[0] == 1  # argmin at init ignores hysteresis
            ctl._variant = 1              # force the k=2 incumbent
            drive(ctl, 0.0, 40, 2.5, busy=0.2, k_dispatch=2)
            assert (ctl.switches > 0) == expect_switch, hyst

    def test_hysteresis_validation(self):
        with pytest.raises(ValueError):
            AdaptiveController(crossing_table(), 4, hysteresis=1.0)

    def test_no_jax_on_hot_path(self):
        """The serve-time decision stack is numpy-only: nothing in the
        controller's modules imports jax."""
        import repro.serving.controller as c
        import repro.serving.metrics as m
        import repro.serving.replay as r
        for mod in (c, m, r):
            assert "jax" not in vars(mod), mod.__name__


class TestTraces:
    def test_traces_deterministic_and_sorted(self):
        for make in (lambda s: replay.poisson_trace(500, 0.3, 4, seed=s),
                     lambda s: replay.mmpp_trace(500, 0.1, 0.6, 4, seed=s),
                     lambda s: replay.diurnal_trace(500, n_replicas=4,
                                                    seed=s)):
            a, b = make(3), make(3)
            np.testing.assert_array_equal(a.t, b.t)
            np.testing.assert_array_equal(a.segment, b.segment)
            assert np.all(np.diff(a.t) >= 0)
            assert not np.array_equal(a.t, make(4).t)

    def test_diurnal_rate_tracks_segments(self):
        tr = replay.diurnal_trace(40_000, rhos=(0.1, 0.5), n_replicas=8,
                                  seed=0)
        for s, rho in enumerate((0.1, 0.5)):
            ts = tr.t[tr.segment == s]
            rate = len(ts) / (ts[-1] - ts[0])
            assert rate == pytest.approx(rho * 8, rel=0.1)


class TestVirtualReplay:
    def test_same_seed_identical_records(self):
        """The CRN contract: same (trace, seed) -> bit-identical latency
        records, including through a (fresh) adaptive controller."""
        tr = replay.diurnal_trace(4_000, n_replicas=8, seed=2)
        a = replay.replay_virtual(tr, static_k=2, seed=9)
        b = replay.replay_virtual(tr, static_k=2, seed=9)
        np.testing.assert_array_equal(a.latency, b.latency)
        assert not np.array_equal(
            a.latency, replay.replay_virtual(tr, static_k=2,
                                             seed=10).latency)

        mk = lambda: AdaptiveController(crossing_table(), 8,
                                        window_s=40.0, decision_stride=16,
                                        initial_rho=0.15)
        c = replay.replay_virtual(tr, controller=mk(), seed=9)
        d = replay.replay_virtual(tr, controller=mk(), seed=9)
        np.testing.assert_array_equal(c.latency, d.latency)
        np.testing.assert_array_equal(c.k_planned, d.k_planned)

    def test_all_complete_and_hedging_helps_at_low_load(self):
        tr = replay.poisson_trace(4_000, 0.15, 8, seed=1)
        r1 = replay.replay_virtual(tr, static_k=1, seed=4)
        r2 = replay.replay_virtual(tr, static_k=2, seed=4)
        assert np.all(np.isfinite(r1.latency))
        assert np.all(np.isfinite(r2.latency))
        # the paper's low-load regime: duplication cuts the tail
        assert r2.tails()[1] < r1.tails()[1]

    def test_delayed_hedge_spares_work(self):
        """A hedge delay converts most duplicates into saved work at
        light load (the copy is only issued if the primary is slow)."""
        tr = replay.poisson_trace(4_000, 0.1, 8, seed=1)
        imm = replay.replay_virtual(tr, static_k=2, static_delay_s=0.0,
                                    seed=4)
        dly = replay.replay_virtual(tr, static_k=2, static_delay_s=2.0,
                                    seed=4)
        # immediate: every non-shed request duplicates at dispatch
        assert (imm.hedged | imm.shed).all()
        assert imm.hedged.mean() > 0.9
        assert 0 < dly.hedged.sum() < 0.5 * tr.n
        assert dly.loser_service < imm.loser_service

    def test_shed_watermark_bounds_duplication(self):
        tr = replay.poisson_trace(4_000, 0.9, 4, seed=1)
        r = replay.replay_virtual(tr, static_k=2, shed_watermark=0.8,
                                  seed=4)
        assert r.shed.sum() > 0
        assert np.all(r.k_planned[r.shed] == 1)

    def test_service_twin_knobs(self):
        """cancel_queued reclaims queued losers; the engine-faithful
        default serves every copy."""
        tr = replay.poisson_trace(4_000, 0.5, 8, seed=1)
        base = replay.replay_virtual(tr, static_k=2, seed=4)
        twin = replay.replay_virtual(tr, static_k=2, seed=4,
                                     cancel_queued=True,
                                     dup_low_priority=True)
        assert base.cancelled_queued == 0
        assert twin.cancelled_queued > 0
        # reclaiming losers strictly reduces congestion
        assert twin.tails()[1] <= base.tails()[1]


class FailingEngine:
    """A replica whose every ``generate`` errors (a crashed backend)."""

    def __init__(self, name="bad"):
        self.name = name

    def generate(self, tokens, max_new_tokens=16, check_cancel=None):
        raise RuntimeError("replica down")


class TestLoadTracker:
    def test_batch_stamp_does_not_explode_arrival_rate(self):
        """submit_batch stamps every row with ONE timestamp; a window
        of identical stamps must read as 'no rate measurable yet', and
        microscopic spans are floored — never a ~1e9/s rate that slams
        the controller to its max-load policy."""
        tr = LoadTracker(4, window_s=10.0)
        for _ in range(64):
            tr.note_arrival(5.0)
        assert tr.arrival_rate(5.0) == 0.0
        tr.note_arrival(5.001)
        # span floored at 5% of the window: bounded, not 65_000/s
        assert tr.arrival_rate(5.001) <= 65 / 0.5
        # an established span still measures the true rate
        tr2 = LoadTracker(4, window_s=10.0)
        for i in range(50):
            tr2.note_arrival(i * 0.1)
        assert tr2.arrival_rate(5.0) == pytest.approx(10.0, rel=0.01)


class TestTelemetryCancelPath:
    def test_note_cancel_only_annotates_live_records(self):
        """Cancellations land on the live record (the service reports
        them before the completion); after the record is folded, only
        the counter moves — no O(n) scan of the done list."""
        tel = Telemetry(window_s=1.0)
        tel.note_arrival(0, 0.0)
        tel.note_dispatch(0, 0.0, 2)
        tel.note_cancel(0, 0.5, 1)
        tel.note_completion(0, 0.5)
        tel.note_cancel(0, 0.6, 1)
        r = tel.records()[0]
        assert r.copies_cancelled == 1 and r.t_cancel == 0.5
        assert tel.counters["cancelled_copies"] == 2

    def test_note_failure_drops_live_record(self):
        tel = Telemetry()
        tel.note_arrival(1, 0.0)
        tel.note_failure(1, 0.2)
        assert tel.counters["failures"] == 1
        assert tel.counters["completions"] == 0
        assert tel.records() == []


class TestBatchedService:
    def _engines(self, n=4, mean_s=0.005, seed=0):
        rngs = [np.random.default_rng(seed + i) for i in range(n)]
        return [SimulatedEngine(lambda r=rngs[i]:
                                float(r.exponential(mean_s)), name=f"s{i}")
                for i in range(n)]

    def test_submit_batch_results_match_engine(self):
        svc = BatchedHedgedService(self._engines(), batch_sizes=(1, 4),
                                   max_seq=8, k=2, seed=0)
        try:
            prompts = [np.full(3, i, np.int32) for i in range(4)]
            reqs = svc.submit_batch(prompts, max_new_tokens=3)
            outs = [svc.result(r, timeout=10.0) for r in reqs]
            for p, o in zip(prompts, outs):
                expect = SimulatedEngine(lambda: 0.0).generate(p, 3)
                np.testing.assert_array_equal(o, expect)
            assert svc.telemetry.counters["completions"] == 4
        finally:
            svc.shutdown()

    def test_batch_size_fit_and_pool_reuse(self):
        pool = TransferBufferPool((2, 8), max_seq=4, buffers_per_size=1)
        assert pool.fit(1) == 2 and pool.fit(3) == 8
        with pytest.raises(ValueError):
            pool.fit(9)
        buf = pool.acquire(2)
        with pytest.raises(TimeoutError):
            pool.acquire(2, timeout=0.02)
        pool.release(buf)
        assert pool.acquire(2) is buf  # same memory recycled

    def test_nonblocking_submit_and_hedge_delay_timer(self):
        """submit() returns before completion; a delayed hedge only
        fires for slow requests (one shared timer thread, no
        per-request waiter)."""
        n_done = 0
        svc = BatchedHedgedService(self._engines(mean_s=0.05), k=2,
                                   hedge_delay_s=10.0, batch_sizes=(1,),
                                   max_seq=8, seed=0)
        try:
            t0 = time.monotonic()
            reqs = [svc.submit(np.zeros(2, np.int32), max_new_tokens=2)
                    for _ in range(8)]
            assert time.monotonic() - t0 < 0.05  # never blocked
            for r in reqs:
                svc.result(r, timeout=10.0)
            assert svc.stats["hedged"] == 0  # delay longer than service
        finally:
            svc.shutdown()

    def test_controller_steers_service(self):
        """With a table that says k=1 everywhere, the service stops
        duplicating; with k=2 everywhere it hedges every request."""
        for variant, want_hedged in ((0, False), (1, True)):
            table = PolicyTable(rhos=[0.1, 0.9], k=[1, 2],
                                delay=[0.0, 0.0],
                                tail=[[1.0, 9.0], [1.0, 9.0]]
                                if variant == 0 else
                                [[9.0, 1.0], [9.0, 1.0]])
            ctl = AdaptiveController(table, n_replicas=4,
                                     mean_service_s=0.005,
                                     decision_stride=4)
            svc = BatchedHedgedService(self._engines(), controller=ctl,
                                       batch_sizes=(1,), max_seq=8,
                                       seed=0)
            try:
                reqs = [svc.submit(np.zeros(2, np.int32),
                                   max_new_tokens=2) for _ in range(12)]
                for r in reqs:
                    svc.result(r, timeout=10.0)
                assert (svc.stats["hedged"] > 0) == want_hedged
            finally:
                svc.shutdown()

    def test_all_copies_failing_raises_instead_of_hanging(self):
        """A request whose every copy errors must surface promptly as a
        failure (result raises RuntimeError), not block its waiter
        forever and leak the pending entry."""
        for k in (1, 2):
            svc = BatchedHedgedService(
                [FailingEngine(f"b{i}") for i in range(2)],
                batch_sizes=(1,), max_seq=8, k=k, seed=0)
            try:
                req = svc.submit(np.zeros(2, np.int32), max_new_tokens=2)
                with pytest.raises(RuntimeError):
                    svc.result(req, timeout=5.0)
                assert req.failed and req.done_event.is_set()
                assert svc.stats["failed"] == 1
                assert svc.telemetry.counters["failures"] == 1
                assert not svc._pending
            finally:
                svc.shutdown()

    def test_all_copies_failing_with_delayed_hedge(self):
        """With a hedge parked in the timer heap, a failing primary
        must WAIT for the hedge (it may still win); once the hedge
        copies fail too, the request finalizes as failed."""
        svc = BatchedHedgedService(
            [FailingEngine(f"b{i}") for i in range(2)],
            batch_sizes=(1,), max_seq=8, k=2, hedge_delay_s=0.05, seed=0)
        try:
            req = svc.submit(np.zeros(2, np.int32), max_new_tokens=2)
            t0 = time.monotonic()
            with pytest.raises(RuntimeError):
                svc.result(req, timeout=10.0)
            # hedge fired at ~50 ms, then failed: no 10 s timeout burn
            assert time.monotonic() - t0 < 5.0
            assert svc.stats["hedged"] == 1 and svc.stats["failed"] == 1
        finally:
            svc.shutdown()

    def test_failure_masked_by_surviving_replica(self):
        """One crashed replica out of two: redundancy masks it and
        every request completes."""
        svc = BatchedHedgedService(
            [FailingEngine("bad")] + self._engines(n=1),
            batch_sizes=(1,), max_seq=8, k=2, seed=0)
        try:
            reqs = [svc.submit(np.zeros(2, np.int32), max_new_tokens=2)
                    for _ in range(6)]
            for r in reqs:
                assert svc.result(r, timeout=10.0)
            assert svc.stats["failed"] == 0
            assert svc.telemetry.counters["completions"] == 6
        finally:
            svc.shutdown()

    def test_telemetry_windows_and_sketch_geometry(self):
        """Telemetry quantiles come from the SAME log-bin geometry as
        the engine's hist_sketch kernel."""
        from repro.kernels.hist_sketch.ops import (DEFAULT_BINS, HIST_HI,
                                                   HIST_LO)
        sk = TailSketch()
        assert sk.n_bins == DEFAULT_BINS
        assert (sk.lo, sk.hi) == (HIST_LO, HIST_HI)
        rng = np.random.default_rng(0)
        vals = rng.exponential(1.0, 20_000) + 1e-3
        sk.fold(vals)
        # within a half log-bin of the exact empirical quantile
        exact = np.quantile(vals, 0.99)
        assert sk.quantile(99.0) == pytest.approx(exact, rel=0.02)

        tel = Telemetry(window_s=1.0)
        for rid, (t_arr, lat) in enumerate([(0.1, 0.5), (0.2, 1.0),
                                            (1.5, 2.0), (2.5, 0.25)]):
            tel.note_arrival(rid, t_arr)
            tel.note_dispatch(rid, t_arr, 2)
            tel.note_completion(rid, t_arr + lat)
        rows = tel.json_rows()
        assert [r["window"] for r in rows] == [0, 1, 2]
        assert rows[0]["count"] == 2
        prov = tel.provenance()
        assert prov["completions"] == 4 and prov["arrivals"] == 4


@pytest.mark.slow
def test_million_request_acceptance():
    """The PR's acceptance run: a 1M-request deterministic open-loop
    diurnal replay where the adaptive controller's p99 is no worse
    than the best static k at every segment and strictly better on at
    least one. (~1 min; CI tier-1 includes it, deselect with
    -m 'not slow'.)"""
    from benchmarks import serving_hedge
    table, _ = serving_hedge.build_policy_table(smoke=True)
    cmp = serving_hedge.adaptive_vs_static(table, 1_000_000)
    assert cmp["adaptive_no_worse"], cmp["p99_per_segment"]
    assert cmp["adaptive_strictly_better"], cmp["p99_per_segment"]
    assert cmp["replay"]["n"] == 1_000_000
