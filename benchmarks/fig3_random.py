"""Figure 3: thresholds of random unit-mean discrete service distributions
(uniform-simplex and Dirichlet(0.1) sampling). Paper: min observed threshold
stays above the deterministic ~0.26."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import distributions as dists
from repro.core import queueing, threshold

CFG = queueing.SimConfig(n_servers=20, n_arrivals=40_000)


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(2)
    rhos = jnp.linspace(0.1, 0.495, 14)
    for support in (2, 10, 100):
        for alpha, label in ((None, "uniform"), (0.1, "dirichlet0.1")):
            ths = []

            def work():
                for i in range(8):
                    k1, k2 = jax.random.split(
                        jax.random.fold_in(key, support * 100 + i))
                    d = dists.random_discrete(k1, support,
                                              dirichlet_alpha=alpha)
                    ths.append(threshold.threshold_grid(
                        k2, d, CFG, rhos=rhos, n_seeds=1))

            _, us = timed(work)
            rows.append((f"fig3/N={support}/{label}", us,
                         f"min={min(ths):.3f};max={max(ths):.3f}"))
    return rows
