"""Figure 3: thresholds of random unit-mean discrete service distributions
(uniform-simplex and Dirichlet(0.1) sampling). Paper: min observed threshold
stays above the deterministic ~0.26.

Each (support, sampler) cell draws 8 random distributions and estimates all
8 thresholds in ONE fused engine call via ``threshold_grid_batch``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import distributions as dists
from repro.core import queueing, threshold

CFG = queueing.SimConfig(n_servers=20, n_arrivals=40_000)


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(2)
    cfg = queueing.SimConfig(n_servers=20, n_arrivals=4_000) if smoke else CFG
    n_draws = 3 if smoke else 8
    rhos = jnp.linspace(0.1, 0.495, 14)
    for support in (2, 10) if smoke else (2, 10, 100):
        for alpha, label in ((None, "uniform"), (0.1, "dirichlet0.1")):
            def work():
                batch = []
                for i in range(n_draws):
                    k1, _ = jax.random.split(
                        jax.random.fold_in(key, support * 100 + i))
                    batch.append(dists.random_discrete(
                        k1, support, dirichlet_alpha=alpha))
                # one engine call for all 8 random distributions; k2 of the
                # pre-refactor split is now the shared sweep key
                _, k2 = jax.random.split(
                    jax.random.fold_in(key, support * 100))
                return threshold.threshold_grid_batch(
                    k2, batch, cfg, rhos=rhos, n_seeds=1)

            ths, us = timed(work)
            rows.append((f"fig3/N={support}/{label}", us,
                         f"min={min(ths):.3f};max={max(ths):.3f}"))
    return rows
