"""Figure 1: response time vs load, deterministic + Pareto(2.1) service,
k=1 vs k=2. Validates the thresholding effect and tail-dominant gains.

Both k values and all loads run in one fused ``queueing.sweep`` call per
distribution; percentiles come from the engine's streaming histogram
sketch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import distributions as dists
from repro.core import queueing

CFG = queueing.SimConfig(n_servers=20, n_arrivals=80_000)
LOADS = jnp.asarray([0.1, 0.2, 0.3, 0.4, 0.45])


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(0)
    cfg = queueing.SimConfig(n_servers=20, n_arrivals=4_000) if smoke else CFG
    for dist in (dists.deterministic(), dists.pareto(2.1)):
        def work(dist=dist):
            out = queueing.sweep(key, dist, LOADS, cfg, ks=(1, 2), n_seeds=1)
            jax.block_until_ready(out["mean"])
            return out

        out, us = timed(work)
        for i, rho in enumerate(LOADS):
            m1 = float(out["mean"][0, i, 0])
            m2 = float(out["mean"][0, i, 1])
            rows.append((f"fig1/{dist.name}/rho={float(rho):.2f}", us / 10,
                         f"mean_k1={m1:.3f};mean_k2={m2:.3f};"
                         f"gain={(m1 - m2) / m1 * 100:.1f}%"))
        # paper: "reducing the 99.9th percentile by 5x under Pareto"
        t1 = float(out["p99.9"][0, 1, 0])
        t2 = float(out["p99.9"][0, 1, 1])
        rows.append((f"fig1/{dist.name}/p999@0.2", us / 10,
                     f"p999_k1={t1:.2f};p999_k2={t2:.2f};"
                     f"ratio={t1 / t2:.2f}x"))
    return rows
