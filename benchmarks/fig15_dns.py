"""Figures 15-17: replicated DNS. Tail-fraction reductions (Fig 15), mean /
percentile reductions vs k (Fig 16), marginal cost-effectiveness vs the
16 ms/KB benchmark (Fig 17)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import analytic, dns


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    pop = dns.DNSPopulation()
    key = jax.random.PRNGKey(6)
    n = 20_000 if smoke else 400_000

    def work():
        ranking = dns.rank_servers(key, pop)
        lat = dns.sample_latencies(jax.random.PRNGKey(7), pop, n)
        return ranking, lat

    (ranking, lat), us = timed(work)
    r1 = dns.replicated_response(lat, ranking, 1)
    means = []
    for k in range(1, 11):
        rk = dns.replicated_response(lat, ranking, k)
        means.append(float(jnp.mean(rk)))
        if k in (2, 5, 10):
            f500 = float(jnp.mean(r1 > 500.0)) / max(
                float(jnp.mean(rk > 500.0)), 1e-9)
            f1500 = float(jnp.mean(r1 > 1500.0)) / max(
                float(jnp.mean(rk > 1500.0)), 1e-9)
            mean_red = (means[0] - means[-1]) / means[0] * 100
            p99_red = (float(jnp.percentile(r1, 99))
                       - float(jnp.percentile(rk, 99))) / \
                float(jnp.percentile(r1, 99)) * 100
            rows.append((f"fig15/k={k}", us / 10,
                         f"frac500_reduction={f500:.1f}x;"
                         f"frac1500_reduction={f1500:.1f}x;"
                         f"mean_reduction={mean_red:.0f}%;"
                         f"p99_reduction={p99_red:.0f}%"))
    marg = dns.marginal_savings_ms_per_kb(jnp.asarray(means), pop)
    total_kb = 9 * pop.query_bytes / 1024.0
    abs_ms_per_kb = (means[0] - means[-1]) / total_kb
    rows.append(("fig17/marginal", us / 10,
                 f"k2_ms_per_kb={float(marg[0]):.0f};"
                 f"k10_ms_per_kb={float(marg[-1]):.1f};"
                 f"absolute_k10={abs_ms_per_kb:.1f};"
                 f"benchmark={analytic.BENEFIT_THRESHOLD_MS_PER_KB}"))
    return rows
