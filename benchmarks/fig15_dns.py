"""Figures 15-17: replicated DNS as engine coordinates. Each replication
level k=1..10 is fitted once into a unit-mean quantile-table
``EmpiricalDist`` (``dns.empirical_k_dists`` — the fit of the min over
the top-k ranked servers, preserving the shared-component correlation),
and ALL TEN ride ONE heterogeneous ``queueing.run`` mixed grid as
single-variant scenarios (``ks=(1,)`` — the replication min is already
baked into each fit, so "k" is purely the ``dist_id`` coordinate).

Tail-fraction reductions (Fig 15) read straight off the fitted quantile
tables via ``EmpiricalDist.exceedance``; mean / p99 reductions vs k
(Fig 16) come from the engine summaries x each fit's ``.scale``;
marginal cost-effectiveness vs the 16 ms/KB benchmark (Fig 17) from the
fitted means."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import analytic, dns, queueing, scenario as scn_mod
from repro.core.scenario import Scenario
from repro.kernels.cell_update import resolve_kernel_mode

KS = tuple(range(1, 11))


def run(smoke: bool = False, mesh=None, kernel: str = "auto") -> list[Row]:
    rows: list[Row] = []
    pop = dns.DNSPopulation()
    key = jax.random.PRNGKey(6)
    resolved = resolve_kernel_mode(kernel)
    mesh_shape = tuple(mesh.devices.shape) if mesh is not None else None
    n = 20_000 if smoke else 400_000

    def work():
        fits = dns.empirical_k_dists(key, pop, KS, n_samples=n)
        # one mixed grid, ten systems: scenario k's cells route to fit k
        # via dist_id; rho ~ 0 approximates the paper's open-loop
        # (elastic-resource) measurement.
        scns = tuple(Scenario(dists=f, ks=(1,)) for f in fits)
        cfg = queueing.SimConfig(n_servers=10,
                                 n_arrivals=4_000 if smoke else 40_000)
        s = queueing.run(jax.random.PRNGKey(7), scns,
                         jnp.asarray([0.05]), cfg, n_seeds=1, mesh=mesh,
                         kernel=resolved)
        return fits, scns, s

    (fits, scns, s), us = timed(work)
    means = [float(s["mean"][0, 0, i]) * fits[i].scale
             for i in range(len(KS))]  # ms, one per k: variant i == fit i
    p99s = [float(s["p99"][0, 0, i]) * fits[i].scale for i in range(len(KS))]

    def tail_ratio(i: int, cutoff_ms: float) -> str:
        # When the replicated fit has NO sampled mass above the cutoff,
        # the true ratio is unbounded; report a lower bound at the fit's
        # resolution (one sample in n) instead of an epsilon artifact.
        num, den = fits[0].exceedance(cutoff_ms), fits[i].exceedance(cutoff_ms)
        if den < 1.0 / n:
            return f">={num * n:.0f}x"
        return f"{num / den:.1f}x"

    for k in (2, 5, 10):
        i = k - 1
        mean_red = (means[0] - means[i]) / means[0] * 100
        p99_red = (p99s[0] - p99s[i]) / p99s[0] * 100
        rows.append((f"fig15/k={k}", us / 10,
                     f"frac500_reduction={tail_ratio(i, 500.0)};"
                     f"frac1500_reduction={tail_ratio(i, 1500.0)};"
                     f"mean_reduction={mean_red:.0f}%;"
                     f"p99_reduction={p99_red:.0f}%",
                     mesh_shape, scn_mod.provenance(scns[i]), resolved))
    # fig17: marginal savings straight off the fitted per-k means (each
    # fit's scale IS its mean in ms)
    marg = dns.marginal_savings_ms_per_kb(
        jnp.asarray([f.scale for f in fits]), pop)
    total_kb = 9 * pop.query_bytes / 1024.0
    abs_ms_per_kb = (fits[0].scale - fits[-1].scale) / total_kb
    rows.append(("fig17/marginal", us / 10,
                 f"k2_ms_per_kb={float(marg[0]):.0f};"
                 f"k10_ms_per_kb={float(marg[-1]):.1f};"
                 f"absolute_k10={abs_ms_per_kb:.1f};"
                 f"benchmark={analytic.BENEFIT_THRESHOLD_MS_PER_KB}"))
    return rows
