"""Sweep-engine speedup: pre-refactor sequential path vs fused engine on the
Figure 2 threshold sweep (15 service-time families).

The "old" path is a faithful reimplementation of the pre-refactor code: one
jitted ``lax.scan`` per (seed, k) from Python — ``2 * n_seeds`` full passes
per distribution — with the distribution a static jit argument, so every
family recompiles both k-variants. The fused path estimates ALL 15
thresholds from one distribution-agnostic engine call
(``threshold.threshold_grid_batch``).

Emits per-family rows plus a ``sweep_engine/total`` row whose derived field
carries the end-to-end speedup (target: >= 5x) and the max |threshold
delta| between the two paths."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import distributions as dists
from repro.core import queueing, threshold

CFG = queueing.SimConfig(n_servers=20, n_arrivals=50_000)

FAMILY_PARAMS = {
    "pareto": (6.0, 3.0, 2.5, 2.2, 2.05),
    "weibull": (2.0, 1.0, 0.7, 0.5, 0.4),
    "two_point": (0.1, 0.5, 0.8, 0.95, 0.99),
}


def _entries():
    return [(fam, x, dists.FAMILIES[fam](x))
            for fam, params in FAMILY_PARAMS.items() for x in params]


def _threshold_grid_reference(key, dist, cfg, *, k=2, rhos=None, n_seeds=2):
    """The pre-refactor path, verbatim: python loops of ``simulate_grid``
    scans over seeds x {1, k}, then crossing interpolation."""
    if rhos is None:
        rhos = jnp.linspace(0.05, 0.495, 24)
    keys = jax.random.split(key, n_seeds)
    gains = []
    for s in range(n_seeds):
        r1 = queueing.simulate_grid(keys[s], dist, rhos, cfg, 1)
        rk = queueing.simulate_grid(keys[s], dist, rhos, cfg, k)
        gains.append(jnp.mean(queueing._warm(r1, cfg), -1)
                     - jnp.mean(queueing._warm(rk, cfg), -1))
    g = jnp.mean(jnp.stack(gains), axis=0)
    return threshold._interp_crossing(rhos, g)


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(1)
    entries = _entries()

    # --- old path: one scan per (family, seed, k), dist static in jit ----
    old_us = []
    t0 = time.perf_counter()
    old_ths = []
    for fam, x, dist in entries:
        t1 = time.perf_counter()
        old_ths.append(_threshold_grid_reference(key, dist, CFG, n_seeds=2))
        old_us.append((time.perf_counter() - t1) * 1e6)
    old_total = time.perf_counter() - t0

    # --- fused path: every family in ONE engine call ---------------------
    t0 = time.perf_counter()
    new_ths = threshold.threshold_grid_batch(
        key, [dist for _, _, dist in entries], CFG, n_seeds=2)
    new_total = time.perf_counter() - t0
    new_us = new_total * 1e6 / len(entries)

    max_delta = 0.0
    for (fam, x, _), t_old, t_new, us in zip(entries, old_ths, new_ths,
                                             old_us):
        max_delta = max(max_delta, abs(t_old - t_new))
        rows.append((f"sweep_engine/{fam}/x={x:g}", us,
                     f"old={t_old:.3f};fused={t_new:.3f};"
                     f"speedup={us / new_us:.1f}x"))
    speedup = old_total / new_total
    rows.append(("sweep_engine/total", old_total * 1e6,
                 f"old_s={old_total:.2f};fused_s={new_total:.2f};"
                 f"speedup={speedup:.1f}x;max_threshold_delta={max_delta:.4f}"))
    return rows
