"""Sweep-engine speedup + chunk-streaming benchmarks.

Part 1 — pre-refactor sequential path vs fused engine on the Figure 2
threshold sweep (15 service-time families). The "old" path is a faithful
reimplementation of the pre-refactor code: one jitted ``lax.scan`` per
(seed, k) from Python — ``2 * n_seeds`` full passes per distribution —
with the distribution a static jit argument, so every family recompiles
both k-variants. The fused path estimates ALL 15 thresholds from one
distribution-agnostic engine call (``threshold.threshold_grid_batch``).

Part 2 — chunk-streamed vs pre-sampled engine: same thresholds via
``chunk_size=4096`` (thresholds must match within the load-grid
interpolation tolerance), wall clock for both, and the peak
randomness-input footprint each path materializes (the chunked path's is
independent of ``n_arrivals``). Finishes with a large-``n_arrivals``
streamed sweep (2M arrivals by default) that the pre-sampled path would
need ~40 MB/seed of inputs for — the chunked engine holds ~80 KB/seed.

Part 3 — sharded cell-plan execution (``mesh`` argument, wired through
``run.py --devices``): the same chunked sweep and the Fig 2 threshold
batch run through ``repro.distributed.sweep_shard`` on a 1-D "cells"
mesh, recording whether the bit-identity contract against the unsharded
engine held (``bit_identical=``) and carrying the mesh shape as JSON
provenance (the contract itself is enforced by tier-1 / CI tests, not
by the benchmark — a violation must still produce rows).

Part 4 — fused cell-update kernel on vs off (``kernel`` argument, wired
through ``run.py --kernel``): the same chunked sweep through the scan
body (``kernel="off"``) and through the Pallas kernel path (the
RESOLVED requested mode; off-TPU ``"on"`` degrades to ``"interpret"``
so a measurement always exists), wall clock both ways, bit-identity
recorded. The ``sweep_engine/kernel_on_vs_off`` row's derived field
carries ``scan_s= / kernel_s= / speedup=`` so BENCH_*.json trajectories
hold the measured kernel speedup as provenance; its 6th row element
(the ``kernel`` JSON field) is the mode the kernel leg executed under.

Part 5 — sampling/compute pipeline on vs off (``queueing.run``'s
``pipeline`` argument, ``repro.core.chunkflow``): the large streamed
sweep with serial per-chunk sampling vs the double-buffered producer
thread + fused jitted sampler, wall clock both ways, bit-identity
recorded, and the run's sampling provenance
(``chunkflow.stats_provenance``) as the row's 7th element — under a
multi-process runtime the same row shows the per-host sampled-bytes
reduction.

Emits per-family rows plus ``sweep_engine/total`` (end-to-end old-vs-fused
speedup, target >= 5x), ``sweep_engine/chunked*``,
``sweep_engine/kernel_on_vs_off``, ``sweep_engine/pipeline_on_vs_off``
and (with a mesh) ``sweep_engine/sharded*`` rows."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import distributions as dists
from repro.core import queueing, scenario as scn_mod, threshold
from repro.core.scenario import Scenario
from repro.kernels.cell_update import resolve_kernel_mode

CFG = queueing.SimConfig(n_servers=20, n_arrivals=50_000)


def _paper_provenance(dist, ks=(1, 2)):
    """Scenario provenance of a legacy paper-default sweep row."""
    return scn_mod.provenance(Scenario.paper_default(dist, ks=ks))

FAMILY_PARAMS = {
    "pareto": (6.0, 3.0, 2.5, 2.2, 2.05),
    "weibull": (2.0, 1.0, 0.7, 0.5, 0.4),
    "two_point": (0.1, 0.5, 0.8, 0.95, 0.99),
}

CHUNK = 4096


def _entries(smoke: bool):
    params = ({fam: ps[:1] for fam, ps in FAMILY_PARAMS.items()} if smoke
              else FAMILY_PARAMS)
    return [(fam, x, dists.FAMILIES[fam](x))
            for fam, ps in params.items() for x in ps]


def _threshold_grid_reference(key, dist, cfg, *, k=2, rhos=None, n_seeds=2):
    """The pre-refactor path, verbatim: python loops of ``simulate_grid``
    scans over seeds x {1, k}, then crossing interpolation."""
    if rhos is None:
        rhos = jnp.linspace(0.05, 0.495, 24)
    keys = jax.random.split(key, n_seeds)
    gains = []
    for s in range(n_seeds):
        r1 = queueing.simulate_grid(keys[s], dist, rhos, cfg, 1)
        rk = queueing.simulate_grid(keys[s], dist, rhos, cfg, k)
        gains.append(jnp.mean(queueing._warm(r1, cfg), -1)
                     - jnp.mean(queueing._warm(rk, cfg), -1))
    g = jnp.mean(jnp.stack(gains), axis=0)
    return threshold._interp_crossing(rhos, g)


def _input_bytes(cfg: queueing.SimConfig, n: int, k_max: int = 2) -> int:
    """Bytes of pre-sampled randomness per seed for ``n`` arrivals: one f32
    gap + k_max i32 servers + k_max f32 services per arrival."""
    del cfg
    return n * 4 * (1 + 2 * k_max)


def _sharded_rows(key, cfg: queueing.SimConfig, mesh,
                  smoke: bool) -> list[Row]:
    """Sharded-vs-unsharded on the chunked sweep + threshold batch: wall
    clock both ways, bit-identity asserted, mesh shape as provenance."""
    from repro.distributed.sweep_shard import sweep_sharded

    shape = tuple(mesh.devices.shape)
    n_dev = mesh.devices.size
    rows: list[Row] = []

    rhos = jnp.linspace(0.1, 0.4, 3 if smoke else 8)
    n_seeds = 2
    d = dists.exponential()
    kw = dict(ks=(1, 2), n_seeds=n_seeds, chunk_size=CHUNK)
    t0 = time.perf_counter()
    un = queueing.sweep(key, d, rhos, cfg, **kw)
    jax.block_until_ready(un["mean"])
    un_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    sh = sweep_sharded(key, d, rhos, cfg, mesh=mesh, **kw)
    jax.block_until_ready(sh["mean"])
    sh_s = time.perf_counter() - t0
    # bit_identical=False in a row is the signal a contract violation
    # leaves behind — never raise here, or the diagnostic row (and the
    # module's other rows) would be dropped before reaching the JSON
    # artifact. Tier-1 / the multi-device CI job enforce the contract.
    bit = all(bool(jnp.array_equal(un[f], sh[f]))
              for f in ("mean", "p50", "p99"))
    cells = n_seeds * rhos.shape[0] * 2
    rows.append((f"sweep_engine/sharded/sweep_d{n_dev}", sh_s * 1e6,
                 f"cells={cells};devices={n_dev};bit_identical={bit};"
                 f"unsharded_s={un_s:.2f};sharded_s={sh_s:.2f}", shape,
                 _paper_provenance(d)))

    fams = [dists.pareto(2.5), dists.weibull(0.7), dists.two_point(0.8)]
    t0 = time.perf_counter()
    th_un = threshold.threshold_grid_batch(key, fams, cfg, n_seeds=2,
                                           chunk_size=CHUNK)
    un_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    th_sh = threshold.threshold_grid_batch(key, fams, cfg, n_seeds=2,
                                           chunk_size=CHUNK, mesh=mesh)
    sh_s = time.perf_counter() - t0
    bit = th_un == th_sh
    rows.append((f"sweep_engine/sharded/thresholds_d{n_dev}", sh_s * 1e6,
                 f"families={len(fams)};devices={n_dev};"
                 f"bit_identical={bit};unsharded_s={un_s:.2f};"
                 f"sharded_s={sh_s:.2f}", shape))
    return rows


def _kernel_rows(key, cfg: queueing.SimConfig, kernel: str,
                 smoke: bool) -> list[Row]:
    """Fused cell-update kernel on-vs-off: wall clock for the scan body
    and for the kernel path on the same chunked sweep, bit-identity
    recorded, measured speedup in the derived field (JSON provenance).
    """
    # off-TPU an "on"/"auto" request resolves to "off"/"interpret"; force
    # the interpreter leg in that case so the row always holds a real
    # kernel-path measurement.
    mode = resolve_kernel_mode(kernel)
    if mode == "off":
        mode = resolve_kernel_mode("on")  # "on" on TPU, else "interpret"
    scn = Scenario.paper_default(dists.exponential(), ks=(1, 2))
    rhos = jnp.linspace(0.1, 0.4, 3)
    kw = dict(n_seeds=2, chunk_size=CHUNK)
    kcfg = (cfg if smoke
            else queueing.SimConfig(n_servers=20, n_arrivals=20_000))

    t0 = time.perf_counter()
    off = queueing.run(key, scn, rhos, kcfg, kernel="off", **kw)
    jax.block_until_ready(off["mean"])
    scan_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    on = queueing.run(key, scn, rhos, kcfg, kernel=mode, **kw)
    jax.block_until_ready(on["mean"])
    kernel_s = time.perf_counter() - t0
    # like the sharded rows: record a violation, never raise
    bit = all(bool(jnp.array_equal(off[f], on[f]))
              for f in ("mean", "p50", "p99"))
    return [("sweep_engine/kernel_on_vs_off", kernel_s * 1e6,
             f"kernel={mode};arrivals={kcfg.n_arrivals};"
             f"scan_s={scan_s:.2f};kernel_s={kernel_s:.2f};"
             f"speedup={scan_s / kernel_s:.2f}x;bit_identical={bit}",
             None, scn_mod.provenance(scn), mode)]


def _pipeline_rows(key, kernel: str, smoke: bool) -> list[Row]:
    """Sampling/compute pipeline on-vs-off on the large streamed sweep
    (the ISSUE-9 acceptance row): wall clock both ways at 2M arrivals,
    bit-identity recorded in the derived field, the run's sampling
    provenance (``chunkflow.stats_provenance``) as the row's 7th
    element. Like the kernel row: record a violation, never raise."""
    from repro.core import chunkflow

    resolved = resolve_kernel_mode(kernel)
    big_m = 200_000 if smoke else 2_000_000
    big_cfg = queueing.SimConfig(n_servers=20, n_arrivals=big_m)
    scn = Scenario.paper_default(dists.exponential(), ks=(1, 2))
    rhos = jnp.asarray([0.3])
    kw = dict(n_seeds=1, chunk_size=CHUNK, kernel=resolved)

    t0 = time.perf_counter()
    off = queueing.run(key, scn, rhos, big_cfg, pipeline="off", **kw)
    jax.block_until_ready(off["mean"])
    off_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    on = queueing.run(key, scn, rhos, big_cfg, pipeline="on", **kw)
    jax.block_until_ready(on["mean"])
    on_s = time.perf_counter() - t0
    bit = all(bool(jnp.array_equal(off[f], on[f]))
              for f in ("mean", "p50", "p99"))
    return [("sweep_engine/pipeline_on_vs_off", on_s * 1e6,
             f"arrivals={big_m};chunk={CHUNK};off_s={off_s:.2f};"
             f"on_s={on_s:.2f};speedup={off_s / on_s:.2f}x;"
             f"bit_identical={bit}",
             None, scn_mod.provenance(scn), resolved,
             chunkflow.stats_provenance())]


def run(smoke: bool = False, mesh=None, kernel: str = "auto") -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(1)
    cfg = (queueing.SimConfig(n_servers=20, n_arrivals=5_000) if smoke
           else CFG)
    resolved = resolve_kernel_mode(kernel)  # stamp rows with the real mode
    entries = _entries(smoke)

    # --- old path: one scan per (family, seed, k), dist static in jit ----
    old_us = []
    t0 = time.perf_counter()
    old_ths = []
    for fam, x, dist in entries:
        t1 = time.perf_counter()
        old_ths.append(_threshold_grid_reference(key, dist, cfg, n_seeds=2))
        old_us.append((time.perf_counter() - t1) * 1e6)
    old_total = time.perf_counter() - t0

    # --- fused path: every family in ONE engine call ---------------------
    t0 = time.perf_counter()
    new_ths = threshold.threshold_grid_batch(
        key, [dist for _, _, dist in entries], cfg, n_seeds=2,
        kernel=resolved)
    new_total = time.perf_counter() - t0
    new_us = new_total * 1e6 / len(entries)

    max_delta = 0.0
    for (fam, x, _), t_old, t_new, us in zip(entries, old_ths, new_ths,
                                             old_us):
        max_delta = max(max_delta, abs(t_old - t_new))
        rows.append((f"sweep_engine/{fam}/x={x:g}", us,
                     f"old={t_old:.3f};fused={t_new:.3f};"
                     f"speedup={us / new_us:.1f}x"))
    speedup = old_total / new_total
    rows.append(("sweep_engine/total", old_total * 1e6,
                 f"old_s={old_total:.2f};fused_s={new_total:.2f};"
                 f"speedup={speedup:.1f}x;max_threshold_delta={max_delta:.4f}"))

    # --- chunked vs pre-sampled: thresholds must agree within the load
    # grid's interpolation tolerance (grid step ~0.02) ---------------------
    rhos = jnp.linspace(0.05, 0.495, 24)
    grid_step = float(rhos[1] - rhos[0])
    chunk_delta = 0.0
    for dist in (dists.exponential(), dists.pareto(2.2)):
        t0 = time.perf_counter()
        th_un = threshold.threshold_grid(key, dist, cfg, rhos=rhos,
                                         n_seeds=2, kernel=resolved)
        un_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        th_ch = threshold.threshold_grid(key, dist, cfg, rhos=rhos,
                                         n_seeds=2, chunk_size=CHUNK,
                                         kernel=resolved)
        ch_s = time.perf_counter() - t0
        chunk_delta = max(chunk_delta, abs(th_un - th_ch))
        rows.append((f"sweep_engine/chunked/{dist.name}", ch_s * 1e6,
                     f"unchunked={th_un:.3f};chunked={th_ch:.3f};"
                     f"delta={abs(th_un - th_ch):.4f};"
                     f"tol={grid_step:.3f};"
                     f"match={abs(th_un - th_ch) <= grid_step};"
                     f"unchunked_s={un_s:.2f};chunked_s={ch_s:.2f}",
                     None, _paper_provenance(dist), resolved))

    # --- streamed large-n_arrivals sweep: peak input memory is set by
    # chunk_size, not n_arrivals --------------------------------------------
    big_m = 200_000 if smoke else 2_000_000
    big_cfg = queueing.SimConfig(n_servers=20, n_arrivals=big_m)
    scn_big = Scenario.paper_default(dists.exponential(), ks=(1, 2))
    t0 = time.perf_counter()
    out = queueing.run(key, scn_big, jnp.asarray([0.3]), big_cfg,
                       n_seeds=1, chunk_size=CHUNK, kernel=resolved)
    jax.block_until_ready(out["mean"])
    big_s = time.perf_counter() - t0
    rows.append((f"sweep_engine/chunked_{big_m // 1000}k", big_s * 1e6,
                 f"chunk={CHUNK};mean_k1={float(out['mean'][0, 0, 0]):.4f};"
                 f"p99_k2={float(out['p99'][0, 0, 1]):.3f};"
                 f"input_kb_chunked={_input_bytes(big_cfg, CHUNK) // 1024};"
                 f"input_kb_presampled="
                 f"{_input_bytes(big_cfg, big_m) // 1024};"
                 f"arrivals_per_s={big_m / big_s:.0f}",
                 None, _paper_provenance(dists.exponential()), resolved))
    rows.append(("sweep_engine/chunked_total", 0.0,
                 f"max_threshold_delta={chunk_delta:.4f};"
                 f"interp_tol={grid_step:.3f}"))

    # --- fused cell-update kernel on vs off: measured speedup ------------
    rows.extend(_kernel_rows(key, cfg, kernel, smoke))

    # --- sampling/compute pipeline on vs off: measured overlap speedup --
    rows.extend(_pipeline_rows(key, kernel, smoke))

    # --- sharded cell-plan execution: bit-identity + mesh provenance ----
    if mesh is not None:
        rows.extend(_sharded_rows(key, cfg, mesh, smoke))
    return rows
