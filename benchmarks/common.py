"""Shared benchmark utilities: timing + CSV rows `name,us_per_call,derived`.

Rows may append provenance elements past the 3-tuple core:

  * 4th — the mesh shape tuple the row executed under (``None`` /
    absent for unsharded rows); ``run.py`` records it as the row's
    ``mesh`` field in the JSON artifact.
  * 5th — the row's scenario provenance (``repro.core.scenario
    .provenance``: policy, service model, mix, ks, overhead, dists) or
    ``None``; ``run.py`` records it as the row's ``scenario`` field so
    BENCH_*.json trajectories say WHICH point of the policy space they
    measured.
  * 6th — the RESOLVED cell-update kernel mode the row executed under
    (``"on"`` / ``"off"`` / ``"interpret"``, see
    ``repro.kernels.cell_update.resolve_kernel_mode``), or ``None`` for
    rows with no engine call; ``run.py`` records it as the row's
    ``kernel`` field so trajectories separate kernel-path from
    scan-path measurements.
  * 7th — the row's sampling/pipeline provenance
    (``repro.core.chunkflow.stats_provenance()``: pipeline on/off,
    per-host sampled rows and bytes vs the full block, locality factor,
    process count) or ``None``; ``run.py`` records it as the row's
    ``sampling`` field so the multi-host sampling reduction is visible
    in the perf artifact.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional, Union

Row = tuple  # (name, us, derived[, mesh[, scenario[, kernel[, sampling]]]])


def timed(fn: Callable[[], Any]) -> tuple[Any, float]:
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def row_provenance(row: Row) -> tuple[Optional[list], Union[dict, list,
                                                            None],
                                      Optional[str], Optional[dict]]:
    """(mesh, scenario, kernel, sampling) provenance of a row, tolerating
    the short forms."""
    mesh = list(row[3]) if len(row) > 3 and row[3] is not None else None
    scn = row[4] if len(row) > 4 else None
    kernel = row[5] if len(row) > 5 else None
    sampling = row[6] if len(row) > 6 else None
    return mesh, scn, kernel, sampling


def emit(rows: list[Row]) -> None:
    for row in rows:
        name, us, derived = row[:3]
        print(f"{name},{us:.1f},{derived}")
