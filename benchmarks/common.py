"""Shared benchmark utilities: timing + CSV rows `name,us_per_call,derived`.

Rows that executed under a device mesh may append a 4th element — the
mesh shape tuple — which ``run.py`` records as the row's ``mesh``
provenance in the JSON artifact (3-element rows get ``mesh: null``).
"""
from __future__ import annotations

import time
from typing import Any, Callable

Row = tuple[str, float, str]
ShardedRow = tuple[str, float, str, tuple[int, ...]]


def timed(fn: Callable[[], Any]) -> tuple[Any, float]:
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def emit(rows: list[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
