"""§3.1: TCP handshake duplication — expected savings vs the cost benchmark."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import analytic


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    m = analytic.TCPModel()
    key = jax.random.PRNGKey(8)
    n = 20_000 if smoke else 400_000

    def work():
        t1 = analytic.handshake_times(key, m, n, duplicated=False)
        t2 = analytic.handshake_times(key, m, n, duplicated=True)
        return t1, t2

    (t1, t2), us = timed(work)
    mean_save = float(jnp.mean(t1) - jnp.mean(t2))
    p995 = float(jnp.percentile(t1, 99.5) - jnp.percentile(t2, 99.5))
    p999 = float(jnp.percentile(t1, 99.9) - jnp.percentile(t2, 99.9))
    # 3 packets * 50 B = 150 B extra per handshake
    ms_per_kb = mean_save * 1e3 / (150 / 1024)
    rows.append(("tcp/handshake", us,
                 f"mean_saving_ms={mean_save * 1e3:.1f};"
                 f"first_order_ms={analytic.handshake_mean_saving(m) * 1e3:.1f};"
                 f"p995_saving_ms={p995 * 1e3:.0f};"
                 f"p999_saving_ms={p999 * 1e3:.0f};"
                 f"ms_per_kb={ms_per_kb:.0f};"
                 f"benchmark={analytic.BENEFIT_THRESHOLD_MS_PER_KB}"))
    return rows
