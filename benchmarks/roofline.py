"""Roofline table: live cell-update kernel measurement + dry-run artifacts.

Part 1 — the fused cell-update kernel (``repro.kernels.cell_update``),
MEASURED: the analytic cost model ``cell_update_costs`` (FLOPs, HBM
traffic, arithmetic intensity of one engine call) against the timed
wall clock of ``queueing.run`` with ``kernel="off"`` (scan body) and
with the kernel path (resolved ``"on"``; ``"interpret"`` off-TPU —
interpreter timings measure dispatch overhead, not kernel perf, and the
rows say which they are). Reports achieved GFLOP/s and achieved HBM
GB/s, their fractions of the TPU peaks, the ridge intensity
``PEAK_FLOPS / HBM_BW`` the kernel must beat to leave the memory-bound
regime, and the measured kernel-vs-scan speedup. ``smoke=True`` shrinks
the measured sweep so CI exercises the full path every push.

Part 2 — dry-run artifacts (EXPERIMENTS.md §Roofline), when present.
Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = collective_bytes / (chips x 50e9 B/s ICI link)
(the dry-run JSON stores PER-DEVICE flops/bytes — chips divide out).
Also reports MODEL_FLOPS = 6*N(_active)*D and the usefulness ratio.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.common import Row

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
RIDGE = PEAK_FLOPS / HBM_BW  # FLOP/byte where compute overtakes memory

_ROOT = Path(__file__).resolve().parent.parent
# prefer the optimized sweep; fall back to the baseline
DRYRUN_DIR = (_ROOT / "experiments/dryrun_opt"
              if (_ROOT / "experiments/dryrun_opt").exists()
              else _ROOT / "experiments/dryrun")


def analyze_record(rec: dict) -> dict | None:
    if not rec.get("ok") or "scaled_flops" not in rec:
        return None
    from repro.configs import base as cfgbase
    cfg = cfgbase.get_config(rec["arch"])
    shape = cfgbase.SHAPES[rec["shape"]]
    devices = rec["devices"]
    # per-device terms (JSON values are per-device already)
    t_compute = rec["scaled_flops"] / PEAK_FLOPS
    t_memory = rec["scaled_io_bytes"] / HBM_BW
    coll = sum(rec.get("collective_bytes", {}).values())
    t_coll = coll / LINK_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda x: x[1])[0]
    # model flops for this step kind
    n_params = (cfg.active_param_count if cfg.moe else cfg.param_count)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_params * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_params * tokens
    else:  # decode: one token per sequence
        model_flops = 2 * n_params * shape.global_batch
    model_flops_dev = model_flops / devices
    useful = model_flops_dev / max(rec["scaled_flops"], 1.0)
    return {
        "t_compute": t_compute, "t_memory": t_memory, "t_coll": t_coll,
        "dominant": dominant, "useful_ratio": useful,
        "model_flops_per_dev": model_flops_dev,
        "hbm_bytes_per_dev": rec.get("temp_size_in_bytes", 0),
    }


def _cell_update_rows(smoke: bool) -> list[Row]:
    """Measured roofline of the fused cell-update kernel vs the scan body.

    One row per path (scan / kernel): wall clock, analytic FLOPs and
    HBM bytes from ``cell_update_costs``, achieved GFLOP/s and GB/s
    with their peak fractions, plus a summary row with the measured
    speedup and the ridge intensity. Timings are steady-state (one
    warmup call compiles, the timed call reuses the jit cache)."""
    import jax
    import jax.numpy as jnp

    from repro.core import distributions as dists, queueing
    from repro.core.scenario import Scenario
    from repro.kernels.cell_update import (cell_update_costs,
                                           resolve_kernel_mode)

    n_arrivals = 5_000 if smoke else 20_000
    n_seeds, chunk = 2, 4_096
    cfg = queueing.SimConfig(n_servers=20, n_arrivals=n_arrivals)
    scn = Scenario.paper_default(dists.exponential(), ks=(1, 2))
    rhos = jnp.linspace(0.1, 0.4, 3)
    key = jax.random.PRNGKey(3)
    costs = cell_update_costs(
        n_cells=n_seeds * rhos.shape[0] * 2, n_servers=cfg.n_servers,
        k_max=2, n_arrivals=n_arrivals, n_bins=queueing.DEFAULT_BINS,
        n_seeds=n_seeds, chunk=chunk)

    kmode = resolve_kernel_mode("on")  # "on" on TPU, "interpret" off
    rows: list[Row] = []
    secs = {}
    for label, mode in (("scan", "off"), ("kernel", kmode)):
        def call():
            out = queueing.run(key, scn, rhos, cfg, n_seeds=n_seeds,
                               chunk_size=chunk, kernel=mode)
            jax.block_until_ready(out["mean"])
        call()  # warmup: compile outside the timed call
        t0 = time.perf_counter()
        call()
        s = time.perf_counter() - t0
        secs[label] = s
        gflops = costs["flops"] / s / 1e9
        gbs = costs["hbm_bytes"] / s / 1e9
        rows.append((f"roofline/cell_update/{label}", s * 1e6,
                     f"kernel={mode};flops={costs['flops']:.3e};"
                     f"hbm_bytes={costs['hbm_bytes']:.3e};"
                     f"achieved_gflops={gflops:.2f};"
                     f"peak_frac={gflops * 1e9 / PEAK_FLOPS:.2e};"
                     f"achieved_gbs={gbs:.2f};"
                     f"hbm_frac={gbs * 1e9 / HBM_BW:.2e}",
                     None, None, mode))
    rows.append(("roofline/cell_update/summary", secs["kernel"] * 1e6,
                 f"kernel={kmode};intensity={costs['intensity']:.1f};"
                 f"ridge={RIDGE:.1f};"
                 f"compute_bound={costs['intensity'] > RIDGE};"
                 f"scan_s={secs['scan']:.2f};kernel_s={secs['kernel']:.2f};"
                 f"speedup={secs['scan'] / secs['kernel']:.2f}x",
                 None, None, kmode))
    return rows


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = _cell_update_rows(smoke)
    if not DRYRUN_DIR.exists():
        return rows + [("roofline/dryrun_missing", 0.0,
                        "run repro.launch.dryrun first")]
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        a = analyze_record(rec)
        if a is None:
            rows.append((f"roofline/{f.stem}", 0.0,
                         f"SKIP({rec.get('error', 'no analysis')})"))
            continue
        rows.append((
            f"roofline/{f.stem}", rec.get("compile_s", 0) * 1e6,
            f"compute_s={a['t_compute']:.3e};memory_s={a['t_memory']:.3e};"
            f"collective_s={a['t_coll']:.3e};dominant={a['dominant']};"
            f"useful={a['useful_ratio']:.2f}"))
    return rows
