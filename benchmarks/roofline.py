"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = collective_bytes / (chips x 50e9 B/s ICI link)
(the dry-run JSON stores PER-DEVICE flops/bytes — chips divide out).
Also reports MODEL_FLOPS = 6*N(_active)*D and the usefulness ratio.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import Row

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_ROOT = Path(__file__).resolve().parent.parent
# prefer the optimized sweep; fall back to the baseline
DRYRUN_DIR = (_ROOT / "experiments/dryrun_opt"
              if (_ROOT / "experiments/dryrun_opt").exists()
              else _ROOT / "experiments/dryrun")


def analyze_record(rec: dict) -> dict | None:
    if not rec.get("ok") or "scaled_flops" not in rec:
        return None
    from repro.configs import base as cfgbase
    cfg = cfgbase.get_config(rec["arch"])
    shape = cfgbase.SHAPES[rec["shape"]]
    devices = rec["devices"]
    # per-device terms (JSON values are per-device already)
    t_compute = rec["scaled_flops"] / PEAK_FLOPS
    t_memory = rec["scaled_io_bytes"] / HBM_BW
    coll = sum(rec.get("collective_bytes", {}).values())
    t_coll = coll / LINK_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda x: x[1])[0]
    # model flops for this step kind
    n_params = (cfg.active_param_count if cfg.moe else cfg.param_count)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_params * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_params * tokens
    else:  # decode: one token per sequence
        model_flops = 2 * n_params * shape.global_batch
    model_flops_dev = model_flops / devices
    useful = model_flops_dev / max(rec["scaled_flops"], 1.0)
    return {
        "t_compute": t_compute, "t_memory": t_memory, "t_coll": t_coll,
        "dominant": dominant, "useful_ratio": useful,
        "model_flops_per_dev": model_flops_dev,
        "hbm_bytes_per_dev": rec.get("temp_size_in_bytes", 0),
    }


def run(smoke: bool = False) -> list[Row]:
    del smoke  # reads precomputed dry-run artifacts; nothing to shrink
    rows: list[Row] = []
    if not DRYRUN_DIR.exists():
        return [("roofline/missing", 0.0, "run repro.launch.dryrun first")]
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        a = analyze_record(rec)
        if a is None:
            rows.append((f"roofline/{f.stem}", 0.0,
                         f"SKIP({rec.get('error', 'no analysis')})"))
            continue
        rows.append((
            f"roofline/{f.stem}", rec.get("compile_s", 0) * 1e6,
            f"compute_s={a['t_compute']:.3e};memory_s={a['t_memory']:.3e};"
            f"collective_s={a['t_coll']:.3e};dominant={a['dominant']};"
            f"useful={a['useful_ratio']:.2f}"))
    return rows
