"""Policy-space figure: Shah et al.'s headline crossover on the scenario
engine — replication (k=2, replicate-all) helps exponential service at a
load below the paper's 1/3 threshold under i.i.d. service, but HURTS once
service times are server-dependent (the request-component ``mix`` -> 1
collapses the threshold toward ~0.28), while Joshi-style cancellation
(``CANCEL_ON_COMPLETE``) keeps replication profitable at every probed
load.

The whole (policy x model x mix x k x load) grid is ONE mixed-policy
``queueing.run`` call — every variant rides the same cell plan and the
same compiled chunk body — the ``lax.scan`` reference or the fused
cell-update kernel per ``kernel`` (wired through ``run.py --kernel``;
bit-identical either way) — sharded over ``mesh`` when ``run.py
--devices`` hands one in. Each row carries its scenario and the
RESOLVED kernel mode as JSON provenance (``benchmarks/run.py`` records
them per row).

Emits one row per scenario (CRN-paired gain at each probe load) plus a
``fig_policy_space/crossover`` summary row asserting the headline:
``gain_iid > 0 > gain_server_dependent`` at the probe load between the
two thresholds."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import distributions as dists, queueing, scenario as scn_mod
from repro.core.scenario import CANCEL_ON_COMPLETE, SERVER_DEPENDENT, Scenario
from repro.kernels.cell_update import resolve_kernel_mode

CFG = queueing.SimConfig(n_servers=20, n_arrivals=200_000)
CHUNK = 4_096
# 0.15: replication helps everywhere it is stable; 0.30: between the
# server-dependent threshold (~0.28 at mix=1) and the paper's 1/3.
RHOS = (0.15, 0.30)
MIXES = (0.5, 1.0)


def _scenarios() -> list[tuple[str, Scenario]]:
    d = dists.exponential()
    entries = [("iid", Scenario.paper_default(d, ks=(1, 2)))]
    for mx in MIXES:
        entries.append((f"server_dep_mix{mx:g}",
                        Scenario(dists=d, service_model=SERVER_DEPENDENT,
                                 mix=mx, ks=(1, 2))))
    entries.append(("cancel",
                    Scenario(dists=d, policy=CANCEL_ON_COMPLETE,
                             ks=(1, 2))))
    return entries


def run(smoke: bool = False, mesh=None, kernel: str = "auto") -> list[Row]:
    key = jax.random.PRNGKey(2)
    cfg = (queueing.SimConfig(n_servers=20, n_arrivals=6_000) if smoke
           else CFG)
    n_seeds = 2 if smoke else 3
    entries = _scenarios()
    rhos = jnp.asarray(RHOS)
    mesh_shape = tuple(mesh.devices.shape) if mesh is not None else None
    resolved = resolve_kernel_mode(kernel)

    t0 = time.perf_counter()
    out = queueing.run(key, tuple(s for _, s in entries), rhos, cfg,
                       n_seeds=n_seeds, percentiles=(), chunk_size=CHUNK,
                       mesh=mesh, kernel=resolved)
    jax.block_until_ready(out["mean"])
    total_us = (time.perf_counter() - t0) * 1e6
    m = jnp.mean(out["mean"], axis=0)  # (B, 2 * n_scenarios)

    rows: list[Row] = []
    gains = {}
    for j, (name, scn) in enumerate(entries):
        g = {r: float(m[i, 2 * j] - m[i, 2 * j + 1])
             for i, r in enumerate(RHOS)}
        gains[name] = g
        derived = ";".join(f"gain@rho{r:g}={v:+.4f}" for r, v in g.items())
        rows.append((f"fig_policy_space/{name}", total_us / len(entries),
                     derived, mesh_shape, scn_mod.provenance(scn),
                     resolved))

    # the headline: between the thresholds, IID helps and
    # server-dependence flips the sign; cancellation helps everywhere.
    rho_x = RHOS[-1]
    crossover = (gains["iid"][rho_x] > 0.0
                 > gains[f"server_dep_mix{MIXES[-1]:g}"][rho_x])
    rows.append(("fig_policy_space/crossover", total_us,
                 f"rho={rho_x};crossover={crossover};"
                 f"cancel_helps_everywhere="
                 f"{all(v > 0 for v in gains['cancel'].values())};"
                 f"scenarios={len(entries)};seeds={n_seeds}",
                 mesh_shape, None, resolved))
    return rows
