"""Figure 4: client-side overhead vs threshold load. Paper: overhead shrinks
the threshold; overhead ~ mean service kills the mean benefit entirely;
variable distributions are more forgiving.

All three distributions share one fused engine call per overhead level
(``threshold_grid_batch``); the overhead itself is a traced scalar, so the
whole 18-point sweep compiles the engine once."""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed
from repro.core import analytic
from repro.core import distributions as dists
from repro.core import queueing, threshold

DISTS = (dists.deterministic(), dists.exponential(), dists.pareto(2.1))


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(3)
    n_arrivals = 4_000 if smoke else 40_000
    for c in (0.0, 0.3) if smoke else (0.0, 0.05, 0.15, 0.3, 0.6, 1.0):
        cfg = queueing.SimConfig(n_servers=20, n_arrivals=n_arrivals,
                                 client_overhead=c)
        ths, us = timed(lambda cf=cfg: threshold.threshold_grid_batch(
            key, list(DISTS), cf, n_seeds=2))
        for dist, t in zip(DISTS, ths):
            extra = ""
            if dist.name == "exponential":
                expect = analytic.exponential_threshold(overhead=c)
                extra = f";closed_form={expect:.3f}"
            rows.append((f"fig4/{dist.name}/c={c:g}", us / len(DISTS),
                         f"threshold={t:.3f}{extra}"))
    return rows
