"""Figures 5-11: the disk-backed database study, reproduced by running the
paper-calibrated storage service-time models through the §2.1 queueing
simulator. One variant per paper figure.

Per variant: one fused ``queueing.sweep`` (k=1 and k=2 together, streaming
percentiles) plus one fused threshold sweep. The client overhead is a
traced scalar, so all seven variants share engine compilations."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import queueing, storage_sim, threshold

VARIANTS = {
    "fig5_base": storage_sim.StorageConfig(),
    "fig6_small_files": storage_sim.StorageConfig(mean_file_kb=0.04),
    "fig7_pareto_sizes": storage_sim.StorageConfig(file_dist="pareto"),
    "fig8_cache_001": storage_sim.StorageConfig(cache_disk_ratio=0.01),
    "fig9_ec2_variance": storage_sim.StorageConfig(seek_cv=1.5),
    "fig10_400kb": storage_sim.StorageConfig(mean_file_kb=400.0),
    "fig11_in_memory": storage_sim.StorageConfig(cache_disk_ratio=2.0),
}

LOADS = jnp.asarray([0.1, 0.2, 0.3, 0.4])


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(4)
    variants = (dict(list(VARIANTS.items())[:2]) if smoke else VARIANTS)
    for name, scfg in variants.items():
        dist, ms_scale, ovh = storage_sim.service_dist(scfg)
        cfg = queueing.SimConfig(n_servers=20,
                                 n_arrivals=4_000 if smoke else 60_000,
                                 client_overhead=ovh)

        def work(dist=dist, cfg=cfg):
            s = queueing.sweep(key, dist, LOADS, cfg, ks=(1, 2), n_seeds=1)
            t = threshold.threshold_grid(key, dist, cfg, n_seeds=1)
            return s, t

        (s, t), us = timed(work)
        m1 = float(s["mean"][0, 0, 0]) * ms_scale
        m2 = float(s["mean"][0, 0, 1]) * ms_scale
        p99_1 = float(s["p99"][0, 1, 0]) * ms_scale
        p99_2 = float(s["p99"][0, 1, 1]) * ms_scale
        p999_1 = float(s["p99.9"][0, 0, 0]) * ms_scale
        p999_2 = float(s["p99.9"][0, 0, 1]) * ms_scale
        rows.append((f"fig5-11/{name}", us,
                     f"threshold={t:.2f};mean@0.1={m1:.2f}->{m2:.2f}ms;"
                     f"p99@0.2={p99_1:.1f}->{p99_2:.1f}ms;"
                     f"p999@0.1_ratio={p999_1 / max(p999_2, 1e-9):.2f}x;"
                     f"overhead_frac={ovh:.3f}"))
    return rows
