"""Figures 5-11: the disk-backed database study — all seven paper
variants as ONE heterogeneous mixed grid.

Each variant's paper-calibrated storage model is fitted once into a
unit-mean quantile-table ``EmpiricalDist`` (``storage_sim
.empirical_service_dist``), wrapped in a single-dist ``Scenario`` with
its own client overhead, and the whole sequence runs through ONE
``queueing.run`` call: "which storage variant" is just the per-cell
``dist_id`` coordinate, so the seven variants share one compiled scan
(or kernel) instead of seven re-traces. Thresholds come from ONE
mixed-grid ``threshold.scenario_gain`` call over the load grid —
seven gain curves from one engine execution — read off per variant
with ``threshold.crossing_load``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import queueing, scenario as scn_mod, storage_sim, threshold
from repro.core.scenario import Scenario
from repro.kernels.cell_update import resolve_kernel_mode

VARIANTS = {
    "fig5_base": storage_sim.StorageConfig(),
    "fig6_small_files": storage_sim.StorageConfig(mean_file_kb=0.04),
    "fig7_pareto_sizes": storage_sim.StorageConfig(file_dist="pareto"),
    "fig8_cache_001": storage_sim.StorageConfig(cache_disk_ratio=0.01),
    "fig9_ec2_variance": storage_sim.StorageConfig(seek_cv=1.5),
    "fig10_400kb": storage_sim.StorageConfig(mean_file_kb=400.0),
    "fig11_in_memory": storage_sim.StorageConfig(cache_disk_ratio=2.0),
}

LOADS = jnp.asarray([0.1, 0.2, 0.3, 0.4])


def run(smoke: bool = False, mesh=None, kernel: str = "auto") -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(4)
    resolved = resolve_kernel_mode(kernel)
    mesh_shape = tuple(mesh.devices.shape) if mesh is not None else None
    variants = (dict(list(VARIANTS.items())[:2]) if smoke else VARIANTS)
    fits = [storage_sim.empirical_service_dist(scfg)
            for scfg in variants.values()]
    scns = tuple(Scenario(dists=dist, ks=(1, 2), client_overhead=ovh)
                 for dist, _, ovh in fits)
    cfg = queueing.SimConfig(n_servers=20,
                             n_arrivals=4_000 if smoke else 60_000)
    rhos = jnp.linspace(0.05, 0.495, 8 if smoke else 24)

    def work():
        # ONE mixed-grid sweep (percentiles) + ONE mixed-grid gain curve:
        # every storage variant is a dist_id coordinate of the same
        # compiled engine call.
        s = queueing.run(key, scns, LOADS, cfg, n_seeds=1, mesh=mesh,
                         kernel=resolved)
        g = threshold.scenario_gain(key, scns, rhos, cfg, n_seeds=1,
                                    mesh=mesh, kernel=resolved)
        return s, g

    (s, g), us = timed(work)
    for i, (name, (dist, ms_scale, ovh)) in enumerate(
            zip(variants, fits)):
        c1, c2 = 2 * i, 2 * i + 1  # paired (k=1, k=2) variant columns
        t = threshold.crossing_load(rhos, g[:, i])
        m1 = float(s["mean"][0, 0, c1]) * ms_scale
        m2 = float(s["mean"][0, 0, c2]) * ms_scale
        p99_1 = float(s["p99"][0, 1, c1]) * ms_scale
        p99_2 = float(s["p99"][0, 1, c2]) * ms_scale
        p999_1 = float(s["p99.9"][0, 0, c1]) * ms_scale
        p999_2 = float(s["p99.9"][0, 0, c2]) * ms_scale
        rows.append((f"fig5-11/{name}", us / len(fits),
                     f"threshold={t:.2f};mean@0.1={m1:.2f}->{m2:.2f}ms;"
                     f"p99@0.2={p99_1:.1f}->{p99_2:.1f}ms;"
                     f"p999@0.1_ratio={p999_1 / max(p999_2, 1e-9):.2f}x;"
                     f"overhead_frac={ovh:.3f}",
                     mesh_shape, scn_mod.provenance(scns[i]), resolved))
    return rows
