"""Figures 12-13: memcached (in-memory) study — client-side overhead makes
replication a net loss beyond ~10% load; the stub measurement bounds the
overhead at ~9% of mean service.

The gain curve comes from one fused ``queueing.sweep`` over
(seeds x loads x {k=1, k=2}); pass ``chunk_size`` to stream arrivals
through the chunked engine (None preserves the pre-sampled behavior)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import queueing, storage_sim


def run(smoke: bool = False,
        chunk_size: int | None = None) -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(5)
    dist, ms_scale, ovh = storage_sim.service_dist(storage_sim.MEMCACHED)
    loads = jnp.asarray([0.1, 0.3, 0.5, 0.7, 0.9])
    cfg = queueing.SimConfig(n_servers=20,
                             n_arrivals=4_000 if smoke else 60_000,
                             client_overhead=ovh)

    def work():
        return queueing.replication_gain(key, dist, loads, cfg, n_seeds=2,
                                         chunk_size=chunk_size)

    g, us = timed(work)
    for i, rho in enumerate(loads):
        rows.append((f"fig12/memcached/rho={float(rho):.1f}", us / 5,
                     f"gain_ms={float(g[i]) * ms_scale:.4f};"
                     f"helps={bool(g[i] > 0)}"))
    # fig13: the stub version quantifies the client-side overhead fraction
    rows.append(("fig13/stub_overhead", 0.0,
                 f"overhead_frac={ovh:.3f};mean_service_ms={ms_scale:.3f}"))
    return rows
