"""Figures 12-13: memcached (in-memory) study — client-side overhead makes
replication a net loss beyond ~10% load; the stub measurement bounds the
overhead at ~9% of mean service.

The memcached service model is fitted once into a unit-mean
quantile-table ``EmpiricalDist`` (``storage_sim.empirical_service_dist``)
and the gain curve comes from one ``threshold.scenario_gain`` engine
call over (seeds x loads x {k=1, k=2}); pass ``chunk_size`` to stream
arrivals through the chunked engine (None preserves the pre-sampled
behavior)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import queueing, scenario as scn_mod, storage_sim, threshold
from repro.core.scenario import Scenario
from repro.kernels.cell_update import resolve_kernel_mode


def run(smoke: bool = False, chunk_size: int | None = None,
        mesh=None, kernel: str = "auto") -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(5)
    resolved = resolve_kernel_mode(kernel)
    mesh_shape = tuple(mesh.devices.shape) if mesh is not None else None
    dist, ms_scale, ovh = storage_sim.empirical_service_dist(
        storage_sim.MEMCACHED)
    scn = Scenario(dists=dist, ks=(1, 2), client_overhead=ovh)
    loads = jnp.asarray([0.1, 0.3, 0.5, 0.7, 0.9])
    cfg = queueing.SimConfig(n_servers=20,
                             n_arrivals=4_000 if smoke else 60_000)

    def work():
        return threshold.scenario_gain(key, scn, loads, cfg, n_seeds=2,
                                       chunk_size=chunk_size, mesh=mesh,
                                       kernel=resolved)

    g, us = timed(work)
    for i, rho in enumerate(loads):
        rows.append((f"fig12/memcached/rho={float(rho):.1f}", us / 5,
                     f"gain_ms={float(g[i]) * ms_scale:.4f};"
                     f"helps={bool(g[i] > 0)}",
                     mesh_shape, scn_mod.provenance(scn), resolved))
    # fig13: the stub version quantifies the client-side overhead fraction
    rows.append(("fig13/stub_overhead", 0.0,
                 f"overhead_frac={ovh:.3f};mean_service_ms={ms_scale:.3f}"))
    return rows
