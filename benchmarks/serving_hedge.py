"""End-to-end hedged serving: the paper's technique running in OUR serving
scheduler (simulated replicas with heavy-tailed service)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core.hedging import HedgePolicy, LoadMeter
from repro.serving.engine import SimulatedEngine
from repro.serving.scheduler import HedgedScheduler


def _sampler(seed: int):
    rng = np.random.default_rng(seed)

    def sample():
        # ~4 ms typical, 60 ms tail 15% of the time (cache miss / GC pause)
        if rng.random() < 0.15:
            return 0.06
        return 0.004 * (0.5 + rng.random())

    return sample


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    n_reqs = 15 if smoke else 80
    for k in (1, 2):
        def work(k=k):
            engines = [SimulatedEngine(_sampler(i), name=f"s{i}")
                       for i in range(4)]
            sched = HedgedScheduler(
                engines, policy=HedgePolicy(max_k=k, threshold=1.1),
                meter=LoadMeter(alpha=0.0, init=0.0), seed=3)
            try:
                lats = [sched.submit(np.zeros(2, np.int32)).latency
                        for _ in range(n_reqs)]
            finally:
                sched.shutdown()
            return np.asarray(lats)

        lat, us = timed(work)
        rows.append((f"serving/k={k}", us / n_reqs,
                     f"mean_ms={lat.mean() * 1e3:.2f};"
                     f"p90_ms={np.percentile(lat, 90) * 1e3:.2f};"
                     f"p99_ms={np.percentile(lat, 99) * 1e3:.2f}"))
    return rows
