"""End-to-end hedged serving: open-loop trace replay, adaptive vs static.

Three rows:

  * ``serving/policy_table`` — the ONE mixed-grid ``queueing.run``
    sweep (``threshold.policy_table``) that precomputes the
    (rho x k x hedge-delay) operating surface the online controller
    interpolates.
  * ``serving/adaptive_vs_static`` — a seeded diurnal trace (night /
    morning / peak / night) replayed open loop through the virtual
    service twin, once per static k in {1, 2} and once with the
    ``AdaptiveController``; per-segment p99/p999 plus the acceptance
    booleans (adaptive no worse than the best static k at EVERY
    segment, strictly better on at least one) land in the row's
    provenance dict. All three runs share the trace and the (request,
    copy)-indexed service draws — paired comparisons (CRN).
  * ``serving/batched_live`` — a short wall-clock replay through the
    real ``BatchedHedgedService`` (threads, pooled transfer buffers,
    group dispatcher) with streaming ``Telemetry``.

The earlier closed-loop version of this benchmark (submit, wait,
repeat) could not see queueing regimes at all: its arrival rate
tracked service capacity, so "load" never existed. Open-loop replay
is the fix — arrivals never wait for completions.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row, timed
from repro.core import distributions as dists
from repro.core import queueing, threshold
from repro.serving import replay
from repro.serving.controller import AdaptiveController, PolicyTable
from repro.serving.engine import SimulatedEngine
from repro.serving.metrics import Telemetry
from repro.serving.service import BatchedHedgedService

N_REPLICAS = 8
# the diurnal day, in offered load: night / morning / peak / night.
# Morning sits at 0.30 — inside the band where the TABLE's winner is a
# DELAYED hedge, a policy neither static k can express, so the
# adaptive run beats both statics there structurally (not via some
# transient that washes out at scale).
SEGMENTS = (0.15, 0.30, 0.75, 0.15)
# service law: the paper's Fig 2(c) two-point family — 0.5 w.p. p,
# 5.5 w.p. 1-p (unit mean). Heavy enough that DELAYED hedging is the
# structural winner at mid load (hedge only the stragglers), which
# neither static k can express.
SERVICE_P = 0.9
SERVICE_HI = (1.0 - 0.5 * SERVICE_P) / (1.0 - SERVICE_P)


def two_point_sampler(rng, shape):
    """Numpy twin of ``dists.two_point(SERVICE_P)`` for the replay."""
    return np.where(rng.random(shape) < SERVICE_P, 0.5, SERVICE_HI)
# relative slack on the per-segment no-worse booleans: the replay and
# the table share physics but not randomness, and p99 reads from
# log-histogram buckets (~0.5% wide); 5% absorbs both without masking
# a real regression (wrong-k penalties are 2-10x, not 5%)
REL_TOL = 1.05

TABLE_RHOS = (0.05, 0.15, 0.25, 0.35, 0.45, 0.55, 0.65, 0.8)
TABLE_DELAYS = (0.0, 0.5, 1.0, 2.0)   # 0.0 = the paper's immediate k=2


def build_policy_table(smoke: bool, seed: int = 0
                       ) -> tuple[PolicyTable, float]:
    """One mixed-grid engine sweep -> PolicyTable (timed)."""
    cfg = queueing.SimConfig(n_servers=N_REPLICAS,
                             n_arrivals=3_000 if smoke else 40_000)
    key = jax.random.PRNGKey(seed)
    d, us = timed(lambda: threshold.policy_table(
        key, dists.two_point(SERVICE_P), cfg, rhos=list(TABLE_RHOS),
        ks=(1, 2), delays=TABLE_DELAYS, percentile=99.0, n_seeds=2))
    return PolicyTable.from_sweep(d), us


def _segment_p99s(res: replay.ReplayResult) -> np.ndarray:
    return np.asarray([res.tails(segment=s)[1]
                       for s in range(res.trace.n_segments)])


def adaptive_vs_static(table: PolicyTable, n_requests: int,
                       seed: int = 0) -> dict:
    """Replay the diurnal trace once per policy; paired by CRN."""
    trace = replay.diurnal_trace(n_requests, rhos=SEGMENTS,
                                 n_replicas=N_REPLICAS, seed=seed)
    static = {}
    for k in (1, 2):
        static[k] = replay.replay_virtual(trace, static_k=k, seed=seed + 1,
                                          svc_sampler=two_point_sampler)
    ctl = AdaptiveController(table, N_REPLICAS, mean_service_s=1.0,
                             window_s=40.0, hysteresis=0.1,
                             decision_stride=16, initial_rho=SEGMENTS[0])
    adaptive = replay.replay_virtual(trace, controller=ctl, seed=seed + 1,
                                     svc_sampler=two_point_sampler)

    p99 = {f"k{k}": _segment_p99s(r) for k, r in static.items()}
    p99["adaptive"] = _segment_p99s(adaptive)
    best_static = np.minimum(p99["k1"], p99["k2"])
    no_worse = bool(np.all(p99["adaptive"] <= REL_TOL * best_static))
    strictly_better = bool(np.any(p99["adaptive"] < best_static))
    return {
        "n_requests": int(n_requests),
        "segments": [r for r in adaptive.segment_tails()],
        "static_segments": {f"k{k}": r.segment_tails()
                            for k, r in static.items()},
        "p99_per_segment": {k: [float(x) for x in v]
                            for k, v in p99.items()},
        "rel_tol": REL_TOL,
        "adaptive_no_worse": no_worse,
        "adaptive_strictly_better": strictly_better,
        "controller": ctl.provenance(),
        "replay": adaptive.provenance(),
    }


def _sampler(seed: int):
    rng = np.random.default_rng(seed)

    def sample():
        # ~4 ms typical, 60 ms tail 15% of the time (cache miss / GC)
        if rng.random() < 0.15:
            return 0.06
        return 0.004 * (0.5 + rng.random())

    return sample


def batched_live(n_requests: int, seed: int = 3) -> dict:
    """Wall-clock smoke of the real batched service on a Poisson trace
    compressed to ~10 ms mean service."""
    mean_s = 0.0124  # mean of _sampler's mixture
    trace = replay.poisson_trace(n_requests, rho=0.2, n_replicas=4,
                                 mean_service_s=mean_s, seed=seed)
    engines = [SimulatedEngine(_sampler(seed + i), name=f"s{i}")
               for i in range(4)]
    svc = BatchedHedgedService(engines, batch_sizes=(1, 4), max_seq=8,
                               k=2, telemetry=Telemetry(window_s=0.25),
                               seed=seed)
    try:
        replay.replay_live(svc, trace, max_new_tokens=2)
    finally:
        svc.shutdown()
    return svc.telemetry.provenance()


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    table, table_us = build_policy_table(smoke)
    lo, hi = table.best(0.1), table.best(0.75)
    rows.append((
        "serving/policy_table", table_us,
        f"grid={len(table.rhos)}x{table.n_variants};"
        f"best@0.10=k{table.k[lo]}d{table.delay[lo]:g};"
        f"best@0.75=k{table.k[hi]}d{table.delay[hi]:g}",
        None, table.to_json()))

    n_requests = 20_000 if smoke else 1_000_000
    cmp, cmp_us = timed(lambda: adaptive_vs_static(table, n_requests))
    rows.append((
        "serving/adaptive_vs_static", cmp_us / n_requests,
        f"n={n_requests};"
        f"no_worse={cmp['adaptive_no_worse']};"
        f"strictly_better={cmp['adaptive_strictly_better']};"
        f"adaptive_p99=" + "/".join(
            f"{x:.2f}" for x in cmp["p99_per_segment"]["adaptive"]),
        None, cmp))

    n_live = 60 if smoke else 400
    live, live_us = timed(lambda: batched_live(n_live))
    rows.append((
        "serving/batched_live", live_us / n_live,
        f"n={n_live};completions={live['completions']};"
        f"hedged={live['hedged']};p99_ms={live['p99'] * 1e3:.1f}",
        None, live))
    return rows
