"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--only fig14`` runs one module.
``--json PATH`` additionally writes the rows as a JSON list (one object per
row: name / us_per_call / derived) so the perf trajectory is
machine-readable across PRs (e.g. ``--json BENCH_queueing.json``).
``--smoke`` runs every module at tiny sizes — CI uses ``--json --smoke``
to refresh the perf-trajectory artifact on every push without paying for
full-size sweeps.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to PATH as a JSON list")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: exercise every module quickly")
    args = ap.parse_args()

    from benchmarks import (fig1_queueing, fig2_threshold, fig3_random,
                            fig4_overhead, fig5_diskdb, fig12_memcached,
                            fig14_network, fig15_dns, roofline,
                            serving_hedge, sweep_engine, tab_tcp)
    modules = [sweep_engine, fig1_queueing, fig2_threshold, fig3_random,
               fig4_overhead, fig5_diskdb, fig12_memcached, fig14_network,
               fig15_dns, tab_tcp, serving_hedge, roofline]

    print("name,us_per_call,derived")
    collected: list[dict[str, object]] = []
    t0 = time.time()
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        try:
            for row_name, us, derived in mod.run(smoke=args.smoke):
                print(f"{row_name},{us:.1f},{derived}", flush=True)
                collected.append({"name": row_name,
                                  "us_per_call": round(us, 1),
                                  "derived": derived})
        except Exception as e:  # keep the harness going
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            collected.append({"name": f"{name}/ERROR", "us_per_call": 0,
                              "derived": f"{type(e).__name__}:{e}"})
            import traceback
            traceback.print_exc(file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(collected, f, indent=1)
        print(f"# wrote {len(collected)} rows to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
