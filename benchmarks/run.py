"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--only fig14`` runs one module
(repeatable: ``--only sweep_engine --only fig_policy_space``).
``--json PATH`` additionally writes the rows as a JSON list so the perf
trajectory is machine-readable across PRs (e.g. ``--json
BENCH_queueing.json``). Each JSON row records execution provenance next
to the measurement — ``backend`` / ``device_count`` / ``process_count``
of the runtime, the ``mesh`` shape the row ran under (``null`` for
unsharded rows), the ``scenario`` the row measured (policy / service
model / mix, from ``repro.core.scenario.provenance``; ``null`` for rows
that are not a queueing-scenario measurement), and the row's
``sampling`` provenance (``repro.core.chunkflow.stats_provenance``:
pipeline on/off, per-host sampled bytes vs the full block, locality
factor; ``null`` for non-engine rows) — so BENCH_*.json trajectories
are comparable across machines AND across points of the policy space,
and the multi-host sampling reduction is visible in the artifact.
``--smoke`` runs every module at tiny sizes — CI uses ``--json --smoke``
to refresh the perf-trajectory artifact on every push without paying for
full-size sweeps. ``--devices N`` builds an N-way ``"cells"`` sweep mesh
and hands it to mesh-aware modules (``sweep_engine`` plus the
empirical-system figures ``fig5_diskdb`` / ``fig12_memcached`` /
``fig15_dns`` / ``fig_cross_system``), which then emit sharded rows; on
CPU export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first.
``--kernel {auto,on,off}`` picks the engine's fused cell-update kernel
mode for kernel-aware modules (``sweep_engine``, ``fig_policy_space``;
``auto`` = kernel on TPU, scan elsewhere); each JSON row's ``kernel``
field records the RESOLVED mode the row actually executed under
(``on`` / ``off`` / ``interpret``, ``null`` for non-engine rows), so
trajectories never mix kernel-path and scan-path numbers silently.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
from pathlib import Path

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; make `from benchmarks import ...` work from any invocation.
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    help="substring filter on module names (repeatable)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to PATH as a JSON list")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: exercise every module quickly")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="run mesh-aware modules through the sharded "
                         "cell-plan engine on an N-device 'cells' mesh")
    ap.add_argument("--kernel", choices=("auto", "on", "off"),
                    default="auto",
                    help="fused cell-update kernel mode for kernel-aware "
                         "modules (auto: kernel on TPU, scan elsewhere)")
    args = ap.parse_args()

    import jax

    mesh = None
    if args.devices:
        # clamp to the largest DIVISOR of the visible device count:
        # make_sweep_mesh validates divisibility, and a mesh over a
        # non-divisor would reject the request anyway
        avail = jax.device_count()
        n = next(d for d in range(min(args.devices, avail), 0, -1)
                 if avail % d == 0)
        if n < args.devices:
            print(f"# --devices {args.devices} clamped to {n} "
                  f"(largest divisor of the {avail} visible devices; on "
                  f"CPU set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count={args.devices})",
                  file=sys.stderr)
        from repro.launch.mesh import make_sweep_mesh
        mesh = make_sweep_mesh(n)

    from benchmarks import (fig1_queueing, fig2_threshold, fig3_random,
                            fig4_overhead, fig5_diskdb, fig12_memcached,
                            fig14_network, fig15_dns, fig_cross_system,
                            fig_fault_masking, fig_policy_space, roofline,
                            serving_hedge, sweep_engine, tab_tcp)
    from benchmarks.common import row_provenance
    modules = [sweep_engine, fig_policy_space, fig1_queueing,
               fig2_threshold, fig3_random, fig4_overhead, fig5_diskdb,
               fig12_memcached, fig14_network, fig15_dns,
               fig_cross_system, tab_tcp, fig_fault_masking,
               serving_hedge, roofline]

    provenance = {"backend": jax.default_backend(),
                  "device_count": jax.device_count(),
                  "process_count": jax.process_count()}

    print("name,us_per_call,derived")
    collected: list[dict[str, object]] = []
    t0 = time.time()
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if args.only and not any(o in name for o in args.only):
            continue
        kwargs = {"smoke": args.smoke}
        params = inspect.signature(mod.run).parameters
        if mesh is not None and "mesh" in params:
            kwargs["mesh"] = mesh
        if "kernel" in params:
            kwargs["kernel"] = args.kernel
        try:
            for row in mod.run(**kwargs):
                # rows are (name, us, derived[, mesh[, scenario
                # [, kernel[, sampling]]]]) — see benchmarks.common
                row_name, us, derived = row[:3]
                (row_mesh, row_scenario, row_kernel,
                 row_sampling) = row_provenance(row)
                print(f"{row_name},{us:.1f},{derived}", flush=True)
                collected.append({"name": row_name,
                                  "us_per_call": round(us, 1),
                                  "derived": derived,
                                  "mesh": row_mesh,
                                  "scenario": row_scenario,
                                  "kernel": row_kernel,
                                  "sampling": row_sampling,
                                  **provenance})
        except Exception as e:  # keep the harness going
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            collected.append({"name": f"{name}/ERROR", "us_per_call": 0,
                              "derived": f"{type(e).__name__}:{e}",
                              "mesh": None, "scenario": None,
                              "kernel": None, "sampling": None,
                              **provenance})
            import traceback
            traceback.print_exc(file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(collected, f, indent=1)
        print(f"# wrote {len(collected)} rows to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
