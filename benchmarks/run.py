"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--only fig14`` runs one module.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    args = ap.parse_args()

    from benchmarks import (fig1_queueing, fig2_threshold, fig3_random,
                            fig4_overhead, fig5_diskdb, fig12_memcached,
                            fig14_network, fig15_dns, roofline,
                            serving_hedge, tab_tcp)
    modules = [fig1_queueing, fig2_threshold, fig3_random, fig4_overhead,
               fig5_diskdb, fig12_memcached, fig14_network, fig15_dns,
               tab_tcp, serving_hedge, roofline]

    print("name,us_per_call,derived")
    t0 = time.time()
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # keep the harness going
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            import traceback
            traceback.print_exc(file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
