"""Fault-masking figure: redundancy vs timeout-retry under failures, at
BOTH layers of the stack.

Engine part — the (fault rate x policy) grid is ONE mixed-policy
``queueing.run`` call. Each fault rate ``f`` splits across both axes of
the degradation model (blackholes ``p_fail=f/2`` and 8x stragglers
``p_slow=f/2``), served three ways: bare k=1 (no protection),
``HEDGE_AFTER_DELAY`` (k=2 redundancy plus Dean & Barroso's delay) and
``TIMEOUT_RETRY`` (non-redundant resend with capped backoff). The two
fault axes separate cleanly in the outputs: blackholes show up in the
COMPLETED fraction (bare loses ~f/2, hedging ~f^2/4, retry nothing —
its last in-budget attempt is blackhole-exempt), stragglers in the TAIL
(bare p99 inflates ~8x, both timed policies mask it back to ~delay +
clean). Every cell rides the same compiled chunk body (scan or fused
kernel per ``--kernel``), shards over ``mesh`` when ``run.py
--devices`` hands one in, and reports mean/p99/p999 plus completion.

Serving part — the chaos acceptance demo: four simulated replicas behind
``HedgedScheduler``, 25% of them (1 of 4) CRASHED mid-trace via
``FaultInjector``. Hedged serving must complete 100% of requests with a
p99 within 2x its no-fault baseline, while the timeout-retry baseline
degrades by at least the hedged gap — the ``chaos`` summary row records
exactly those booleans so the JSON artifact pins the claim per PR."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed
from repro.core import queueing, scenario as scn_mod
from repro.core.hedging import HedgePolicy, LoadMeter
from repro.core.scenario import Degradation, Policy, Scenario
from repro.kernels.cell_update import resolve_kernel_mode
from repro.serving.engine import SimulatedEngine
from repro.serving.faults import FaultInjector
from repro.serving.scheduler import HedgedScheduler, RetryPolicy

CFG = queueing.SimConfig(n_servers=10, n_arrivals=120_000)
CHUNK = 4_096
RHO = 0.2
# f splits evenly across blackholes (p_fail=f/2) and stragglers
# (p_slow=f/2): a copy is "bad" with probability f, so the both-copies-
# bad mass f^2 must stay < 1% for k=2's p99 to sit in the masked region
# (see tests/test_faults.py::TestStragglers)
FAULT_RATES = (0.0, 0.04, 0.08)
SLOW_FACTOR = 8.0
DELAY = 1.0            # units of mean service time, both policies
N_REQS = 80            # serving trace length (full run)


def _engine_grid() -> list[tuple[str, Scenario]]:
    from repro.core.distributions import exponential
    d = exponential()
    entries: list[tuple[str, Scenario]] = []
    for f in FAULT_RATES:
        kw = ({"degradation": Degradation(p_fail=f / 2, p_slow=f / 2,
                                          slow_factor=SLOW_FACTOR)}
              if f > 0 else {})
        entries.append((f"bare@f{f:g}",
                        Scenario(dists=d, ks=(1,), **kw)))
        entries.append((f"hedge@f{f:g}",
                        Scenario(dists=d, policy=Policy.HEDGE_AFTER_DELAY,
                                 delay=DELAY, ks=(2,), **kw)))
        entries.append((f"retry@f{f:g}",
                        Scenario(dists=d, policy=Policy.TIMEOUT_RETRY,
                                 delay=DELAY, ks=(2,), **kw)))
    return entries


def _serve_trace(n_reqs: int, retry: bool, crash: bool,
                 seed: int) -> dict[str, float]:
    """One scheduler trace: mid-trace, replica s1 (25% of the fleet) is
    crashed WITHOUT being removed — a blackhole the scheduler does not
    know about, masked only by redundancy (hedged) or deadlines
    (retry)."""
    inj = FaultInjector()
    engines = [inj.wrap(SimulatedEngine(
        (lambda r=np.random.default_rng(seed + i):
         0.004 * (0.5 + r.random())), name=f"s{i}")) for i in range(4)]
    sched = HedgedScheduler(
        engines, policy=HedgePolicy(max_k=2, threshold=1.1),
        meter=LoadMeter(alpha=0.0, init=0.0), tied_cancel=True,
        seed=seed,
        retry=RetryPolicy(deadline=0.05, backoff=2.0, max_retries=2)
        if retry else None)
    lats, done = [], 0
    try:
        for i in range(n_reqs):
            if crash and i == n_reqs // 2:
                inj.crash("s1")
            try:
                req = sched.submit(np.zeros(2, np.int32),
                                   max_new_tokens=2, timeout=5.0)
                lats.append(req.latency)
                done += 1
            except TimeoutError:
                pass
    finally:
        sched.shutdown()
    lats = np.asarray(lats) if lats else np.asarray([np.inf])
    return {"frac": done / n_reqs,
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
            "max_ms": float(lats.max() * 1e3),
            "retries": sched.stats["retries"],
            "hedged": sched.stats["hedged"]}


def run(smoke: bool = False, mesh=None, kernel: str = "auto") -> list[Row]:
    rows: list[Row] = []
    mesh_shape = tuple(mesh.devices.shape) if mesh is not None else None
    resolved = resolve_kernel_mode(kernel)

    # ---- engine: (fault rate x policy) in ONE mixed-grid run --------
    cfg = (queueing.SimConfig(n_servers=10, n_arrivals=6_000) if smoke
           else CFG)
    n_seeds = 2 if smoke else 3
    entries = _engine_grid()
    t0 = time.perf_counter()
    out = queueing.run(jax.random.PRNGKey(17),
                       tuple(s for _, s in entries),
                       jnp.asarray((RHO,)), cfg, n_seeds=n_seeds,
                       percentiles=(99.0, 99.9), chunk_size=CHUNK,
                       mesh=mesh, kernel=resolved)
    jax.block_until_ready(out["mean"])
    total_us = (time.perf_counter() - t0) * 1e6
    stats = {s: np.asarray(out[s]).mean(axis=0)[0] for s in
             ("mean", "p99", "p99.9", "completed")}
    count = float(np.asarray(out["count"]))
    tails, fracs = {}, {}
    for j, (name, scn) in enumerate(entries):
        tails[name] = float(stats["p99"][j])
        fracs[name] = float(stats["completed"][j]) / count
        rows.append((
            f"fig_fault_masking/{name}", total_us / len(entries),
            f"mean={stats['mean'][j]:.4f};p99={stats['p99'][j]:.4f};"
            f"p999={stats['p99.9'][j]:.4f};"
            f"completed_frac={fracs[name]:.4f}",
            mesh_shape, scn_mod.provenance(scn), resolved))
    fx = f"f{FAULT_RATES[-1]:g}"
    rows.append((
        "fig_fault_masking/engine", total_us,
        f"rho={RHO:g};delay={DELAY:g};"
        f"hedge_masks_tail={tails[f'hedge@{fx}'] < 0.6 * tails[f'bare@{fx}']};"
        f"retry_masks_tail={tails[f'retry@{fx}'] < 0.6 * tails[f'bare@{fx}']};"
        f"completion_order="
        f"{fracs[f'retry@{fx}'] >= fracs[f'hedge@{fx}'] > fracs[f'bare@{fx}']};"
        f"retry_completes_all={fracs[f'retry@{fx}'] == 1.0};"
        f"scenarios={len(entries)};seeds={n_seeds}",
        mesh_shape, None, resolved))

    # ---- serving: 25% of replicas crashed mid-trace -----------------
    n_reqs = 16 if smoke else N_REQS
    res = {}
    for tag, retry, crash in (("hedged_nofault", False, False),
                              ("hedged_crash25", False, True),
                              ("retry_nofault", True, False),
                              ("retry_crash25", True, True)):
        r, us = timed(lambda retry=retry, crash=crash:
                      _serve_trace(n_reqs, retry, crash, seed=11))
        res[tag] = r
        rows.append((f"fig_fault_masking/serve_{tag}", us / n_reqs,
                     f"completed_frac={r['frac']:.3f};"
                     f"p99_ms={r['p99_ms']:.2f};max_ms={r['max_ms']:.2f};"
                     f"retries={r['retries']};hedged={r['hedged']}"))

    # the acceptance booleans, pinned into the JSON artifact
    hedged_gap = (res["hedged_crash25"]["p99_ms"]
                  - res["hedged_nofault"]["p99_ms"])
    retry_gap = (res["retry_crash25"]["p99_ms"]
                 - res["retry_nofault"]["p99_ms"])
    completes = res["hedged_crash25"]["frac"] == 1.0
    within_2x = (res["hedged_crash25"]["p99_ms"]
                 <= 2.0 * res["hedged_nofault"]["p99_ms"])
    rows.append((
        "fig_fault_masking/chaos", 0.0,
        f"crashed_frac=0.25;hedged_completes_all={completes};"
        f"hedged_p99_within_2x={within_2x};"
        f"hedged_gap_ms={hedged_gap:.2f};retry_gap_ms={retry_gap:.2f};"
        f"retry_degrades_more={retry_gap >= hedged_gap};"
        f"masked={completes and within_2x and retry_gap >= hedged_gap}"))
    return rows
