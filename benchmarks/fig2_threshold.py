"""Figure 2: threshold load vs variance for Pareto / Weibull / two-point
families. Paper: thresholds rise with variance, bounded in (~0.26, 0.5).

All 15 families run through ONE fused sweep-engine call
(``threshold_grid_batch`` stacks them along the engine's seed axis)."""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed
from repro.core import distributions as dists
from repro.core import queueing, threshold

CFG = queueing.SimConfig(n_servers=20, n_arrivals=50_000)

FAMILIES = {
    "pareto": [(a, dists.pareto(a)) for a in (6.0, 3.0, 2.5, 2.2, 2.05)],
    "weibull": [(k, dists.weibull(k)) for k in (2.0, 1.0, 0.7, 0.5, 0.4)],
    "two_point": [(p, dists.two_point(p))
                  for p in (0.1, 0.5, 0.8, 0.95, 0.99)],
}


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(1)
    cfg = queueing.SimConfig(n_servers=20, n_arrivals=4_000) if smoke else CFG
    entries = [(fam, x, dist) for fam, fam_entries in FAMILIES.items()
               for x, dist in fam_entries]
    ths, us = timed(lambda: threshold.threshold_grid_batch(
        key, [dist for _, _, dist in entries], cfg, n_seeds=2))
    for (fam, x, dist), t in zip(entries, ths):
        var = "inf" if dist.variance is None else f"{dist.variance:.2f}"
        rows.append((f"fig2/{fam}/x={x:g}", us / len(entries),
                     f"threshold={t:.3f};variance={var}"))
    return rows
