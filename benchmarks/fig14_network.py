"""Figure 14: in-network replication of the first 8 packets of short flows
at strict low priority, on the k=6 fat-tree simulator.

The per-load rows compare raw FCT percentiles; the closing ``fct_table``
row instead fits both runs' short-flow FCT laws into engine-native
quantile tables (``netsim.empirical_fct_dist`` ->
``distributions.EmpiricalDist``) and reads the tail gain off the fitted
tables' ``exceedance`` — the same representation every other measured
system uses, so the netsim tails compose with the sweep engine."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, timed
from repro.core import netsim


def run(smoke: bool = False) -> list[Row]:
    rows: list[Row] = []
    n_flows = 200 if smoke else 500
    tail_cfgs = None
    for load in (0.25,) if smoke else (0.1, 0.25, 0.4, 0.6, 0.8):
        base = netsim.NetConfig(n_flows=n_flows, load=load, replicate_first=0,
                                elephant_frac=0.12, elephant_pkts=400,
                                seed=7)
        rep = dataclasses.replace(base, replicate_first=8)

        def work(b=base, r=rep):
            f0, s0, sh0, _ = netsim.flow_completion_times(b)
            f1, s1, sh1, _ = netsim.flow_completion_times(r)
            return f0[sh0], f1[sh1], f0[~sh0], f1[~sh1]

        (a, b, ea, eb), us = timed(work)
        mean_gain = (a.mean() - b.mean()) / a.mean() * 100
        p90_gain = (np.percentile(a, 90) - np.percentile(b, 90)) / \
            max(np.percentile(a, 90), 1) * 100
        p99_gain = (np.percentile(a, 99) - np.percentile(b, 99)) / \
            max(np.percentile(a, 99), 1) * 100
        eleph = (ea.mean() - eb.mean()) / ea.mean() * 100
        rows.append((f"fig14/load={load:g}", us,
                     f"short_mean_gain={mean_gain:.1f}%;"
                     f"p90_gain={p90_gain:.1f}%;p99_gain={p99_gain:.1f}%;"
                     f"elephant_delta={eleph:.2f}%"))
        if load == 0.25:  # the paper's headline load
            tail_cfgs = (base, rep)

    # quantile-table tails at the headline load: P[FCT > p99_baseline]
    # before/after replication, read off the fitted EmpiricalDists
    if tail_cfgs is not None:
        def fit(bc=tail_cfgs[0], rc=tail_cfgs[1]):
            return (netsim.empirical_fct_dist(bc),
                    netsim.empirical_fct_dist(rc))

        (d0, d1), us = timed(fit)
        x99 = float(np.quantile(
            np.asarray(d0.table, np.float64) * d0.scale, 0.99))
        rows.append(("fig14/fct_table", us,
                     f"knots={len(d0.table)};mean_slots={d0.scale:.1f};"
                     f"rep_mean_slots={d1.scale:.1f};"
                     f"exceed_p99_base={d0.exceedance(x99):.4f};"
                     f"exceed_p99_rep={d1.exceedance(x99):.4f}"))
    return rows
