"""Cross-system crossover: disk-backed DB vs memcached vs DNS on ONE
mixed grid.

The paper's three measured systems answer the same question at three
points of the service-time spectrum: where is the load threshold below
which replication helps? Here each system is fitted once into a
unit-mean quantile-table ``EmpiricalDist`` (storage and memcached via
``storage_sim.empirical_service_dist``, DNS via the k=1 fit of
``dns.empirical_k_dists``) and all three ride ONE
``threshold.scenario_gain`` engine call as a heterogeneous mixed grid —
"which system" is the per-cell ``dist_id`` coordinate, so the three
help/hurt curves come out of a single compiled sweep, CRN-paired within
each system. ``threshold.crossing_load`` reads each system's crossover
off its gain column, and the summary row orders them: heavy-tailed disk
crosses latest, overhead-dominated memcached earliest.

A parity row re-runs the (smoke-sized) grid through the interpreted
Pallas cell-update kernel and records bit-identity with the scan body —
the mixed-grid analogue of ``sweep_engine/kernel_on_vs_off``."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.core import dns, queueing, scenario as scn_mod, storage_sim, \
    threshold
from repro.core.scenario import Scenario
from repro.kernels.cell_update import resolve_kernel_mode

SYSTEMS = ("disk", "memcached", "dns")


def _fits():
    """(dist, ms_scale, overhead) per system, fitted once."""
    disk = storage_sim.empirical_service_dist(storage_sim.StorageConfig())
    mem = storage_sim.empirical_service_dist(storage_sim.MEMCACHED)
    d = dns.empirical_k_dists(jax.random.PRNGKey(6), dns.DNSPopulation(),
                              ks=(1,))[0]
    # replicating a DNS query costs one extra ~0.5 KB packet, not a
    # client-side protocol handshake: no overhead term.
    return [disk, mem, (d, d.scale, 0.0)]


def run(smoke: bool = False, mesh=None, kernel: str = "auto") -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(8)
    resolved = resolve_kernel_mode(kernel)
    mesh_shape = tuple(mesh.devices.shape) if mesh is not None else None
    fits = _fits()
    scns = tuple(Scenario(dists=dist, ks=(1, 2), client_overhead=ovh)
                 for dist, _, ovh in fits)
    cfg = queueing.SimConfig(n_servers=20,
                             n_arrivals=4_000 if smoke else 60_000)
    rhos = jnp.linspace(0.05, 0.495, 8 if smoke else 24)

    def work():
        # ONE engine call, three systems: gain matrix (B, 3)
        return threshold.scenario_gain(key, scns, rhos, cfg, n_seeds=2,
                                       mesh=mesh, kernel=resolved)

    g, us = timed(work)
    crossings = {}
    for i, name in enumerate(SYSTEMS):
        dist, ms_scale, ovh = fits[i]
        t = threshold.crossing_load(rhos, g[:, i])
        crossings[name] = t
        g_lo, g_hi = float(g[0, i]) * ms_scale, float(g[-1, i]) * ms_scale
        rows.append((f"fig_cross_system/{name}", us / len(SYSTEMS),
                     f"crossover_load={t:.3f};"
                     f"gain@{float(rhos[0]):.2f}={g_lo:.4f}ms;"
                     f"gain@{float(rhos[-1]):.2f}={g_hi:.4f}ms;"
                     f"mean_service_ms={ms_scale:.3f};"
                     f"overhead_frac={ovh:.3f}",
                     mesh_shape, scn_mod.provenance(scns[i]), resolved))
    order = sorted(crossings, key=crossings.get, reverse=True)
    rows.append(("fig_cross_system/crossover", us,
                 ";".join(f"{n}={crossings[n]:.3f}" for n in order)
                 + f";order={'>'.join(order)};"
                 f"rho_grid=[{float(rhos[0]):.2f},{float(rhos[-1]):.2f}]"
                 f"x{rhos.shape[0]}",
                 mesh_shape, scn_mod.provenance(scns), resolved))

    # scan-vs-kernel parity on the mixed grid (interpreted off-TPU so a
    # kernel-path measurement always exists); smoke-sized — parity is a
    # contract check, not a timing row.
    mode = resolved if resolved != "off" else resolve_kernel_mode("on")
    pcfg = queueing.SimConfig(n_servers=20, n_arrivals=2_000)
    prhos = jnp.asarray([0.1, 0.3])
    off = queueing.run(key, scns, prhos, pcfg, n_seeds=1, kernel="off")
    on, kus = timed(lambda: queueing.run(key, scns, prhos, pcfg,
                                         n_seeds=1, kernel=mode))
    bit = all(bool(jnp.array_equal(off[f], on[f]))
              for f in ("mean", "p50", "p99"))
    rows.append(("fig_cross_system/kernel_parity", kus,
                 f"kernel={mode};bit_identical={bit};"
                 f"cells={prhos.shape[0] * 2 * len(scns)};"
                 f"arrivals={pcfg.n_arrivals}",
                 None, scn_mod.provenance(scns), mode))
    return rows
