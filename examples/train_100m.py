"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
with the full production substrate — deterministic data pipeline, hedged
(redundant) data loading, async checkpointing, crash-safe resume.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
(~100M params on CPU: expect a few seconds per step.)
"""
import argparse

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    # ~105M params: 12L, d768, GQA 12/4 heads — a GPT-2-small-ish config
    # assembled from the same blocks as the assigned architectures.
    return ModelConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32_000,
        pattern=("global",), mlp_act="silu", gated_mlp=True,
        tie_embeddings=True, recipe="tp", long_context_ok=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    cfg = model_100m()
    print(f"params ~ {cfg.param_count / 1e6:.1f}M")
    trainer = Trainer(
        cfg,
        DataConfig(seq_len=args.seq_len, batch_size=args.batch, seed=0),
        TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50,
                      hedged_loader_k=2, log_every=10),
        opt=make_optimizer("adamw", lr=3e-4))
    out = trainer.run(args.steps)
    print(f"final loss {out['history'][-1]['loss']:.4f}; "
          f"hedged-loader duplicate wins: {out['loader_duplicate_wins']}")


if __name__ == "__main__":
    main()
