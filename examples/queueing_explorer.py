"""Explore the replication policy space interactively from the CLI: pick a
service-time family, a replication policy and a service model, and sweep
loads / replication factors.

The whole (load x k) table comes from ONE ``queueing.run`` call executing
a declarative ``Scenario`` (policy, service model, mix, ks).

Run:  PYTHONPATH=src python examples/queueing_explorer.py \
          --family pareto --param 2.1 --k 1 2 3

``--policy cancel_on_complete`` switches to the Joshi et al. regime
(losers vacate their queue slot at the winner's finish),
``--policy replicate_to_idle`` only copies to idle servers, and
``--service-model server_dependent --mix 0.8`` blends Shah et al.'s
shared request component into every copy's service time (replication
stops helping as ``--mix`` approaches 1).

``--chunk-size`` streams arrivals through the chunked engine so
``--arrivals`` can go into the millions without pre-sampling the whole
stream (the default, no chunking, preserves the old behavior).

``--devices N`` runs the sweep (and the threshold probes) through the
sharded cell-plan executor on an N-device "cells" mesh — bit-identical
to the local engine, but each device owns a slice of the (load x k)
cells; the policy/model codes shard with the plan, so every policy rides
the same path. On CPU, export
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first to get N
virtual devices.

``--kernel {auto,on,off,interpret}`` picks the engine's chunk-body
implementation: the fused Pallas cell-update kernel or the ``lax.scan``
reference (``auto`` = kernel on TPU, scan elsewhere; every mode is
bit-identical, see ``repro.kernels.cell_update``).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import distributions as dists
from repro.core import queueing, threshold
from repro.core.scenario import (Policy, Scenario, ServiceModel,
                                 parse_policy, parse_service_model)
from repro.kernels.cell_update import resolve_kernel_mode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="exponential",
                    choices=sorted(dists.FAMILIES))
    ap.add_argument("--param", type=float, default=None,
                    help="family parameter (pareto alpha / weibull k / "
                         "two_point p)")
    ap.add_argument("--k", type=int, nargs="+", default=[1, 2])
    ap.add_argument("--loads", type=float, nargs="+",
                    default=[0.1, 0.2, 0.3, 0.4])
    ap.add_argument("--servers", type=int, default=20)
    ap.add_argument("--arrivals", type=int, default=60_000)
    ap.add_argument("--policy", default="replicate_all",
                    choices=[p.name.lower() for p in Policy],
                    help="replication policy (paper: replicate_all)")
    ap.add_argument("--service-model", default="iid",
                    choices=[m.name.lower() for m in ServiceModel],
                    help="copy service-time model (paper: iid)")
    ap.add_argument("--mix", type=float, default=0.5,
                    help="server_dependent only: fraction of each copy's "
                         "service time that is the shared request "
                         "component (0 = iid, 1 = fully request-bound)")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="stream arrivals in chunks of this many steps "
                         "(memory independent of --arrivals)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the sweep's cells over this many devices "
                         "(CPU: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--kernel", default="auto",
                    choices=("auto", "on", "off", "interpret"),
                    help="fused cell-update kernel mode (auto: kernel on "
                         "TPU, scan elsewhere; all modes bit-identical)")
    args = ap.parse_args()

    factory = dists.FAMILIES[args.family]
    dist = factory(args.param) if args.param is not None else factory()
    cfg = queueing.SimConfig(n_servers=args.servers,
                             n_arrivals=args.arrivals)
    scn = Scenario(dists=dist, policy=parse_policy(args.policy),
                   service_model=parse_service_model(args.service_model),
                   mix=args.mix, ks=tuple(args.k))
    key = jax.random.PRNGKey(0)
    loads = jnp.asarray(args.loads)

    mesh = None
    if args.devices:
        from repro.launch.mesh import make_sweep_mesh
        n_dev = min(args.devices, jax.device_count())
        if n_dev < args.devices:
            print(f"# --devices {args.devices} clamped to {n_dev} visible "
                  f"devices (on CPU set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count={args.devices})")
        mesh = make_sweep_mesh(n_dev)

    kernel = resolve_kernel_mode(args.kernel)

    # one engine call over all (load, k) cells of the scenario
    s = queueing.run(key, scn, loads, cfg, n_seeds=1,
                     chunk_size=args.chunk_size, mesh=mesh, kernel=kernel)

    model = scn.service_model.name.lower()
    if scn.service_model is ServiceModel.SERVER_DEPENDENT:
        model += f"(mix={scn.mix:g})"
    print(f"service = {dist.name}, N = {args.servers}, "
          f"policy = {scn.policy.name.lower()}, model = {model}"
          + (f", mesh = {mesh.devices.size}-way 'cells'" if mesh else "")
          + f", kernel = {kernel}")
    header = "load  " + "  ".join(f"k={k}: mean/p99" for k in args.k)
    print(header)
    for i, rho in enumerate(loads):
        cells = []
        for j, _ in enumerate(args.k):
            cells.append(f"{float(s['mean'][0, i, j]):7.3f}/"
                         f"{float(s['p99'][0, i, j]):8.2f}")
        print(f"{float(rho):.2f} " + "  ".join(cells))

    t = threshold.threshold_grid(key, scn, cfg, n_seeds=2,
                                 chunk_size=args.chunk_size, mesh=mesh,
                                 kernel=kernel)
    print(f"\nestimated threshold load (k=2): {t:.3f} "
          f"(paper model: always in ~(0.26, 0.5) with no client overhead)")


if __name__ == "__main__":
    main()
