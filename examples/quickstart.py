"""Quickstart: the paper's result in 30 seconds.

1. Declare the paper's queueing model (§2.1) as a ``Scenario`` and run it
   through the sweep engine; locate the threshold load for exponential
   service — Theorem 1 says exactly 1/3.
2. Step OFF the paper's point in the policy space: cancellation
   (Joshi et al.) keeps replication helpful at loads where the paper's
   replicate-all model has already flipped to harmful.
3. Wrap a flaky "service" in the hedged-call combinator and watch the tail
   collapse.
4. Fault masking — the paper's "even under exceptional conditions":
   stragglers and blackhole failures as Scenario coordinates
   (``Degradation``), masked by hedging in the engine; then a live
   replica CRASHED mid-trace, masked by the hedged scheduler
   (``serving.faults.FaultInjector`` — the full matrix is
   ``benchmarks/fig_fault_masking.py``).
5. Adaptive serving — close the loop: precompute a (load x policy)
   table from ONE engine sweep, then replay a diurnal trace open loop
   while an online controller interpolates the table from live load and
   re-picks k / hedge delay as the day moves through the threshold
   (the million-request version is ``benchmarks/serving_hedge.py``).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytic, distributions as dists, hedging, queueing, threshold
from repro.core.scenario import CANCEL_ON_COMPLETE, Scenario

# --- 1. the queueing model, as a declarative Scenario -------------------
key = jax.random.PRNGKey(0)
cfg = queueing.SimConfig(n_servers=20, n_arrivals=40_000)
loads = jnp.asarray([0.1, 0.25, 0.4])
paper = Scenario.paper_default(dists.exponential())  # replicate-all, iid
gain = threshold.scenario_gain(key, paper, loads, cfg)
print("replication gain (mean response, k=2 vs k=1, paper model):")
for rho, g in zip(loads, gain):
    sign = "helps" if g > 0 else "hurts"
    print(f"  load {float(rho):.2f}: {float(g):+.3f}  ({sign})")

t = threshold.threshold_bisect(key, paper, cfg, iters=7, n_seeds=2)
print(f"estimated threshold load = {t:.3f} "
      f"(Theorem 1: {analytic.THRESHOLD_EXPONENTIAL:.3f})")

# --- 2. one step into the policy space: cancel the losers ---------------
cancel = Scenario(dists=dists.exponential(), policy=CANCEL_ON_COMPLETE)
g_cancel = threshold.scenario_gain(key, cancel, loads, cfg)
print("with CANCEL_ON_COMPLETE (losers vacate their queue slot):")
for rho, g in zip(loads, g_cancel):
    sign = "helps" if g > 0 else "hurts"
    print(f"  load {float(rho):.2f}: {float(g):+.3f}  ({sign})")

# --- 3. hedged calls ----------------------------------------------------
rng = np.random.default_rng(0)


def flaky_service():
    # 5 ms typical, 100 ms with probability 0.2
    time.sleep(0.1 if rng.random() < 0.2 else 0.005)
    return "ok"


lat1, lat2 = [], []
for _ in range(30):
    t0 = time.monotonic()
    flaky_service()
    lat1.append(time.monotonic() - t0)
    res = hedging.hedged_call([flaky_service, flaky_service], k=2)
    lat2.append(res.latency)

print(f"\nhedged_call: p90 {np.percentile(lat1, 90) * 1e3:.0f} ms -> "
      f"{np.percentile(lat2, 90) * 1e3:.0f} ms "
      f"(mean {np.mean(lat1) * 1e3:.0f} -> {np.mean(lat2) * 1e3:.0f} ms)")

# --- 4a. fault masking in the engine ------------------------------------
# Degradation makes faults sweep coordinates: with probability p_slow a
# copy's service is inflated 8x (straggler), with p_fail it never
# returns (blackhole). Healthy cells keep their exact bits — fault draws
# come from a dedicated CRN stream.
from repro.core.scenario import Degradation, Policy
from repro.serving.engine import SimulatedEngine
from repro.serving.faults import FaultInjector
from repro.serving.scheduler import HedgedScheduler
from repro.core.hedging import HedgePolicy

deg = Degradation(p_slow=0.05, slow_factor=8.0, p_fail=0.02)
d = dists.exponential()
scns = [
    Scenario(dists=d, ks=(1,), degradation=deg),                # bare
    Scenario(dists=d, policy=Policy.TIMEOUT_RETRY, delay=1.0,
             ks=(2,), degradation=deg),                         # retry
    Scenario(dists=d, policy=Policy.HEDGE_AFTER_DELAY, delay=1.0,
             ks=(2,), degradation=deg),                         # hedge
]
out = queueing.run(key, scns, jnp.asarray([0.2]), cfg, n_seeds=2,
                   percentiles=(99.0,))
p99 = np.asarray(out["p99"]).mean(axis=0)[0]
frac = np.asarray(out["completed"]).mean(axis=0)[0] / float(
    np.asarray(out["count"]))
print("\nfault masking (5% 8x-stragglers + 2% blackholes, load 0.2):")
for name, j in (("bare k=1", 0), ("timeout-retry", 1),
                ("hedge-after-delay", 2)):
    print(f"  {name:18s} p99 {p99[j]:6.2f}   completed {frac[j]:.4f}")

# --- 4b. fault masking in the serving stack -----------------------------
# crash one of three replicas mid-trace; the hedged duplicate on a
# healthy replica masks the blackhole at ~zero latency cost.
inj = FaultInjector()
engines = [inj.wrap(SimulatedEngine(lambda: 0.005, name=f"s{i}"))
           for i in range(3)]
sched = HedgedScheduler(engines,
                        policy=HedgePolicy(max_k=2, threshold=1.1),
                        tied_cancel=True, seed=0)
try:
    lats = []
    for i in range(20):
        if i == 10:
            inj.crash("s1")  # blackhole: never answers, never removed
        lats.append(sched.submit(np.zeros(2, np.int32),
                                 timeout=5.0).latency)
finally:
    sched.shutdown()
print(f"replica s1 crashed mid-trace: 20/20 completed, "
      f"max latency {max(lats) * 1e3:.1f} ms (hedging masks the crash)")

# --- 5. adaptive serving: the threshold, closed-loop --------------------
# ONE mixed-grid sweep precomputes p99 over (load x {k=1, k=2@delay});
# at serve time the controller is pure numpy — it estimates load from
# arrival/busy windows and interpolates the table to re-pick the policy.
from repro.serving.controller import AdaptiveController, PolicyTable
from repro.serving.replay import diurnal_trace, replay_virtual

tab = threshold.policy_table(key, dists.exponential(),
                             queueing.SimConfig(n_servers=8,
                                                n_arrivals=3_000),
                             rhos=[0.05, 0.2, 0.35, 0.5, 0.7],
                             ks=(1, 2), delays=(0.0, 1.0), n_seeds=2)
table = PolicyTable.from_sweep(tab)
trace = diurnal_trace(20_000, rhos=(0.15, 0.75, 0.15), n_replicas=8,
                      seed=0)
runs = {f"static k={k}": replay_virtual(trace, static_k=k, seed=1)
        for k in (1, 2)}
ctl = AdaptiveController(table, n_replicas=8, window_s=40.0,
                         decision_stride=16, initial_rho=0.15)
runs["adaptive"] = replay_virtual(trace, controller=ctl, seed=1)
print("\nadaptive serving over a night/peak/night day (p99 per segment):")
for name, r in runs.items():
    segs = "  ".join(f"{r.tails(segment=s)[1]:6.2f}"
                     for s in range(trace.n_segments))
    print(f"  {name:11s} {segs}")
print(f"  controller re-decided {ctl.decisions} times, "
      f"switched policy {ctl.switches}x as load crossed the threshold")
