"""Quickstart: the paper's result in 30 seconds.

1. Declare the paper's queueing model (§2.1) as a ``Scenario`` and run it
   through the sweep engine; locate the threshold load for exponential
   service — Theorem 1 says exactly 1/3.
2. Step OFF the paper's point in the policy space: cancellation
   (Joshi et al.) keeps replication helpful at loads where the paper's
   replicate-all model has already flipped to harmful.
3. Wrap a flaky "service" in the hedged-call combinator and watch the tail
   collapse.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analytic, distributions as dists, hedging, queueing, threshold
from repro.core.scenario import CANCEL_ON_COMPLETE, Scenario

# --- 1. the queueing model, as a declarative Scenario -------------------
key = jax.random.PRNGKey(0)
cfg = queueing.SimConfig(n_servers=20, n_arrivals=40_000)
loads = jnp.asarray([0.1, 0.25, 0.4])
paper = Scenario.paper_default(dists.exponential())  # replicate-all, iid
gain = threshold.scenario_gain(key, paper, loads, cfg)
print("replication gain (mean response, k=2 vs k=1, paper model):")
for rho, g in zip(loads, gain):
    sign = "helps" if g > 0 else "hurts"
    print(f"  load {float(rho):.2f}: {float(g):+.3f}  ({sign})")

t = threshold.threshold_bisect(key, paper, cfg, iters=7, n_seeds=2)
print(f"estimated threshold load = {t:.3f} "
      f"(Theorem 1: {analytic.THRESHOLD_EXPONENTIAL:.3f})")

# --- 2. one step into the policy space: cancel the losers ---------------
cancel = Scenario(dists=dists.exponential(), policy=CANCEL_ON_COMPLETE)
g_cancel = threshold.scenario_gain(key, cancel, loads, cfg)
print("with CANCEL_ON_COMPLETE (losers vacate their queue slot):")
for rho, g in zip(loads, g_cancel):
    sign = "helps" if g > 0 else "hurts"
    print(f"  load {float(rho):.2f}: {float(g):+.3f}  ({sign})")

# --- 3. hedged calls ----------------------------------------------------
rng = np.random.default_rng(0)


def flaky_service():
    # 5 ms typical, 100 ms with probability 0.2
    time.sleep(0.1 if rng.random() < 0.2 else 0.005)
    return "ok"


lat1, lat2 = [], []
for _ in range(30):
    t0 = time.monotonic()
    flaky_service()
    lat1.append(time.monotonic() - t0)
    res = hedging.hedged_call([flaky_service, flaky_service], k=2)
    lat2.append(res.latency)

print(f"\nhedged_call: p90 {np.percentile(lat1, 90) * 1e3:.0f} ms -> "
      f"{np.percentile(lat2, 90) * 1e3:.0f} ms "
      f"(mean {np.mean(lat1) * 1e3:.0f} -> {np.mean(lat2) * 1e3:.0f} ms)")
