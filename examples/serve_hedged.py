"""Serve a small model with batched requests through the hedged scheduler:
4 replicas, one artificially slow (straggler) — redundancy masks it.

Run:  PYTHONPATH=src python examples/serve_hedged.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.hedging import HedgePolicy, LoadMeter
from repro.models import lm
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import HedgedScheduler


class SlowWrapper:
    """A replica with an injected 150 ms stall (multi-tenant interference)."""

    def __init__(self, inner, stall_s=0.15):
        self.inner = inner
        self.stall_s = stall_s
        self.name = inner.name + "-slow"

    def generate(self, *args, **kwargs):
        time.sleep(self.stall_s)
        return self.inner.generate(*args, **kwargs)


def run(k: int, engines) -> np.ndarray:
    sched = HedgedScheduler(
        engines, policy=HedgePolicy(max_k=k, threshold=1.1),
        meter=LoadMeter(alpha=0.0, init=0.0), seed=0)
    rng = np.random.default_rng(0)
    lat = []
    try:
        for _ in range(16):
            prompt = rng.integers(0, 500, 12).astype(np.int32)
            req = sched.submit(prompt, max_new_tokens=4)
            lat.append(req.latency)
        stats = dict(sched.stats)
    finally:
        sched.shutdown()
    print(f"  k={k}: mean={np.mean(lat) * 1e3:.0f}ms "
          f"p90={np.percentile(lat, 90) * 1e3:.0f}ms  stats={stats}")
    return np.asarray(lat)


def main() -> None:
    cfg = get_smoke_config("gemma2-2b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engines = [InferenceEngine(cfg, params, max_len=64, name=f"r{i}")
               for i in range(4)]
    engines[0] = SlowWrapper(engines[0])  # one straggler replica
    # warm the jit caches so latencies measure serving, not compilation
    engines[1].generate(np.zeros(4, np.int32), max_new_tokens=2)

    print("without redundancy (k=1): requests landing on the slow replica "
          "eat the stall")
    l1 = run(1, engines)
    print("with redundancy (k=2, duplicates at low priority):")
    l2 = run(2, engines)
    print(f"p90 improvement: {np.percentile(l1, 90) / np.percentile(l2, 90):.1f}x")


if __name__ == "__main__":
    main()
