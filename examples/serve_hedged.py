"""Serve a small model through the BATCHED hedged service: pooled
transfer buffers, non-blocking submits, and an online controller that
picks the replication factor from engine sweeps — then a chaos segment
where two replicas stall and the controller backs replication off.

Run:  PYTHONPATH=src python examples/serve_hedged.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import distributions as dists
from repro.core import queueing, threshold
from repro.models import lm
from repro.serving.controller import AdaptiveController, PolicyTable
from repro.serving.engine import InferenceEngine, SimulatedEngine
from repro.serving.faults import FaultInjector
from repro.serving.metrics import Telemetry
from repro.serving.replay import poisson_trace, replay_live
from repro.serving.service import BatchedHedgedService


class SlowWrapper:
    """A replica with an injected 150 ms stall (multi-tenant interference)."""

    def __init__(self, inner, stall_s=0.15):
        self.inner = inner
        self.stall_s = stall_s
        self.name = inner.name + "-slow"

    def generate(self, *args, **kwargs):
        time.sleep(self.stall_s)
        return self.inner.generate(*args, **kwargs)


def run_static(k: int, engines) -> np.ndarray:
    """Batched submits through the service at a fixed k."""
    svc = BatchedHedgedService(engines, batch_sizes=(1, 4), max_seq=16,
                               k=k, seed=0)
    rng = np.random.default_rng(0)
    lat = []
    try:
        for _ in range(4):
            prompts = [rng.integers(0, 500, 12).astype(np.int32)
                       for _ in range(4)]
            reqs = svc.submit_batch(prompts, max_new_tokens=4)
            for r in reqs:
                svc.result(r, timeout=30.0)
                lat.append(r.latency)
        stats = dict(svc.stats)
    finally:
        svc.shutdown()
    print(f"  k={k}: mean={np.mean(lat) * 1e3:.0f}ms "
          f"p90={np.percentile(lat, 90) * 1e3:.0f}ms  stats={stats}")
    return np.asarray(lat)


def chaos_segment() -> None:
    """Open-loop Poisson traffic on 4 fast simulated replicas; two of
    them stall mid-run. The controller's busy term (stalled workers
    stay busy) pushes its load estimate past the crossing, it backs
    off to k=1, and after the heal the estimate falls and hedging
    returns."""
    mean_s = 0.01
    print("\nchaos: sweep the policy table (one mixed-grid engine run)...")
    cfg = queueing.SimConfig(n_servers=4, n_arrivals=2_000)
    tab = threshold.policy_table(jax.random.PRNGKey(0),
                                 dists.exponential(), cfg,
                                 rhos=[0.05, 0.2, 0.35, 0.5, 0.7],
                                 ks=(1, 2), delays=(0.0, 1.0), n_seeds=2)
    table = PolicyTable.from_sweep(tab)

    rngs = [np.random.default_rng(10 + i) for i in range(4)]
    injector = FaultInjector()
    engines = [injector.wrap(SimulatedEngine(
        lambda r=rngs[i]: float(r.exponential(mean_s)), name=f"s{i}"))
        for i in range(4)]
    ctl = AdaptiveController(table, n_replicas=4, mean_service_s=mean_s,
                             window_s=1.0, hysteresis=0.1,
                             decision_stride=16, initial_rho=0.2)
    svc = BatchedHedgedService(engines, batch_sizes=(1, 4), max_seq=8,
                               controller=ctl,
                               telemetry=Telemetry(window_s=1.0), seed=1)
    trace = poisson_trace(720, rho=0.2, n_replicas=4,
                          mean_service_s=mean_s, seed=2)
    # the chaos clock: stall two replicas a third of the way in, heal
    # them two thirds of the way in
    span = float(trace.t[-1])
    for name in ("s0", "s1"):
        injector.stall(name, after=span / 3)
        injector.heal(name, after=2 * span / 3)
    try:
        replay_live(svc, trace, max_new_tokens=2, timeout_s=60.0)
    finally:
        svc.shutdown()

    thirds = [0, 0, 0], [0, 0, 0]
    ks, counts = thirds
    for h in ctl.history:
        third = min(int(3 * (h.t - ctl.history[0].t)
                        / max(span, 1e-9)), 2)
        ks[third] += h.k
        counts[third] += 1
    mean_k = [k / max(c, 1) for k, c in zip(ks, counts)]
    print(f"  controller mean k by phase: healthy={mean_k[0]:.2f}  "
          f"stalled={mean_k[1]:.2f}  healed={mean_k[2]:.2f}")
    print(f"  switches={ctl.switches}  decisions={ctl.decisions}")
    print(f"  telemetry: {svc.telemetry.provenance()}")
    assert mean_k[1] < mean_k[0], "controller should back off under stall"


def main() -> None:
    cfg = get_smoke_config("gemma2-2b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engines = [InferenceEngine(cfg, params, max_len=64, name=f"r{i}")
               for i in range(4)]
    engines[0] = SlowWrapper(engines[0])  # one straggler replica
    # warm the jit caches so latencies measure serving, not compilation
    engines[1].generate(np.zeros(4, np.int32), max_new_tokens=2)

    print("without redundancy (k=1): requests landing on the slow replica "
          "eat the stall")
    l1 = run_static(1, engines)
    print("with redundancy (k=2, duplicates at low priority):")
    l2 = run_static(2, engines)
    print(f"p90 improvement: "
          f"{np.percentile(l1, 90) / np.percentile(l2, 90):.1f}x")

    chaos_segment()


if __name__ == "__main__":
    main()
