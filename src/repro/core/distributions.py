"""Service-time distributions for the replication queueing model (paper §2.1).

Every distribution is normalized to UNIT MEAN so that per-server utilization
equals the arrival rate per server (rho). The families here are exactly the
ones the paper studies: exponential (Theorem 1), deterministic (Conjecture 1
worst case), Pareto / Weibull / two-point (Figure 2), random discrete
(Figure 3), plus empirical mixtures used by the storage/DNS studies.

All samplers are pure functions of a PRNG key and shape, suitable for use
inside jit/vmap.

Empirical distributions & the system coordinate
-----------------------------------------------
``empirical`` turns ANY sample set — measured traces, draws from
``repro.core.storage_sim._sample_ms``, marginals of
``repro.core.dns.sample_latencies`` — into a unit-mean quantile-table
``EmpiricalDist``: n+1 quantile knots q_0..q_n fitted at the evenly
spaced probabilities u_i = i/n, sampled by inverse-CDF with linear
interpolation between knots (so the fitted law is the piecewise-linear
CDF through the knots; mean and variance have closed forms over the
table). The original sample mean is kept as ``.scale`` so engine output
(unit-mean time) maps back to milliseconds, and ``.exceedance(x)``
reads tail fractions straight off the table. Because the result is a
plain ``ServiceDist``, every empirical system rides the engine's dist
batch axis and the Pallas ``cell_update`` kernel unchanged — "which
system" becomes the per-cell ``dist_id`` coordinate of
``repro.core.scenario`` / ``repro.core.queueing``.

jit-cache contract
------------------
``ServiceDist`` is a *static* argument of the jitted simulators in
``repro.core.queueing``, so two distinct instances — even with identical
parameters — trigger a full retrace/recompile. To make repeated configs hit
the jit cache, every factory with hashable scalar parameters
(``exponential``, ``deterministic``, ``pareto``, ``weibull``, ``two_point``,
``scaled``) is memoized: ``pareto(2.1) is pareto(2.1)`` holds, and building
the "same" distribution twice costs nothing. Factories taking arrays or PRNG
keys (``discrete``, ``random_discrete``, ``mixture``) cannot be memoized —
hold on to the returned object and reuse it across jitted calls.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServiceDist:
    """A unit-mean service-time distribution."""

    name: str
    sample: Callable[[Array, tuple[int, ...]], Array]
    mean: float = 1.0
    variance: float | None = None  # None = infinite / not in closed form

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"ServiceDist({self.name})"


@functools.lru_cache(maxsize=None)
def exponential() -> ServiceDist:
    """Exp(1): the analytically tractable case of Theorem 1."""

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        return jax.random.exponential(key, shape)

    return ServiceDist("exponential", sample, variance=1.0)


@functools.lru_cache(maxsize=None)
def deterministic() -> ServiceDist:
    """Unit point mass — the paper's conjectured worst case (threshold ~25.8%)."""

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        del key
        return jnp.ones(shape)

    return ServiceDist("deterministic", sample, variance=0.0)


@functools.lru_cache(maxsize=None)
def pareto(alpha: float) -> ServiceDist:
    """Unit-mean Pareto with tail index ``alpha`` (> 1).

    x_m = (alpha - 1) / alpha so that E[X] = alpha * x_m / (alpha - 1) = 1.
    Variance is finite only for alpha > 2.
    """
    if alpha <= 1.0:
        raise ValueError("Pareto needs alpha > 1 for a finite mean")
    x_m = (alpha - 1.0) / alpha
    if alpha > 2.0:
        var = x_m**2 * alpha / ((alpha - 1.0) ** 2 * (alpha - 2.0))
    else:
        var = None

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        u = jax.random.uniform(key, shape, minval=jnp.finfo(jnp.float32).tiny)
        return x_m * u ** (-1.0 / alpha)

    return ServiceDist(f"pareto(a={alpha:g})", sample, variance=var)


@functools.lru_cache(maxsize=None)
def weibull(shape_k: float) -> ServiceDist:
    """Unit-mean Weibull with shape ``k`` (k < 1 => heavier than exponential)."""
    if shape_k <= 0:
        raise ValueError("Weibull shape must be positive")
    # scale so that mean = lam * Gamma(1 + 1/k) = 1
    import math

    g1 = math.gamma(1.0 + 1.0 / shape_k)
    lam = 1.0 / g1
    g2 = math.gamma(1.0 + 2.0 / shape_k)
    var = lam**2 * (g2 - g1**2)

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        u = jax.random.uniform(key, shape, minval=jnp.finfo(jnp.float32).tiny)
        return lam * (-jnp.log(u)) ** (1.0 / shape_k)

    return ServiceDist(f"weibull(k={shape_k:g})", sample, variance=float(var))


@functools.lru_cache(maxsize=None)
def two_point(p: float) -> ServiceDist:
    """The paper's Fig 2(c) family: 0.5 w.p. p, (1 - 0.5 p)/(1 - p) w.p. 1-p.

    Unit mean by construction; variance -> infinity as p -> 1.
    """
    if not 0.0 <= p < 1.0:
        raise ValueError("two_point needs 0 <= p < 1")
    hi = (1.0 - 0.5 * p) / (1.0 - p)
    var = p * 0.25 + (1.0 - p) * hi**2 - 1.0

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        u = jax.random.uniform(key, shape)
        return jnp.where(u < p, 0.5, hi)

    return ServiceDist(f"two_point(p={p:g})", sample, variance=float(var))


def discrete(values: Array | list[float], probs: Array | list[float],
             name: str = "discrete") -> ServiceDist:
    """Arbitrary discrete distribution, renormalized to unit mean.

    Used for the paper's Figure 3 (random distributions on {1..N}) and for
    the storage-service empirical mixtures.
    """
    v = jnp.asarray(values, dtype=jnp.float32)
    p = jnp.asarray(probs, dtype=jnp.float32)
    p = p / jnp.sum(p)
    mean = jnp.sum(v * p)
    v = v / mean  # unit mean
    var = float(jnp.sum(p * v**2) - 1.0)
    logits = jnp.log(p)

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        idx = jax.random.categorical(key, logits, shape=shape)
        return v[idx]

    return ServiceDist(name, sample, variance=var)


def random_discrete(key: Array, support: int, *, dirichlet_alpha: float | None = None,
                    name: str | None = None) -> ServiceDist:
    """A random unit-mean discrete distribution on {1, .., support}.

    ``dirichlet_alpha=None`` samples probabilities uniformly from the simplex
    (equivalently Dirichlet(1)); the paper additionally uses a symmetric
    Dirichlet with concentration 0.1 to get a wider spread (Figure 3).
    """
    alpha = 1.0 if dirichlet_alpha is None else dirichlet_alpha
    probs = jax.random.dirichlet(key, jnp.full((support,), alpha))
    values = jnp.arange(1, support + 1, dtype=jnp.float32)
    label = name or f"random_discrete(N={support},a={alpha:g})"
    return discrete(values, probs, name=label)


def mixture(components: list[ServiceDist], weights: list[float],
            name: str = "mixture", *, normalize: bool = True) -> ServiceDist:
    """Finite mixture of unit-mean components (renormalized to unit mean).

    The storage-service models (disk/cache) are mixtures of a fast memory
    path and a slow disk path.
    """
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)
    logits = jnp.log(w)
    # mixture of unit-mean components has unit mean already; ``normalize`` is
    # for callers that pass non-unit components on purpose.

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        k1, k2 = jax.random.split(key)
        idx = jax.random.categorical(k1, logits, shape=shape)
        keys = jax.random.split(k2, len(components))
        draws = jnp.stack([c.sample(keys[i], shape) for i, c in enumerate(components)])
        return jnp.take_along_axis(draws, idx[None, ...], axis=0)[0]

    means = jnp.asarray([c.mean for c in components])
    mixture_mean = float(jnp.sum(w * means))
    # Closed-form variance when every component has one: E[X^2] of a
    # mixture is the weighted sum of component second moments.
    var = None
    if all(c.variance is not None for c in components):
        e2 = float(jnp.sum(w * jnp.asarray(
            [c.variance + c.mean**2 for c in components])))
        var = e2 - mixture_mean**2
    if normalize and abs(mixture_mean - 1.0) > 1e-6:
        inner = sample

        def sample(key: Array, shape: tuple[int, ...]) -> Array:  # noqa: F811
            return inner(key, shape) / mixture_mean

        if var is not None:
            var = var / mixture_mean**2
        mixture_mean = 1.0
    return ServiceDist(name, sample, mean=mixture_mean, variance=var)


@dataclasses.dataclass(frozen=True, repr=False)  # keep the short repr
class EmpiricalDist(ServiceDist):
    """A unit-mean quantile-table distribution fitted from samples.

    ``table`` holds the n+1 unit-mean quantile knots q_0..q_n at the
    evenly spaced probabilities u_i = i/n; sampling is inverse-CDF with
    linear interpolation between knots, so the fitted law is the
    piecewise-linear CDF through the knots. ``scale`` is the mean of the
    ORIGINAL samples (e.g. milliseconds), so ``x * scale`` maps a draw
    back to sample units. Being a plain hashable dataclass, an
    ``EmpiricalDist`` rides the jit-cache contract like any other
    ``ServiceDist``: hold the object and reuse it across jitted calls.
    """

    table: tuple[float, ...] = ()
    scale: float = 1.0

    def exceedance(self, x: float) -> float:
        """P[X > x] with ``x`` in ORIGINAL sample units (table geometry:
        linear interpolation of the fitted CDF)."""
        import numpy as np

        knots = np.asarray(self.table, dtype=np.float64) * self.scale
        u = np.linspace(0.0, 1.0, len(knots))
        # CDF(x) by inverting the (monotone) quantile function.
        return float(1.0 - np.interp(x, knots, u, left=0.0, right=1.0))

    @classmethod
    def from_trace(cls, path, *, n_quantiles: int = 512,
                   name: str | None = None) -> "EmpiricalDist":
        """Fit a quantile table from a latency TRACE file: newline-
        delimited samples in milliseconds (blank lines and ``#``
        comments skipped — the common format of packet/RPC latency
        dumps). The returned dist is unit-mean like every engine
        distribution; ``scale`` holds the trace mean in ms, so paper-
        style absolute plots multiply back by it. Fitting goes through
        ``empirical`` (tail-conditional top knot and all)."""
        import os

        import numpy as np

        with open(path) as fh:
            vals = [float(ln) for ln in (s.strip() for s in fh)
                    if ln and not ln.startswith("#")]
        if len(vals) < 2:
            raise ValueError(f"trace {path!r} has {len(vals)} usable "
                             f"sample(s); need at least 2")
        label = name or f"trace:{os.path.basename(str(path))}"
        return empirical(np.asarray(vals), n_quantiles=n_quantiles,
                         name=label)


def empirical(samples, *, n_quantiles: int = 512,
              name: str = "empirical") -> EmpiricalDist:
    """Fit a unit-mean quantile-table distribution to ``samples``.

    Fits n+1 quantile knots at u_i = i/n (float64; the top knot is
    moved from the sample max to the value whose uniform lerp matches
    the empirical tail-conditional mean — see below), takes the EXACT
    mean of the piecewise-linear law (trapezoid rule over the knots) as
    the ``scale``, and normalizes the knots to unit mean. The
    closed-form variance of the piecewise-linear law is
    ``sum((q_i^2 + q_i q_{i+1} + q_{i+1}^2) / (3n)) - 1``.

    Cannot be memoized (takes an array); hold the returned object and
    reuse it across jitted calls (see the module jit-cache contract).
    """
    import numpy as np

    s = np.asarray(samples, dtype=np.float64).ravel()
    if s.size < 2:
        raise ValueError("empirical needs at least 2 samples")
    if not np.all(np.isfinite(s)):
        raise ValueError("empirical needs finite samples")
    if np.any(s < 0):
        raise ValueError("service-time samples must be non-negative")
    n = int(n_quantiles)
    if n < 2:
        raise ValueError("n_quantiles must be >= 2")
    q = np.quantile(s, np.linspace(0.0, 1.0, n + 1))
    # The raw top knot is the sample MAX — an extreme order statistic,
    # and lerping the top bin uniformly up to it overweights a heavy
    # tail (pareto(2.1) fits came out ~14% above the sample mean).
    # Replace it so the top bin's uniform lerp reproduces the empirical
    # tail-conditional mean: (q_{n-1} + q_n) / 2 == mean(s | s >= q_{n-1}).
    tail = s[s >= q[-2]]
    if tail.size:
        q[-1] = max(q[-2], 2.0 * float(tail.mean()) - q[-2])
    # exact mean of the piecewise-linear inverse CDF (trapezoid rule)
    scale = float((0.5 * q[0] + q[1:-1].sum() + 0.5 * q[-1]) / n)
    if scale <= 0.0:
        raise ValueError("empirical needs samples with a positive mean")
    q = q / scale
    var = float(((q[:-1] ** 2 + q[:-1] * q[1:] + q[1:] ** 2) / 3.0).mean()
                - 1.0)
    tbl = jnp.asarray(q, dtype=jnp.float32)

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        u = jax.random.uniform(key, shape)
        x = u * n
        idx = jnp.clip(x.astype(jnp.int32), 0, n - 1)
        frac = x - idx.astype(x.dtype)
        return tbl[idx] + (tbl[idx + 1] - tbl[idx]) * frac

    return EmpiricalDist(f"{name}[q{n}]", sample, variance=var,
                         table=tuple(float(v) for v in q), scale=scale)


@functools.lru_cache(maxsize=None)
def scaled(dist: ServiceDist, scale: float) -> ServiceDist:
    """Scale a unit-mean distribution to mean ``scale`` (storage sims use
    real milliseconds)."""

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        return dist.sample(key, shape) * scale

    var = None if dist.variance is None else dist.variance * scale**2
    return ServiceDist(f"{dist.name}*{scale:g}", sample, mean=dist.mean * scale,
                       variance=var)


# Registry used by benchmarks / CLI.
FAMILIES: dict[str, Callable[..., ServiceDist]] = {
    "exponential": exponential,
    "deterministic": deterministic,
    "pareto": pareto,
    "weibull": weibull,
    "two_point": two_point,
}
