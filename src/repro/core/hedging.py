"""Redundant ("hedged") execution — the paper's technique as a runtime.

The paper's prescription, operationalized:
  * duplicate a request to k diverse resources and take the first completion
    (``hedged_call``);
  * only duplicate while measured utilization is below the threshold load
    for the measured service distribution (``HedgePolicy`` — §2.1 says that
    threshold is 25-50%, so the default conservative threshold is 0.25 and a
    measured one can be plugged in);
  * optionally issue duplicates at lower priority so they never delay
    primary work (§2.4) — honored by the serving scheduler which passes
    ``priority=LOW`` for copies >= 1;
  * optionally cancel outstanding copies once one completes (beyond-paper:
    Dean & Barroso's "tied requests"; the paper's model serves every copy to
    completion, so cancellation is OFF by default).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Sequence

PRIORITY_HIGH = 0
PRIORITY_LOW = 1


@dataclasses.dataclass
class HedgeResult:
    value: Any
    winner: int               # index of the replica that completed first
    latency: float            # seconds until first completion
    k: int                    # number of copies actually issued
    losers_cancelled: int = 0


class LoadMeter:
    """EWMA utilization estimate: fraction of busy capacity.

    ``update`` is fed (busy_fraction in [0, 1]) samples by whoever owns the
    resource pool (the serving scheduler reports queue occupancy / busy
    replicas each tick).
    """

    def __init__(self, alpha: float = 0.1, init: float = 0.0):
        self.alpha = float(alpha)
        self._util = float(init)
        self._lock = threading.Lock()

    def update(self, busy_fraction: float) -> None:
        b = min(max(float(busy_fraction), 0.0), 1.0)
        with self._lock:
            self._util = (1.0 - self.alpha) * self._util + self.alpha * b

    @property
    def utilization(self) -> float:
        with self._lock:
            return self._util


class LoadTracker:
    """O(1) shared load signal for a replica pool.

    ``LoadMeter`` is an EWMA the scheduler must FEED by polling every
    worker per request (O(n_replicas) lock acquisitions on the hot
    path). ``LoadTracker`` inverts the flow: workers increment /
    decrement one shared busy counter as copies start and finish, and
    every reader — the shed decision, the adaptive controller, the
    benchmark — sees the SAME instantaneous signal with one lock and no
    per-worker traversal:

      * ``utilization()``       busy copies / capacity, O(1);
      * ``arrival_rate(now)``   arrivals per second over a sliding
                                ``window_s`` window (amortized O(1):
                                timestamps in a deque, stale entries
                                popped on read);
      * ``copies_per_request()`` dispatched copies per arrival over the
                                same window — the measured effective
                                replication factor k_eff, which lets a
                                controller convert busy fraction back
                                to OFFERED load (busy/k_eff) without
                                its own hedging feeding back into its
                                load estimate.

    Timestamps default to ``time.monotonic()`` but every note-method
    takes an explicit ``t`` so a virtual-clock harness (the trace
    replay simulator) can drive the identical object in simulated
    seconds.
    """

    def __init__(self, capacity: int, window_s: float = 30.0):
        self._lock = threading.Lock()
        self._busy = 0
        self._capacity = max(int(capacity), 0)
        self.window_s = float(window_s)
        self._arrivals = collections.deque()   # arrival timestamps
        self._copies = collections.deque()     # (timestamp, n_copies)
        self._copies_sum = 0

    def set_capacity(self, capacity: int) -> None:
        with self._lock:
            self._capacity = max(int(capacity), 0)

    @property
    def capacity(self) -> int:
        with self._lock:
            return self._capacity

    def incr_busy(self) -> None:
        with self._lock:
            self._busy += 1

    def decr_busy(self) -> None:
        with self._lock:
            self._busy -= 1

    def utilization(self) -> float:
        with self._lock:
            return self._busy / max(self._capacity, 1)

    def note_arrival(self, t: float | None = None) -> None:
        t = time.monotonic() if t is None else float(t)
        with self._lock:
            self._arrivals.append(t)
            self._trim(t)

    def note_copies(self, n: int, t: float | None = None) -> None:
        t = time.monotonic() if t is None else float(t)
        with self._lock:
            self._copies.append((t, int(n)))
            self._copies_sum += int(n)
            self._trim(t)

    def _trim(self, now: float) -> None:
        horizon = now - self.window_s
        arr, cop = self._arrivals, self._copies
        while arr and arr[0] < horizon:
            arr.popleft()
        while cop and cop[0][0] < horizon:
            self._copies_sum -= cop.popleft()[1]

    def arrival_rate(self, now: float | None = None) -> float:
        """Arrivals per second over the retained window. Batch submits
        stamp many arrivals with ONE timestamp, so the raw span can be
        zero (or microscopic) while the deque is full — dividing by it
        would report an absurd rate and slam an adaptive controller to
        its max-load policy. Until the window has observed a span of at
        least 5% of ``window_s``, the rate is conservatively floored:
        zero span reads as 0 (no rate measurable yet), tiny spans are
        divided by the floor instead."""
        now = time.monotonic() if now is None else float(now)
        with self._lock:
            self._trim(now)
            if not self._arrivals:
                return 0.0
            span = now - self._arrivals[0]
            if span <= 0.0:
                return 0.0
            return len(self._arrivals) / max(span, 0.05 * self.window_s)

    def copies_per_request(self, now: float | None = None) -> float:
        now = time.monotonic() if now is None else float(now)
        with self._lock:
            self._trim(now)
            if not self._arrivals:
                return 1.0
            return max(self._copies_sum / len(self._arrivals), 1.0)


@dataclasses.dataclass
class HedgePolicy:
    """Decide the replication factor for the next request.

    ``threshold`` should be the threshold load for the system's service-time
    distribution (estimated via ``repro.core.threshold``); the paper
    guarantees it lies in (0.258, 0.5) when client-side overhead is small,
    so 0.25 is a universally safe default. ``client_overhead_frac`` is the
    client-side duplication cost relative to mean service time; following
    §2.1/Fig 4, hedging is disabled when it is large.
    """

    max_k: int = 2
    threshold: float = 0.25
    client_overhead_frac: float = 0.0
    overhead_cutoff: float = 0.5  # Fig 4: overhead ~ mean latency kills gains

    def k_for(self, utilization: float) -> int:
        if self.client_overhead_frac >= self.overhead_cutoff:
            return 1
        # duplicating multiplies utilization by k; pick the largest k
        # whose k-fold load stays under the threshold.
        k = self.max_k
        while k > 1 and k * utilization >= self.threshold:
            k -= 1
        return k


def hedged_call(replicas: Sequence[Callable[..., Any]],
                *args: Any,
                k: int = 2,
                executor: ThreadPoolExecutor | None = None,
                cancel: bool = False,
                timeout: float | None = None,
                **kwargs: Any) -> HedgeResult:
    """Run ``k`` of the given replica callables concurrently, first wins.

    Replicas are picked in order (callers shuffle / rank for diversity, as
    the DNS study ranks servers). ``cancel=True`` attempts
    ``Future.cancel()`` on the losers (only not-yet-started work can be
    cancelled — same constraint a real RPC layer has before the server
    dequeues the request).
    """
    k = max(1, min(k, len(replicas)))
    own_pool = executor is None
    pool = executor or ThreadPoolExecutor(max_workers=k)
    t0 = time.monotonic()
    futures: list[Future] = [pool.submit(replicas[i], *args, **kwargs)
                             for i in range(k)]
    try:
        done, pending = wait(futures, timeout=timeout,
                             return_when=FIRST_COMPLETED)
        if not done:
            raise TimeoutError(f"no replica completed within {timeout}s")
        # earliest completed future wins; exceptions propagate only if every
        # issued copy failed (redundancy masks single failures).
        winner_future = None
        for f in done:
            if f.exception() is None:
                winner_future = f
                break
        if winner_future is None:
            remaining = list(pending)
            while remaining:
                d, remaining_set = wait(remaining, return_when=FIRST_COMPLETED)
                remaining = list(remaining_set)
                for f in d:
                    if f.exception() is None:
                        winner_future = f
                        break
                if winner_future is not None:
                    break
            if winner_future is None:
                raise next(iter(done)).exception()  # every copy failed
        latency = time.monotonic() - t0
        cancelled = 0
        if cancel:
            for f in futures:
                if f is not winner_future and f.cancel():
                    cancelled += 1
        return HedgeResult(value=winner_future.result(),
                           winner=futures.index(winner_future),
                           latency=latency, k=k, losers_cancelled=cancelled)
    finally:
        if own_pool:
            pool.shutdown(wait=False, cancel_futures=True)
