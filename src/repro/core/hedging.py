"""Redundant ("hedged") execution — the paper's technique as a runtime.

The paper's prescription, operationalized:
  * duplicate a request to k diverse resources and take the first completion
    (``hedged_call``);
  * only duplicate while measured utilization is below the threshold load
    for the measured service distribution (``HedgePolicy`` — §2.1 says that
    threshold is 25-50%, so the default conservative threshold is 0.25 and a
    measured one can be plugged in);
  * optionally issue duplicates at lower priority so they never delay
    primary work (§2.4) — honored by the serving scheduler which passes
    ``priority=LOW`` for copies >= 1;
  * optionally cancel outstanding copies once one completes (beyond-paper:
    Dean & Barroso's "tied requests"; the paper's model serves every copy to
    completion, so cancellation is OFF by default).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Sequence

PRIORITY_HIGH = 0
PRIORITY_LOW = 1


@dataclasses.dataclass
class HedgeResult:
    value: Any
    winner: int               # index of the replica that completed first
    latency: float            # seconds until first completion
    k: int                    # number of copies actually issued
    losers_cancelled: int = 0


class LoadMeter:
    """EWMA utilization estimate: fraction of busy capacity.

    ``update`` is fed (busy_fraction in [0, 1]) samples by whoever owns the
    resource pool (the serving scheduler reports queue occupancy / busy
    replicas each tick).
    """

    def __init__(self, alpha: float = 0.1, init: float = 0.0):
        self.alpha = float(alpha)
        self._util = float(init)
        self._lock = threading.Lock()

    def update(self, busy_fraction: float) -> None:
        b = min(max(float(busy_fraction), 0.0), 1.0)
        with self._lock:
            self._util = (1.0 - self.alpha) * self._util + self.alpha * b

    @property
    def utilization(self) -> float:
        with self._lock:
            return self._util


@dataclasses.dataclass
class HedgePolicy:
    """Decide the replication factor for the next request.

    ``threshold`` should be the threshold load for the system's service-time
    distribution (estimated via ``repro.core.threshold``); the paper
    guarantees it lies in (0.258, 0.5) when client-side overhead is small,
    so 0.25 is a universally safe default. ``client_overhead_frac`` is the
    client-side duplication cost relative to mean service time; following
    §2.1/Fig 4, hedging is disabled when it is large.
    """

    max_k: int = 2
    threshold: float = 0.25
    client_overhead_frac: float = 0.0
    overhead_cutoff: float = 0.5  # Fig 4: overhead ~ mean latency kills gains

    def k_for(self, utilization: float) -> int:
        if self.client_overhead_frac >= self.overhead_cutoff:
            return 1
        # duplicating multiplies utilization by k; pick the largest k
        # whose k-fold load stays under the threshold.
        k = self.max_k
        while k > 1 and k * utilization >= self.threshold:
            k -= 1
        return k


def hedged_call(replicas: Sequence[Callable[..., Any]],
                *args: Any,
                k: int = 2,
                executor: ThreadPoolExecutor | None = None,
                cancel: bool = False,
                timeout: float | None = None,
                **kwargs: Any) -> HedgeResult:
    """Run ``k`` of the given replica callables concurrently, first wins.

    Replicas are picked in order (callers shuffle / rank for diversity, as
    the DNS study ranks servers). ``cancel=True`` attempts
    ``Future.cancel()`` on the losers (only not-yet-started work can be
    cancelled — same constraint a real RPC layer has before the server
    dequeues the request).
    """
    k = max(1, min(k, len(replicas)))
    own_pool = executor is None
    pool = executor or ThreadPoolExecutor(max_workers=k)
    t0 = time.monotonic()
    futures: list[Future] = [pool.submit(replicas[i], *args, **kwargs)
                             for i in range(k)]
    try:
        done, pending = wait(futures, timeout=timeout,
                             return_when=FIRST_COMPLETED)
        if not done:
            raise TimeoutError(f"no replica completed within {timeout}s")
        # earliest completed future wins; exceptions propagate only if every
        # issued copy failed (redundancy masks single failures).
        winner_future = None
        for f in done:
            if f.exception() is None:
                winner_future = f
                break
        if winner_future is None:
            remaining = list(pending)
            while remaining:
                d, remaining_set = wait(remaining, return_when=FIRST_COMPLETED)
                remaining = list(remaining_set)
                for f in d:
                    if f.exception() is None:
                        winner_future = f
                        break
                if winner_future is not None:
                    break
            if winner_future is None:
                raise next(iter(done)).exception()  # every copy failed
        latency = time.monotonic() - t0
        cancelled = 0
        if cancel:
            for f in futures:
                if f is not winner_future and f.cancel():
                    cancelled += 1
        return HedgeResult(value=winner_future.result(),
                           winner=futures.index(winner_future),
                           latency=latency, k=k, losers_cancelled=cancelled)
    finally:
        if own_pool:
            pool.shutdown(wait=False, cancel_futures=True)
