"""In-network packet replication on a k=6 fat-tree (paper §2.4).

Slot-synchronous packet simulation in JAX (one ``lax.scan`` over time
slots). Topology: 54 hosts, 18 edge, 18 agg, 9 core switches (3-layer
fat-tree, full bisection). Every directed link serves one 1500 B packet per
slot from a two-level strict-priority queue.

The paper's scheme: the first R packets of every flow are REPLICATED along
an alternate (edge->agg->core) path at strict LOW priority — duplicates can
never delay primary traffic. A packet is delivered when either copy
arrives. Primaries dropped at a full queue are retransmitted after an RTO
with exponential backoff (the §2.4 timeout-avoidance mechanism); dropped
duplicates simply vanish.

Simplifications vs ns-3 (documented in DESIGN.md §8): no TCP
congestion-window dynamics (flows are paced one packet/slot at the source),
drops happen on enqueue past the buffer cap, per-hop delay = 1 slot.
The reproduced phenomenology is Fig 14's: median FCT gain rising to
intermediate load then falling, and tail gains from RTO avoidance.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

K = 6
N_HOST = 54
N_EDGE = 18
N_AGG = 18
N_CORE = 9
# directed link ids: we enumerate (host->edge), (edge->agg), (agg->core),
# (core->agg), (agg->edge), (edge->host)
L_HE = 0
L_EA = N_HOST                       # 18 edges x 3 aggs = 54
L_AC = L_EA + 54                    # 18 aggs x 3 cores = 54
L_CA = L_AC + 54
L_AE = L_CA + 54
L_EH = L_AE + 54
N_LINKS = L_EH + N_HOST

MAX_HOPS = 6


def _edge_of(host: int) -> int:
    return host // 3


def _pod_of_edge(e: int) -> int:
    return e // 3


def _links_for_path(src: int, dst: int, up1: int, up2: int) -> list[int]:
    """Directed link ids for src->dst via agg choice up1 (0..2) and core
    choice up2 (0..2). Intra-edge flows shortcut at the edge switch."""
    es, ed = _edge_of(src), _edge_of(dst)
    out = [L_HE + src]
    if es == ed:
        out.append(L_EH + dst)
        return out
    ps, pd = _pod_of_edge(es), _pod_of_edge(ed)
    agg_s = ps * 3 + up1           # agg index within pod ps
    if ps == pd:
        # up to agg, back down to target edge
        out.append(L_EA + es * 3 + up1)
        out.append(L_AE + agg_s * 3 + (ed % 3))
        out.append(L_EH + dst)
        return out
    agg_d = pd * 3 + up1
    out.append(L_EA + es * 3 + up1)
    out.append(L_AC + agg_s * 3 + up2)
    out.append(L_CA + agg_d * 3 + up2)
    out.append(L_AE + agg_d * 3 + (ed % 3))
    out.append(L_EH + dst)
    return out


@dataclasses.dataclass(frozen=True)
class NetConfig:
    n_flows: int = 600
    load: float = 0.4               # fraction of host-link capacity
    mean_flow_pkts: int = 7         # ~10 KB at 1500 B
    elephant_frac: float = 0.05     # heavy flows (data-center mix [8])
    elephant_pkts: int = 200
    replicate_first: int = 8        # R packets duplicated (0 = baseline)
    buffer_pkts: int = 150          # 225 KB / 1500 B
    rto_slots: int = 300            # TCP minRTO >> RTT, the 10 ms analogue
    seed: int = 0


def build_workload(cfg: NetConfig):
    """Packet table (numpy, host side): paths, start slots, flow ids."""
    rng = np.random.default_rng(cfg.seed)
    sizes = np.where(rng.random(cfg.n_flows) < cfg.elephant_frac,
                     cfg.elephant_pkts,
                     1 + rng.geometric(1.0 / cfg.mean_flow_pkts))
    sizes = sizes.astype(np.int64)
    # Poisson flow arrivals so that offered load matches cfg.load
    total_pkts = sizes.sum()
    horizon = int(total_pkts / (N_HOST * cfg.load))
    starts = np.sort(rng.integers(0, max(horizon, 1), cfg.n_flows))
    src = rng.integers(0, N_HOST, cfg.n_flows)
    dst = (src + 1 + rng.integers(0, N_HOST - 1, cfg.n_flows)) % N_HOST

    rows = []  # (flow, seq, start_slot, prio, path..., is_dup)
    for f in range(cfg.n_flows):
        up1, up2 = rng.integers(0, 3), rng.integers(0, 3)
        alt1, alt2 = (up1 + 1 + rng.integers(0, 2)) % 3, rng.integers(0, 3)
        path = _links_for_path(int(src[f]), int(dst[f]), int(up1), int(up2))
        alt_path = _links_for_path(int(src[f]), int(dst[f]), int(alt1),
                                   int(alt2))
        for s in range(int(sizes[f])):
            t0 = int(starts[f]) + s  # paced: one packet per slot
            rows.append((f, s, t0, 0, path, 0))
            if s < cfg.replicate_first:
                rows.append((f, s, t0, 1, alt_path, 1))
    n = len(rows)
    paths = np.full((n, MAX_HOPS), -1, np.int32)
    meta = np.zeros((n, 5), np.int32)  # flow, seq, start, prio, is_dup
    lens = np.zeros((n,), np.int32)
    for i, (f, s, t0, prio, path, dup) in enumerate(rows):
        meta[i] = (f, s, t0, prio, dup)
        lens[i] = len(path)
        paths[i, :len(path)] = path
    return meta, paths, lens, sizes, starts


@partial(jax.jit, static_argnames=("n_slots", "buffer_pkts", "rto_slots"))
def _simulate(meta: Array, paths: Array, lens: Array, *, n_slots: int,
              buffer_pkts: int, rto_slots: int):
    """Advance the packet table slot by slot. Returns delivery slots (-1 if
    never delivered)."""
    n = meta.shape[0]
    flow, seq, start, prio, is_dup = (meta[:, 0], meta[:, 1], meta[:, 2],
                                      meta[:, 3], meta[:, 4])

    state = {
        "hop": jnp.zeros((n,), jnp.int32),
        "ready": start,                       # slot at which eligible
        "alive": jnp.ones((n,), bool),
        "delivered": jnp.full((n,), -1, jnp.int32),
        "retries": jnp.zeros((n,), jnp.int32),
    }

    big = jnp.int32(1 << 30)

    def slot_step(state, t):
        hop = state["hop"]
        cur_link = jnp.take_along_axis(paths, hop[:, None], axis=1)[:, 0]
        in_flight = (state["alive"] & (state["delivered"] < 0)
                     & (state["ready"] <= t))
        cur_link = jnp.where(in_flight, cur_link, N_LINKS)  # park inactive

        # queue occupancy per link (all waiting packets)
        occ = jax.ops.segment_sum(in_flight.astype(jnp.int32), cur_link,
                                  num_segments=N_LINKS + 1)

        # service: per link pick lexicographic (priority, ready, uid) via
        # three rounds of int32 segment_min (strict priority then FIFO)
        def seg_min(vals, mask):
            v = jnp.where(mask, vals, big)
            return jax.ops.segment_min(v, cur_link,
                                       num_segments=N_LINKS + 1)

        best_prio = seg_min(prio, in_flight)
        cand = in_flight & (prio == best_prio[cur_link])
        best_ready = seg_min(state["ready"], cand)
        cand = cand & (state["ready"] == best_ready[cur_link])
        uid = jnp.arange(n, dtype=jnp.int32)
        first_uid = seg_min(uid, cand)
        served = cand & (uid == first_uid[cur_link]) & (cur_link < N_LINKS)

        new_hop = jnp.where(served, hop + 1, hop)
        done = served & (new_hop >= lens)
        delivered = jnp.where(done & (state["delivered"] < 0), t,
                              state["delivered"])

        # next-queue overflow: drop or schedule retransmit
        nxt_link = jnp.take_along_axis(
            paths, jnp.minimum(new_hop, MAX_HOPS - 1)[:, None], axis=1)[:, 0]
        entering = served & ~done
        nxt_occ = occ[jnp.where(entering, nxt_link, N_LINKS)]
        overflow = entering & (nxt_occ >= buffer_pkts)
        # duplicates vanish on drop; primaries back off and retransmit
        drop_dup = overflow & (is_dup == 1)
        retrans = overflow & (is_dup == 0)
        alive = state["alive"] & ~drop_dup
        retries = jnp.where(retrans, state["retries"] + 1, state["retries"])
        backoff = rto_slots * (1 << jnp.minimum(retries, 6))
        ready = jnp.where(retrans, t + backoff,
                          jnp.where(served, t + 1, state["ready"]))
        new_hop = jnp.where(retrans, jnp.zeros_like(new_hop),
                            jnp.where(overflow, hop, new_hop))

        return {"hop": new_hop, "ready": ready, "alive": alive,
                "delivered": delivered, "retries": retries}, None

    state, _ = jax.lax.scan(slot_step, state, jnp.arange(n_slots))
    return state["delivered"]


def flow_completion_times(cfg: NetConfig, n_slots: int | None = None):
    """Run the sim; returns (fct_slots (n_flows,), sizes, short_mask,
    undelivered_mask). FCTs are RELATIVE slots (completion - start + 1);
    undelivered flows are censored at the horizon in the same units
    (n_slots - start)."""
    meta, paths, lens, sizes, starts = build_workload(cfg)
    if n_slots is None:
        n_slots = int(starts.max() + sizes.max() * 3 + 8 * cfg.rto_slots)
    delivered = np.asarray(_simulate(
        jnp.asarray(meta), jnp.asarray(paths), jnp.asarray(lens),
        n_slots=n_slots, buffer_pkts=cfg.buffer_pkts,
        rto_slots=cfg.rto_slots))
    flow, seq, start, prio, is_dup = meta.T
    n_flows = cfg.n_flows
    # Vectorized min-over-copies / max-over-packets reduction (the naive
    # version is an O(n_flows * n_packets) Python loop): scatter each
    # delivered copy's slot into a dense (flow, seq) table with
    # ``np.minimum.at`` (duplicates of a packet reduce to the earliest
    # arrival), then reduce per flow.
    big = np.int64(1) << 40
    max_pkts = int(sizes.max())
    best = np.full((n_flows, max_pkts), big)
    ok = delivered >= 0
    np.minimum.at(best, (flow[ok], seq[ok]), delivered[ok].astype(np.int64))
    valid = np.arange(max_pkts)[None, :] < sizes[:, None]
    undelivered = ((best == big) & valid).any(axis=1)
    last = np.where(valid, best, -big).max(axis=1)
    # Censor undelivered flows at the horizon IN RELATIVE SLOTS
    # (n_slots - starts) so they share units with delivered flows'
    # last - starts + 1 — the absolute n_slots would inflate censored
    # FCTs by a start-time-dependent amount.
    fct = np.where(undelivered, (float(n_slots) - starts).astype(np.float64),
                   last.astype(np.float64) - starts + 1.0)
    short = sizes <= 10
    return fct, sizes, short, undelivered


def empirical_fct_dist(cfg: NetConfig, n_slots: int | None = None, *,
                       short_only: bool = True, n_quantiles: int = 256):
    """Fit the simulated flow-completion times into a quantile-table
    ``EmpiricalDist`` (``distributions.empirical``). The Fig 14 tail
    analysis reads the short-flow FCT law off this table (its
    ``exceedance`` gives P[FCT > x] in slot units via ``scale``) instead
    of keeping raw per-flow arrays around — the same engine-native form
    every other service law uses, closing the "netsim is the last
    bespoke simulator" gap. ``short_only`` restricts the fit to the
    paper's short flows (<= 10 packets); undelivered flows keep their
    horizon-censored FCT, like the raw output."""
    from repro.core import distributions as dists

    fct, sizes, short, undelivered = flow_completion_times(cfg, n_slots)
    sel = fct[short] if short_only else fct
    kind = "short" if short_only else "all"
    return dists.empirical(sel, n_quantiles=n_quantiles,
                           name=f"netsim_fct[{kind}]")
