"""Discrete-event simulation of the paper's replication queueing model (§2.1).

Model (exactly as in the paper): ``N`` independent identical FIFO servers,
Poisson arrivals at rate ``N * rho`` (so each server sees utilization ``rho``
without replication), each arriving request is copied to ``k`` distinct
servers chosen uniformly at random, every copy is served to completion
(no cancellation — this is what doubles utilization), and the request's
response time is the minimum over its copies' (queueing delay + service
time). An optional fixed ``client_overhead`` is added to every request when
k > 1 (paper Figure 4).

Common random numbers (CRN): the arrival process, the first copy's server
choice, and the first copy's service time are identical for every ``k``
under the same seed, which makes paired k=2 vs k=1 comparisons (and hence
threshold estimation) low-variance.

Fused sweep engine — design note
--------------------------------

Every paper figure sweeps the same simulator over a (seed, load, k) grid,
and the pre-refactor code ran one sequential ``lax.scan`` per grid cell
from Python (``replication_gain`` alone ran ``2 * n_seeds`` full passes).
``sweep`` replaces those loops with ONE ``lax.scan`` over arrivals whose
carry stacks the per-server next-free times for the whole grid:

    free:  (S, B, K, N)   S seeds x B loads x K replication factors
                          x N servers

The scan step ``vmap``s a single-cell update (gather k server-free times,
max with the arrival time, add service, scatter back, min-reduce) over the
three grid axes. Randomness is sampled ONCE per seed at ``k_max = max(ks)``
and every k-slice consumes a prefix of the same copy set / service draws,
so the CRN coupling of the sequential path is preserved exactly: the k=1
slice sees bit-identical inputs to the old ``simulate_grid(key, ..., k=1)``.

The engine never materializes an ``(S, B, K, M)`` response array. Instead
it folds each response into streaming statistics:

  * a Kahan-compensated post-warmup sum (=> exact-to-float32 means), and
  * a log-spaced histogram sketch of ``n_bins`` buckets spanning
    [HIST_LO, HIST_HI], from which percentiles are read as geometric bin
    midpoints (relative error <= half a bin width, ~0.5% at the default
    2048 bins over 8 decades). The per-arrival one-hot scatter of PR 2 is
    gone: responses are staged in blocks of ``_SKETCH_BLOCK`` scan steps
    and folded into the histogram by the Pallas ``hist_sketch`` kernel
    (``repro.kernels.hist_sketch``), which contracts skinny 0/1 indicator
    matrices on the MXU and keeps the accumulator in VMEM (interpret mode
    off-TPU).

Chunk streaming (``chunk_size``)
--------------------------------

With ``chunk_size=None`` all randomness is pre-sampled, so host memory
caps ``n_arrivals`` at O(S * M * k_max). Passing ``chunk_size=T`` streams
the sweep instead: arrivals are processed in fixed-size chunks whose
gaps / copy sets / service times are freshly sampled per chunk, and only
the (S,B,K,N) free-time grid plus the streaming summaries cross chunk
boundaries. Peak memory is O(S * T * k_max + S*B*K*(N + n_bins)),
independent of ``n_arrivals`` — 10M-arrival sweeps run on a laptop.

Key-splitting / CRN contract (chunked mode):

  * Chunk ``c`` (arrivals ``[c*T, min((c+1)*T, M))``) draws ALL of its
    randomness from ``jax.random.fold_in(key, c)`` through the same
    samplers the unchunked engine uses, at ``n_arrivals=T``. The stream
    is a pure function of ``(key, chunk_size)``: reruns are bit-identical
    and chunk ``c``'s draws do not depend on how many chunks follow.
  * Every CRN pairing of the unchunked engine holds within each chunk —
    the arrival process is shared across loads, copy sets are nested
    across k (k=2's extra server is one of k=3's), copy j's service draw
    is shared by every k >= j, and ``sweep_dists`` gives all
    distributions the same arrival process — so paired comparisons
    (replication gain, thresholds) stay low-variance under chunking.
  * Different ``chunk_size`` values consume the key differently: the
    resulting summaries are statistically identical (same process, same
    estimator) but not bit-identical. ``chunk_size=None`` keeps the PR 2
    contract: seed ``s``, k-slice ``j`` sees bit-identical inputs to
    ``simulate_grid(split(key, n_seeds)[s], dist, rhos, cfg, ks[j])``.
  * Sharding invariance: the sharded executor
    (``repro.distributed.sweep_shard``) derives every cell's randomness
    from its SEED COORDINATE in the cell plan — chunk ``c``, seed ``s``
    draws from ``split(fold_in(key, c), n_seeds)[s]`` (``split(key,
    n_seeds)[s]`` unchunked) no matter which device owns the cell — and
    pad cells are sliced away before any summary is read. For the same
    ``(key, chunk_size)``, sharded and unsharded sweeps (and the
    thresholds derived from them) are therefore bit-identical for ANY
    device count.

Scenario & policy codes
-----------------------

``run(key, scenario, rhos, cfg, ...)`` is the public entry point: a
``repro.core.scenario.Scenario`` (or a sequence of them — a *mixed
grid*) declares replication policy, service model, ``ks``, client
overhead and warmup, and the engine lowers it to per-cell policy/model
CODES stored in the cell plan next to (seed, load, k). ``sweep`` /
``sweep_dists`` / ``replication_gain`` remain as thin paper-default
shims over ``run``.

Key-consumption contract per policy: every policy and service model
consumes EXACTLY the same randomness. The samplers always draw the full
``k_max`` copy set and all ``k_max`` per-copy service times, no matter
which policy uses how much of them — ``CANCEL_ON_COMPLETE`` discards a
cancelled loser's draw, ``REPLICATE_TO_IDLE`` discards the draws of
copies it never dispatches — and the ``SERVER_DEPENDENT`` service model
adds ONE extra column (the shared request component, sampled from
``fold_in(k_svc, k_max)``) only when a grid contains such a variant;
columns ``0..k_max-1`` are bit-identical either way. Policies and
models therefore stay CRN-paired with each other cell-for-cell: a
mixed grid's REPLICATE_ALL/IID column is bit-identical to the same
cell in a pure paper-default sweep, and paired policy comparisons
(cancel-vs-keep, idle-vs-all, any mix) are low-variance.

Why mixed grids stay ONE compiled body: the per-cell step branches on
the policy/model codes with ``jnp.where`` selects (all variants'
updates are computed, the cell's code picks one), so the vmapped cell
update has a single trace — no per-policy recompile, no ragged control
flow, and device-local state in the sharded executor is untouched. The
REPLICATE_ALL/IID branch is the pre-redesign computation op-for-op,
which is what keeps ``Scenario.paper_default`` bit-identical to the
legacy engine.

Execution layers
----------------

The engine is split into plan construction (``repro.core.cellplan``
flattens the stacked (S, B, K) axes into one padded cell axis), the
per-chunk body (``_sweep_chunk_cells``, one flat cell axis), and
finalization (``_finalize_summary``). ``_run_engine`` below drives the
body on a single device; ``repro.distributed.sweep_shard`` drives the
SAME body under ``shard_map`` over a 1-D ``"cells"`` device mesh.

Each chunk also rebases times to its own start (the free-time carry is
kept relative to the last chunk boundary), so float32 arrival times stay
O(chunk duration) instead of growing to O(total sim time) — long streams
LOSE no precision to the cumsum, unlike the pre-sampled path.

``simulate`` / ``simulate_grid`` remain for callers that need raw
per-arrival response times (tests, exact percentiles); they are thin
wrappers over the same single-cell step function.

Cell-update kernel (``kernel=``)
--------------------------------

The per-chunk body has two interchangeable, BIT-IDENTICAL
implementations dispatched by ``_sweep_chunk_cells``'s static
``use_kernel`` flag: the ``lax.scan`` reference
(``repro.kernels.cell_update.ref``, the default off-TPU) and a fused
Pallas kernel (``repro.kernels.cell_update.kernel``) that keeps each
cell's free-time grid, Kahan state and histogram counts resident in
VMEM across the whole chunk, writing carry to HBM once per chunk
instead of once per arrival. ``run(..., kernel=...)`` takes
``"auto"`` (kernel on TPU, scan elsewhere), ``"on"``, ``"off"`` or
``"interpret"`` (the kernel through the Pallas interpreter — how CPU
CI bit-tests the kernel path); the sharded executor threads the same
mode through ``shard_map``, preserving sharded==unsharded
bit-identity in every mode. Kernel mode pads every chunk to a
sketch-block multiple (scan mode only pads when the sketch is on) —
legal because zero-weight steps are bitwise no-ops on all carry state
(see ``ref.kahan_fold``), so padded and unpadded layouts agree bit
for bit. The step physics lives ONCE in
``repro.kernels.cell_update.ref.step_cell`` (re-exported here as
``_step_cell``); the kernel package's docstrings carry the VMEM
layout / block-size / CRN-contract design note.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import cellplan
from repro.core import chunkflow
from repro.core import scenario as scenario_mod
from repro.core.distributions import ServiceDist
from repro.core.scenario import (Policy, Scenario, ServiceModel,  # noqa: F401
                                 Variant)
from repro.kernels.cell_update import ops as cell_ops
from repro.kernels.cell_update.ref import cell_update_ref, step_cell
from repro.kernels.hist_sketch import ops as hist_ops
from repro.kernels.hist_sketch.ops import (DEFAULT_BINS, HIST_HI,  # noqa: F401
                                           HIST_LO)
from repro.launch import mesh as launch_mesh

Array = jax.Array

DEFAULT_PERCENTILES = (50.0, 90.0, 99.0, 99.9)

# Scan steps staged per hist_sketch kernel call; chunk lengths are padded
# up to a multiple of this with zero-weight no-op arrivals.
_SKETCH_BLOCK = 512


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Machine shape of a simulation. ``warmup_frac``/``client_overhead``
    are legacy knobs consumed by the paper-default shims (``sweep``,
    ``simulate``, the threshold estimators); ``run`` reads them from the
    ``Scenario`` instead."""

    n_servers: int = 20
    n_arrivals: int = 100_000
    warmup_frac: float = 0.1
    client_overhead: float = 0.0  # latency penalty added to replicated requests


def _overhead_when_replicated(overhead: float, k: int) -> float:
    """The paper's Figure 4 rule, in ONE place for every entry point:
    client overhead is charged only when a request is replicated (k > 1)."""
    return float(overhead) if k > 1 else 0.0


def _arrival_part(key: Array, n: int, m: int, k_max: int):
    """Distribution-independent randomness: unit-rate exponential gaps
    (scaled by the actual rate at sim time so the same key yields a coupled
    arrival process across loads) and the per-request copy sets."""
    k_gap, k_srv0, k_srvx, _ = jax.random.split(key, 4)
    unit_gaps = jax.random.exponential(k_gap, (m,))
    first = jax.random.randint(k_srv0, (m,), 0, n)
    if k_max > 1:
        # distinct extra copies: choose k-1 distinct offsets in [1, n).
        # The same score tensor is used for every k, so copy sets are nested
        # (k=2's extra server is also one of k=3's) — CRN across k.
        scores = jax.random.uniform(k_srvx, (m, n - 1))
        _, offs = jax.lax.top_k(scores, k_max - 1)  # (m, k_max-1) in [0, n-1)
        extra = (first[:, None] + 1 + offs) % n
        servers = jnp.concatenate([first[:, None], extra], axis=1)
    else:
        servers = first[:, None]
    return unit_gaps, servers


# fold_in index of the SERVER_DEPENDENT shared request component: FIXED —
# never a function of k or k_max — so the same arrival draws the same
# shared component in every grid layout and in the raw simulate paths
# (CRN across k and across entry points). Any constant that can never
# collide with a copy index works.
_SHARED_SVC_FOLD = 0x5CA1AB1E

# DEDICATED fold_in index of the degradation model's per-copy uniforms
# (copy j draws from fold_in(fold_in(k_svc, _DEGRADE_FOLD), j)). The
# PR-7 CRN contract: failure/straggler draws live on their OWN branch
# of the key tree — the service columns, the shared component and the
# arrival stream are untouched — so a healthy grid samples exactly the
# pre-degradation randomness (healthy cells keep today's bits), and
# degraded vs healthy cells stay CRN-paired draw-for-draw.
_DEGRADE_FOLD = 0xFA11ED


def _service_part(key: Array, dist: ServiceDist, cfg: SimConfig,
                  n_copies: int, with_shared: bool = False,
                  with_degr: bool = False):
    """Per-copy fold_in keys so copy j's service times are identical for
    every k_max (CRN: k=1 and k=2 share the first copy's service draw).
    ``with_shared`` appends the SERVER_DEPENDENT shared request component
    as one extra column, drawn from the fixed
    ``fold_in(k_svc, _SHARED_SVC_FOLD)``: the copy columns are
    bit-identical either way, and the shared column is identical for
    every ``n_copies`` — so it is CRN-shared across k, across grid
    layouts, and across the sweep/simulate entry points. ``with_degr``
    appends ``n_copies`` more uniform(0,1) columns (the degradation
    draws, one per copy) from the dedicated ``_DEGRADE_FOLD`` branch;
    column layout is ``[copies][shared?][degradation?]`` and the
    consumers receive the shared flag statically (the count alone is
    ambiguous at ``n_copies == 1``)."""
    m = cfg.n_arrivals
    _, _, _, k_svc = jax.random.split(key, 4)
    cols = [dist.sample(jax.random.fold_in(k_svc, j), (m,))
            for j in range(n_copies)]
    if with_shared:
        cols.append(dist.sample(
            jax.random.fold_in(k_svc, _SHARED_SVC_FOLD), (m,)))
    if with_degr:
        k_deg = jax.random.fold_in(k_svc, _DEGRADE_FOLD)
        cols.extend(jax.random.uniform(jax.random.fold_in(k_deg, j), (m,))
                    for j in range(n_copies))
    return jnp.stack(cols, axis=1)


def _sample_inputs(key: Array, dist: ServiceDist, cfg: SimConfig, k_max: int,
                   with_shared: bool = False, with_degr: bool = False):
    """Draw all randomness up front. Column 0 of servers/services is shared
    by every k (CRN); services carry the extra shared-component column
    when ``with_shared`` (SERVER_DEPENDENT scenarios) and the per-copy
    degradation uniforms when ``with_degr`` (degraded scenarios)."""
    unit_gaps, servers = _arrival_part(key, cfg.n_servers, cfg.n_arrivals,
                                       k_max)
    services = _service_part(key, dist, cfg, k_max, with_shared, with_degr)
    return unit_gaps, servers, services


# The single-arrival physics moved to the cell_update kernel package so
# the scan body and the Pallas kernel share one source of truth; kept
# under the old private name for the raw-response paths and tests.
_step_cell = step_cell


def _scan_sim(arrivals: Array, servers: Array, services: Array, n_servers: int,
              variant: Variant) -> Array:
    """Run the FIFO replication DES for ONE scenario variant. arrivals
    (M,), servers (M,k), services (M,k) or (M,k+1) with the shared
    component last -> response times (M,). Shares ``_step_cell`` with the
    sweep engine, so raw-response callers exercise the same policy/model
    code path."""
    k = servers.shape[1]
    ovh = jnp.asarray(_overhead_when_replicated(variant.overhead, k),
                      jnp.float32)
    mask = jnp.ones((k,), bool)
    pol = jnp.asarray(int(variant.policy), jnp.int32)
    mdl = jnp.asarray(int(variant.service_model), jnp.int32)
    mix = jnp.asarray(variant.mix, jnp.float32)
    psl = jnp.asarray(variant.p_slow, jnp.float32)
    sfa = jnp.asarray(variant.slow_factor, jnp.float32)
    pfl = jnp.asarray(variant.p_fail, jnp.float32)
    dly = jnp.asarray(variant.delay, jnp.float32)
    n_base = k + (1 if variant.needs_shared_draw else 0)
    has_degr = variant.needs_degradation_draw

    has_timed = variant.policy in scenario_mod.TIMED_POLICIES

    def step(free: Array, inp):
        t, srv, svc = inp
        shared = svc[k] if variant.needs_shared_draw else svc[0]
        degr = svc[n_base:n_base + k] if has_degr else jnp.zeros((k,))
        return _step_cell(free, t, srv, svc[:k], shared, degr, mask, ovh,
                          pol, mdl, mix, psl, sfa, pfl, dly,
                          has_timed=has_timed)

    free0 = jnp.zeros((n_servers,))
    _, resp = jax.lax.scan(step, free0, (arrivals, servers, services))
    return resp


@partial(jax.jit, static_argnames=("dist", "cfg", "k", "scenario"))
def simulate(key: Array, dist: ServiceDist, rho: Array, cfg: SimConfig,
             k: int = 1, *, scenario: Scenario | None = None) -> Array:
    """Response times (M,) for a single load ``rho`` and replication ``k``.

    Routed through the paper-default ``Scenario`` shim by default;
    ``scenario`` overrides policy / service model / mix / overhead for
    raw-response studies of the wider policy space (its ``dists``/``ks``
    are ignored here — ``dist``/``k`` stay authoritative).
    """
    scn = scenario or Scenario.paper_default(
        dist, client_overhead=cfg.client_overhead,
        warmup_frac=cfg.warmup_frac)
    variant = scn.variant_for(k)
    unit_gaps, servers, services = _sample_inputs(
        key, dist, cfg, k, with_shared=variant.needs_shared_draw,
        with_degr=variant.needs_degradation_draw)
    rate = cfg.n_servers * rho
    arrivals = jnp.cumsum(unit_gaps / rate)
    return _scan_sim(arrivals, servers[:, :k], services,
                     cfg.n_servers, variant)


@partial(jax.jit, static_argnames=("dist", "cfg", "k", "scenario"))
def simulate_grid(key: Array, dist: ServiceDist, rhos: Array, cfg: SimConfig,
                  k: int = 1, *, scenario: Scenario | None = None) -> Array:
    """Response times (B, M) for a grid of loads, one coupled sample path.
    ``scenario`` as in ``simulate``."""
    scn = scenario or Scenario.paper_default(
        dist, client_overhead=cfg.client_overhead,
        warmup_frac=cfg.warmup_frac)
    variant = scn.variant_for(k)
    unit_gaps, servers, services = _sample_inputs(
        key, dist, cfg, k, with_shared=variant.needs_shared_draw,
        with_degr=variant.needs_degradation_draw)
    rates = cfg.n_servers * rhos  # (B,)
    arrivals = jnp.cumsum(unit_gaps)[None, :] / rates[:, None]  # (B, M)
    sim = jax.vmap(
        lambda a: _scan_sim(a, servers[:, :k], services,
                            cfg.n_servers, variant))
    return sim(arrivals)


def _warm(resp: Array, cfg: SimConfig) -> Array:
    start = int(cfg.n_arrivals * cfg.warmup_frac)
    return resp[..., start:]


def summarize(resp: Array, cfg: SimConfig,
              percentiles=DEFAULT_PERCENTILES) -> dict[str, Array]:
    """Post-warmup mean + percentiles along the last axis."""
    r = _warm(resp, cfg)
    out = {"mean": jnp.mean(r, axis=-1)}
    for p in percentiles:
        out[f"p{p:g}"] = jnp.percentile(r, p, axis=-1)
    return out


# ---------------------------------------------------------------------------
# Fused sweep engine
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_servers", "n_arrivals", "k_max",
                                   "n_seeds"))
def _sample_sweep_arrivals(key: Array, n_servers: int, n_arrivals: int,
                           k_max: int, n_seeds: int):
    """(S,M) unit gaps + (S,M,k_max) copy sets. Distribution-independent and
    keyed only on the shape-bearing config fields (NOT the whole SimConfig),
    so its (one, comparatively expensive) compile is shared by every family
    — and every client_overhead / warmup variant — a benchmark sweeps."""
    keys = jax.random.split(key, n_seeds)
    return jax.vmap(
        lambda kk: _arrival_part(kk, n_servers, n_arrivals, k_max))(keys)


def _sample_sweep_services(key: Array, dist: ServiceDist, cfg: SimConfig,
                           k_max: int, n_seeds: int,
                           with_shared: bool = False,
                           with_degr: bool = False):
    """(S,M,n_svc) service draws (``n_svc = k_max + with_shared +
    k_max * with_degr``, layout ``[copies][shared?][degradation?]``).
    Deliberately NOT jitted: eager sampling reuses jax's per-op caches
    across distributions, so sweeping 15 families costs 15 x ~20ms
    instead of 15 x ~1s of per-family jit compiles (the PRNG bits are
    identical either way)."""
    keys = jax.random.split(key, n_seeds)
    return jnp.stack([_service_part(keys[s], dist, cfg, k_max, with_shared,
                                    with_degr)
                      for s in range(n_seeds)], axis=0)


def _sample_sweep_inputs(key: Array, dist: ServiceDist, cfg: SimConfig,
                         k_max: int, n_seeds: int,
                         with_shared: bool = False,
                         with_degr: bool = False):
    """Per-seed randomness for the engine: (S,M) gaps, (S,M,k_max) servers,
    (S,M,n_svc) services (shared component column for SERVER_DEPENDENT
    grids, per-copy degradation uniforms for degraded grids — see
    ``_service_part``). Bit-identical to ``n_seeds`` sequential
    ``_sample_inputs`` calls on ``jax.random.split(key, n_seeds)``."""
    unit_gaps, servers = _sample_sweep_arrivals(
        key, cfg.n_servers, cfg.n_arrivals, k_max, n_seeds)
    services = _sample_sweep_services(key, dist, cfg, k_max, n_seeds,
                                      with_shared, with_degr)
    return unit_gaps, servers, services


@partial(jax.jit, static_argnames=("n_servers", "n_bins", "block",
                                   "use_kernel", "has_shared",
                                   "has_timed", "has_dists"))
def _sweep_chunk_cells(free: Array, ssum: Array, comp: Array, cnt: Array,
                       hist: Array,
                       unit_gaps: Array, servers: Array, services: Array,
                       start: Array, n_valid: Array, warmup_start: Array,
                       seed_idx: Array, rates: Array, k_mask: Array,
                       ovh: Array, policy_code: Array, model_code: Array,
                       mix: Array, p_slow: Array, slow_factor: Array,
                       p_fail: Array, delay: Array, svc_idx: Array = None,
                       *, n_servers: int,
                       n_bins: int, block: int, use_kernel: str = "off",
                       has_shared: bool = False, has_timed: bool = False,
                       has_dists: bool = False):
    """Scenario- and distribution-agnostic fused core over ONE chunk of
    arrivals, on a flat cell axis (see ``repro.core.cellplan``).

    Per-cell carry threaded across chunks: ``free`` (C,N) server-free
    times RELATIVE to the chunk-start arrival time, ``ssum``/``comp``
    (C,) Kahan mean state, ``cnt`` (C,) completed post-warmup response
    counts (== the static post-warmup count for cells that cannot lose
    requests; less for degraded cells with blackholed copies), ``hist``
    (C, n_bins) sketch counts (shape (0, 0) skips the sketch). Sampled
    inputs stay at SEED granularity — ``unit_gaps`` (S,T), ``servers``
    (S,T,k_max), ``services`` (S,T,n_svc) with column layout
    ``[k_max copies][shared if has_shared][k_max degradation uniforms
    if present]`` (``has_shared`` is static; the degradation columns
    are detected from the remainder) — and ``seed_idx`` (C,) maps each
    cell to its input row, so one sampled row is shared by all
    (load, k) cells of a seed: the gather happens per scan step on a
    (S,k_max) slice, and the (C,T,...) expansion is never
    materialized. The sharded driver runs this same body per shard with
    the inputs replicated and ``seed_idx`` restricted to the local
    cells (global seed indices, sharded over the mesh).

    HETEROGENEOUS grids (``has_dists=True``, per-cell ``dist_id``):
    ``services`` carries one (n_seeds, T, n_svc) table PER dist-union
    member stacked along axis 0, and ``svc_idx`` (C,) =
    ``dist_id * n_seeds + seed_idx`` routes each cell's SERVICE gather
    to its system's table — gaps/servers/rebase stay ``seed_idx``-keyed
    (arrivals and copy sets are CRN-shared across systems). With
    ``has_dists=False`` (the default) ``svc_idx`` is unused and the
    compiled program is exactly the pre-dist_id one.
    ``rates``/``ovh``/``mix``/``p_slow``/``slow_factor``/``p_fail``/
    ``delay`` (C,), ``k_mask`` (C,k_max) and the ``policy_code``/
    ``model_code`` (C,) scenario coordinates are per-cell parameters
    gathered from the plan; the vmapped ``_step_cell`` branches on the
    codes per lane, which is what lets a MIXED grid (cells disagreeing
    on policy/model/degradation) run in this one compiled body. Callers
    that pass SERVER_DEPENDENT or degraded codes must supply the extra
    services columns (healthy/IID layouts reuse column 0 / zeros as
    dummies that the selects discard).

    ``start`` is the global index of the chunk's first step; ``n_valid``
    the real (non-padding) steps. Steps past ``n_valid`` are masked to
    zero-gap / zero-service / zero-weight no-ops — they can only bump an
    idle server's free time up to the chunk-end arrival time, which no
    later arrival (all at times >= it) can observe.

    When the sketch is on, the scan is staged in ``block``-step
    sub-blocks whose responses are folded into ``hist`` by the Pallas
    hist_sketch kernel — no per-step scatter, no (C,T) materialization
    beyond one block. Returns the carry with ``free`` rebased to the
    chunk-end time.

    ``use_kernel`` picks the body implementation (see the module design
    note): ``"off"`` runs the ``lax.scan`` reference
    (``cell_update_ref``), ``"on"`` / ``"interpret"`` the fused Pallas
    kernel (compiled / interpreted) — bit-identical by contract, pinned
    by the kernel parity tests. Kernel modes require ``T`` padded to
    the ``block`` multiple even without the sketch (``_chunk_layout``
    arranges this).
    """
    S, T = unit_gaps.shape
    need_hist = hist.size > 0
    if need_hist:
        assert T % block == 0, (T, block)

    i = jnp.arange(T)
    valid = i < n_valid                                       # (T,)
    warm = (valid & (start + i >= warmup_start)).astype(jnp.float32)
    gaps = unit_gaps * valid
    services = services * valid[None, :, None]
    cum = jnp.cumsum(gaps, axis=1)      # (S, T) offsets from chunk start

    body = (cell_update_ref if use_kernel == "off"
            else partial(cell_ops.cell_update,
                         interpret=(use_kernel == "interpret")))
    free, ssum, comp, cnt, hist = body(
        free, ssum, comp, cnt, hist, cum, warm,
        valid.astype(jnp.float32), servers, services, seed_idx,
        rates, k_mask, ovh, policy_code, model_code, mix, p_slow,
        slow_factor, p_fail, delay, svc_idx,
        n_servers=n_servers, n_bins=n_bins, block=block,
        has_shared=has_shared, has_timed=has_timed, has_dists=has_dists)

    # rebase to the chunk-end arrival time so floats stay O(chunk duration)
    free = free - (cum[:, -1][seed_idx] / rates)[:, None]
    return free, ssum, comp, cnt, hist


# --- plan construction / finalization shared by both execution layers ----

def _plan_cell_params(plan: cellplan.CellPlan, rhos: Array, cfg: SimConfig,
                      variants):
    """Per-cell engine parameters gathered from the plan's coordinates:
    arrival rates (C,), copy masks (C,k_max), client overheads (C,),
    service-model mixes (C,), degradation probabilities / straggler
    factors / blackhole probabilities / policy delays (C,) each.
    ``variants`` may be a plain ``ks`` tuple (paper default per k,
    overhead from ``cfg``) or per-variant ``scenario.Variant``s."""
    variants = tuple(
        v if isinstance(v, Variant)
        else Variant(k=int(v), overhead=cfg.client_overhead)
        for v in variants)
    k_max = max(v.k for v in variants)
    rates = cfg.n_servers * jnp.asarray(rhos)
    k_mask = jnp.asarray([[j < v.k for j in range(k_max)] for v in variants])
    ovh = jnp.asarray([_overhead_when_replicated(v.overhead, v.k)
                       for v in variants], jnp.float32)
    mix = jnp.asarray([v.mix for v in variants], jnp.float32)
    p_slow = jnp.asarray([v.p_slow for v in variants], jnp.float32)
    s_fac = jnp.asarray([v.slow_factor for v in variants], jnp.float32)
    p_fail = jnp.asarray([v.p_fail for v in variants], jnp.float32)
    delay = jnp.asarray([v.delay for v in variants], jnp.float32)
    return (rates[plan.load_idx], k_mask[plan.k_idx], ovh[plan.k_idx],
            mix[plan.k_idx], p_slow[plan.k_idx], s_fac[plan.k_idx],
            p_fail[plan.k_idx], delay[plan.k_idx])


def _init_cell_state(plan: cellplan.CellPlan, cfg: SimConfig, n_bins: int,
                     need_hist: bool):
    """Zeroed per-cell carry: free times, Kahan state, completed-response
    counts, sketch counts."""
    free = jnp.zeros((plan.n_padded, cfg.n_servers))
    ssum = comp = cnt = jnp.zeros((plan.n_padded,))
    hist = (jnp.zeros((plan.n_padded, n_bins)) if need_hist
            else jnp.zeros((0, 0)))
    return free, ssum, comp, cnt, hist


def _chunk_layout(cfg: SimConfig, chunk_size: int | None, need_hist: bool,
                  kernel_on: bool = False):
    """(chunk length, #chunks, sketch block, pad-to-block) of a stream.

    Chunks are padded to a block multiple when the sketch needs staged
    sub-blocks OR the Pallas cell-update kernel is on (its time grid is
    blocked unconditionally). Padding never changes bits: zero-weight
    steps are bitwise no-ops on the whole carry (``ref.kahan_fold``)."""
    m = cfg.n_arrivals
    t_chunk = m if chunk_size is None else min(int(chunk_size), m)
    n_chunks = math.ceil(m / t_chunk)
    block = min(_SKETCH_BLOCK, t_chunk)
    pad = (-t_chunk) % block if (need_hist or kernel_on) else 0
    return t_chunk, n_chunks, block, pad


def _pad_chunk_inputs(unit_gaps: Array, servers: Array, services: Array,
                      pad: int):
    """Zero-pad a chunk's sampled inputs up to the sketch-block multiple."""
    if pad:
        unit_gaps = jnp.pad(unit_gaps, ((0, 0), (0, pad)))
        servers = jnp.pad(servers, ((0, 0), (0, pad), (0, 0)))
        services = jnp.pad(services, ((0, 0), (0, pad), (0, 0)))
    return unit_gaps, servers, services


def _finalize_summary(plan: cellplan.CellPlan, ssum: Array, cnt: Array,
                      hist: Array, count: int,
                      percentiles: tuple[float, ...]) -> dict[str, Array]:
    """Per-cell streaming state -> stacked (S,B,K) summaries. This is the
    single point where the sharded executor's device-local buffers are
    gathered (``unflatten`` slices pad cells away first, so they cannot
    contribute to any summary). ``count`` is the static post-warmup
    OFFERED count; ``cnt`` the per-cell COMPLETED count (less when a
    degraded cell blackholes every copy of a request). The mean divides
    by ``cnt`` — bit-identical to the pre-degradation ``ssum / count``
    for cells that cannot lose requests, since their float count equals
    the int exactly (f32 is exact on integers below 2**24)."""
    completed = cellplan.unflatten(plan, cnt)
    out: dict[str, Array] = {
        "mean": cellplan.unflatten(plan, ssum) / completed,
        "count": count, "completed": completed}
    if len(percentiles) > 0:
        quant = hist_ops.sketch_quantiles(
            cellplan.unflatten(plan, hist),
            jnp.asarray(percentiles, jnp.float32))            # (Q,S,B,K)
        for qi, p in enumerate(percentiles):
            out[f"p{p:g}"] = quant[qi]
    return out


def _record_pipeline_stats(sampler, *, enabled: bool, n_chunks: int,
                           t_pad: int, seed_rows: int,
                           svc_rows: int) -> None:
    """Publish this run's pipeline + sampling shape to ``chunkflow`` so
    the benchmark harness can attach it as JSON provenance. ``seed_rows``
    / ``svc_rows`` are the rows THIS process sampled per chunk (the full
    block on one process; the per-host reduction on many)."""
    spec = getattr(sampler, "spec", None)
    if spec is None:
        return
    k_max, n_svc = spec.k_max, spec.n_svc_cols

    def nbytes(n_seed, n_svc_rows):
        # f32 gaps (rows, T) + i32 servers (rows, T, k_max)
        # + f32 services (rows, T, n_svc)
        return 4 * t_pad * (n_seed * (1 + k_max) + n_svc_rows * n_svc)

    chunkflow.record_stats(chunkflow.PipelineStats(
        enabled=enabled, depth=chunkflow.DEFAULT_DEPTH, n_chunks=n_chunks,
        seed_rows_sampled=seed_rows, seed_rows_total=spec.n_seed_rows,
        svc_rows_sampled=svc_rows, svc_rows_total=spec.n_svc_rows,
        bytes_sampled_per_chunk=nbytes(seed_rows, svc_rows),
        bytes_full_per_chunk=nbytes(spec.n_seed_rows, spec.n_svc_rows),
        process_count=jax.process_count(),
        process_index=jax.process_index()))


def _run_engine(sampler, n_seeds_total: int, rhos: Array, cfg: SimConfig, *,
                variants: tuple[Variant, ...], warmup_frac: float,
                percentiles: tuple[float, ...],
                n_bins: int, chunk_size: int | None,
                use_kernel: str = "off",
                pipeline: str = "off") -> dict[str, Array]:
    """Drive ``_sweep_chunk_cells`` over the whole arrival stream on one
    device: unpadded cell plan (variant policy/model codes as per-cell
    coordinates), seed-level sampled inputs shared by each seed's
    (load, variant) cells.

    ``sampler(chunk_idx, chunk_len)`` returns that chunk's
    ``(unit_gaps (S,T), servers (S,T,k_max), services (S,T,n_svc))`` —
    one call over the full stream when ``chunk_size`` is None.
    ``use_kernel`` is a RESOLVED kernel mode (never ``"auto"``); so is
    ``pipeline`` (``"on"``/``"off"``, never ``"auto"``): ``"on"``
    prefetches chunk ``c+1``'s inputs on a producer thread — through the
    sampler's FUSED jit entry point, one dispatch per chunk — while the
    chunk body for ``c`` runs (``repro.core.chunkflow``); bit-identical
    to ``"off"`` because it changes when inputs are sampled, never what.
    """
    m = cfg.n_arrivals
    policies, models = scenario_mod.variant_codes(variants)
    plan = cellplan.make_cell_plan(
        n_seeds_total, rhos.shape[0], len(variants),
        policies=policies, models=models,
        dist_ids=scenario_mod.variant_dist_ids(variants))
    (rates_c, k_mask_c, ovh_c, mix_c, pslow_c, sfac_c, pfail_c,
     delay_c) = _plan_cell_params(plan, rhos, cfg, variants)
    has_shared = scenario_mod.any_server_dependent(variants)
    has_timed = scenario_mod.any_timed(variants)
    has_dists = scenario_mod.any_dist_ids(variants)
    # heterogeneous grids: route each cell's service gather to its
    # system's table row (services stacks one table per union member
    # along the seed axis); None keeps the legacy jaxpr untouched
    svc_idx_c = (plan.dist_id * n_seeds_total + plan.seed_idx
                 if has_dists else None)
    warmup_start = int(m * warmup_frac)
    need_hist = len(percentiles) > 0
    t_chunk, n_chunks, block, pad = _chunk_layout(
        cfg, chunk_size, need_hist, kernel_on=use_kernel != "off")
    free, ssum, comp, cnt, hist = _init_cell_state(plan, cfg, n_bins,
                                                   need_hist)

    use_pipe = pipeline == "on" and n_chunks > 1
    fused = getattr(sampler, "fused", None)
    draw = fused if (use_pipe and fused is not None) else sampler

    def produce(c: int):
        return _pad_chunk_inputs(*draw(c, t_chunk), pad)

    for c, (unit_gaps, servers, services) in enumerate(
            chunkflow.iter_staged(produce, n_chunks, enabled=use_pipe)):
        start = c * t_chunk
        free, ssum, comp, cnt, hist = _sweep_chunk_cells(
            free, ssum, comp, cnt, hist, unit_gaps, servers, services,
            jnp.asarray(start), jnp.asarray(min(t_chunk, m - start)),
            jnp.asarray(warmup_start), plan.seed_idx, rates_c, k_mask_c,
            ovh_c, plan.policy_code, plan.model_code, mix_c, pslow_c,
            sfac_c, pfail_c, delay_c, svc_idx_c,
            n_servers=cfg.n_servers, n_bins=n_bins, block=block,
            use_kernel=use_kernel, has_shared=has_shared,
            has_timed=has_timed, has_dists=has_dists)
    # block on the last chunk so the producer thread (if any) is drained
    # before stats are read, then record sampling provenance
    jax.block_until_ready(ssum)
    spec = getattr(sampler, "spec", None)
    _record_pipeline_stats(
        sampler, enabled=use_pipe, n_chunks=n_chunks, t_pad=t_chunk + pad,
        seed_rows=spec.n_seed_rows if spec is not None else 0,
        svc_rows=spec.n_svc_rows if spec is not None else 0)

    return _finalize_summary(plan, ssum, cnt, hist, m - warmup_start,
                             percentiles)


def _chunk_key(key: Array, chunk_idx: int, chunk_size: int | None) -> Array:
    """The key-splitting contract: chunk c draws from fold_in(key, c);
    the unchunked stream consumes ``key`` itself (PR 2 compatible)."""
    return key if chunk_size is None else jax.random.fold_in(key, chunk_idx)


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Hashable static descriptor of a sweep's per-chunk randomness.

    ``kind`` picks the input-block layout (matching the three legacy
    sampler closures):

      ``"single"``   one distribution; gaps/servers/services all have
                     ``n_seeds`` rows.
      ``"stacked"``  legacy multi-dist sweeps (``sweep_dists``): every
                     dist shares the arrival process (CRN), so gaps /
                     servers are sampled once and TILED ``d`` times;
                     seed-row and service-row spaces both have
                     ``d * n_seeds`` rows.
      ``"tables"``   heterogeneous per-cell ``dist_id`` grids: gaps /
                     servers keep ``n_seeds`` rows, services stack one
                     table per dist-union member (``d * n_seeds``
                     service rows reached via ``svc_idx``).

    Being a frozen dataclass of hashables (``ServiceDist`` is already a
    static jit argument elsewhere), a spec is a valid static jit key —
    the fused samplers below compile once per spec and are shared by
    every chunk of a run.
    """

    kind: str
    dists: tuple[ServiceDist, ...]
    cfg: SimConfig
    k_max: int
    n_seeds: int
    with_shared: bool = False
    with_degr: bool = False

    @property
    def n_dists(self) -> int:
        return len(self.dists)

    @property
    def n_seed_rows(self) -> int:
        """Rows of the gaps/servers block (the seed-row space)."""
        return self.n_seeds * (self.n_dists if self.kind == "stacked"
                               else 1)

    @property
    def n_svc_rows(self) -> int:
        """Rows of the services block (the service-row space)."""
        return self.n_seeds * (self.n_dists if self.kind != "single"
                               else 1)

    @property
    def n_svc_cols(self) -> int:
        return (self.k_max + int(self.with_shared)
                + self.k_max * int(self.with_degr))


def _sample_chunk(spec: SamplerSpec, ck: Array, t: int):
    """One chunk's full ``(gaps, servers, services)`` block for any
    sampler kind — op-for-op the legacy closure bodies, so eager
    execution reproduces their exact per-op sequence (and bits)."""
    ccfg = dataclasses.replace(spec.cfg, n_arrivals=t)
    gaps, servers = _sample_sweep_arrivals(
        ck, spec.cfg.n_servers, t, spec.k_max, spec.n_seeds)
    if spec.kind == "single":
        services = _sample_sweep_services(
            ck, spec.dists[0], ccfg, spec.k_max, spec.n_seeds,
            spec.with_shared, spec.with_degr)
    else:
        services = jnp.concatenate(
            [_sample_sweep_services(ck, dd, ccfg, spec.k_max,
                                    spec.n_seeds, spec.with_shared,
                                    spec.with_degr)
             for dd in spec.dists], axis=0)
    if spec.kind == "stacked":
        d = spec.n_dists
        gaps, servers = (jnp.tile(gaps, (d, 1)),
                         jnp.tile(servers, (d, 1, 1)))
    return gaps, servers, services


@partial(jax.jit, static_argnames=("spec", "t"))
def _sample_chunk_fused(spec: SamplerSpec, ck: Array, t: int):
    """The same block as ONE jitted program. Bit-identical to the eager
    path (pinned by tests/test_multihost.py): the PRNG transforms'
    op shapes are per seed row either way, so XLA's shape-dependent
    ULP wobble (see the sweep_shard design note) cannot bite. One
    dispatch per chunk is what lets the sampling/compute pipeline
    overlap host sampling with device compute."""
    return _sample_chunk(spec, ck, t)


def _sample_chunk_rows(spec: SamplerSpec, ck: Array, t: int,
                       seed_rows: tuple[int, ...],
                       svc_rows: tuple[int, ...]):
    """Row-reduced sampling: draw ONLY the requested global rows of the
    chunk's input block.

    Row ``r`` of the seed-row space always derives from per-seed key
    ``split(ck, n_seeds)[r % n_seeds]`` (the tiled "stacked" layout
    repeats seed keys every ``n_seeds`` rows), and service row ``r``
    from ``(dists[r // n_seeds], split(ck, n_seeds)[r % n_seeds])`` —
    per-seed determinism, so each returned row is bit-identical to the
    corresponding row of ``_sample_chunk``'s full block no matter which
    subset is requested (pinned by tests/test_multihost.py). This is
    the per-host sampling reduction: a multi-host executor passes just
    the rows its local cells gather instead of the full
    O(all-rows x chunk) block.

    Deliberately EAGER, never jitted: under jit XLA fuses the stacked
    per-row service draws into one program whose op shapes depend on
    WHICH rows were requested, and that shape-dependent fusion wobbles
    individual draws by 1 ULP (observed: requesting all rows of a
    4-seed block flipped ~0.1% of row 0's service values — see the
    sweep_shard design note). Eagerly, every row is the same
    per-op-cached ``_service_part`` call the full block makes, so
    bit-identity is by construction, not by XLA's grace.
    """
    ccfg = dataclasses.replace(spec.cfg, n_arrivals=t)
    keys = jax.random.split(ck, spec.n_seeds)
    seed_of = jnp.asarray([r % spec.n_seeds for r in seed_rows])
    gaps, servers = jax.vmap(
        lambda kk: _arrival_part(kk, spec.cfg.n_servers, t,
                                 spec.k_max))(keys[seed_of])
    services = jnp.stack(
        [_service_part(keys[r % spec.n_seeds],
                       spec.dists[r // spec.n_seeds], ccfg, spec.k_max,
                       spec.with_shared, spec.with_degr)
         for r in svc_rows], axis=0)
    return gaps, servers, services


class ChunkSampler:
    """The engine's per-chunk input sampler.

    Callable with ``(chunk_idx, chunk_len)`` — the legacy closure
    protocol, drawing the full block EAGERLY (the PR 3 path: per-op
    caches shared across dist families, no per-family jit compile).
    Two additional entry points serve the pipeline and the multi-host
    executor, both bit-identical to the eager call by construction:

      ``fused(c, t)``                    the full block as one jitted
                                         dispatch (compiled per spec).
      ``rows(c, t, seed_rows, svc_rows)`` only the requested global
                                         rows (per-host reduction);
                                         eager, so the requested subset
                                         cannot change the bits (see
                                         ``_sample_chunk_rows``).
    """

    def __init__(self, spec: SamplerSpec, key: Array,
                 chunk_size: int | None):
        self.spec = spec
        self.key = key
        self.chunk_size = chunk_size

    def chunk_key(self, c: int) -> Array:
        return _chunk_key(self.key, c, self.chunk_size)

    def __call__(self, c: int, t: int):
        return _sample_chunk(self.spec, self.chunk_key(c), t)

    def fused(self, c: int, t: int):
        return _sample_chunk_fused(self.spec, self.chunk_key(c), t)

    def rows(self, c: int, t: int, seed_rows, svc_rows):
        return _sample_chunk_rows(self.spec, self.chunk_key(c), t,
                                  tuple(int(r) for r in seed_rows),
                                  tuple(int(r) for r in svc_rows))


def _sweep_sampler(key: Array, dist: ServiceDist, cfg: SimConfig,
                   k_max: int, n_seeds: int, chunk_size: int | None,
                   with_shared: bool = False, with_degr: bool = False):
    """The per-chunk sampler behind ``run``/``sweep``. Shared — this
    exact object, not a copy — with the sharded executor, so the two
    paths cannot drift apart on the CRN-critical sampling code the
    bit-identity contract depends on."""
    return ChunkSampler(SamplerSpec("single", (dist,), cfg, k_max,
                                    n_seeds, with_shared, with_degr),
                        key, chunk_size)


def _sweep_dists_sampler(key: Array, dist_list, cfg: SimConfig,
                         k_max: int, n_seeds: int,
                         chunk_size: int | None,
                         with_shared: bool = False,
                         with_degr: bool = False):
    """The per-chunk sampler behind multi-distribution runs (shared with
    the sharded executor, like ``_sweep_sampler``). Every distribution
    sees the same key, hence the same arrival process and copy sets
    (CRN across dists): arrivals are sampled once and tiled."""
    return ChunkSampler(SamplerSpec("stacked", tuple(dist_list), cfg,
                                    k_max, n_seeds, with_shared,
                                    with_degr), key, chunk_size)


def _dist_table_sampler(key: Array, dist_list, cfg: SimConfig,
                        k_max: int, n_seeds: int,
                        chunk_size: int | None,
                        with_shared: bool = False,
                        with_degr: bool = False):
    """The per-chunk sampler behind HETEROGENEOUS grids (per-cell
    ``dist_id``). Unlike ``_sweep_dists_sampler`` it does NOT tile the
    arrivals: gaps/servers stay (n_seeds, T) and only ``services``
    stacks one (n_seeds, T, n_svc) table per dist-union member along
    axis 0 — cells reach their system's table through ``svc_idx =
    dist_id * n_seeds + seed_idx`` while sharing one arrival process and
    copy sets (CRN across systems; dist-0 rows are bit-identical to a
    pure single-dist run of the same key)."""
    return ChunkSampler(SamplerSpec("tables", tuple(dist_list), cfg,
                                    k_max, n_seeds, with_shared,
                                    with_degr), key, chunk_size)


def run(key: Array, scenario: scenario_mod.ScenarioLike, rhos: Array,
        cfg: SimConfig, *, n_seeds: int = 2,
        percentiles: tuple[float, ...] = DEFAULT_PERCENTILES,
        n_bins: int = DEFAULT_BINS,
        chunk_size: int | None = None,
        mesh: jax.sharding.Mesh | None = None,
        kernel: str = "auto",
        pipeline: str = "auto") -> dict[str, Array]:
    """Execute a ``Scenario`` (or a sequence — a MIXED grid) over a load
    grid. THE public entry point of the sweep engine; ``sweep`` /
    ``sweep_dists`` / ``replication_gain`` are thin shims over it.

    Returns post-warmup summaries, each of shape
    ``(n_seeds, len(rhos), n_variants)`` — for a single scenario the
    variant axis is its ``ks`` in order; a sequence concatenates each
    scenario's variants. Scenarios with multiple ``dists`` add a leading
    dist axis (``sweep_dists`` layout). A HETEROGENEOUS sequence —
    scenarios with DIFFERENT single dists — instead keeps the
    ``(n_seeds, B, n_variants)`` layout: each variant carries its
    ``dist_id`` into the deduped dist union (see ``scenario.combine``),
    the engine samples one service table per union member, and every
    cell's service gather routes to its system's table inside the same
    compiled mixed grid ("which system" is just one more variant
    coordinate):

      ``mean``          streaming mean response
      ``p<q>``          histogram-sketch percentile per entry of
                        ``percentiles`` (pass ``()`` to skip the sketch
                        entirely — e.g. threshold estimation needs means
                        only)
      ``count``         post-warmup arrivals per cell (scalar)
      ``completed``     per-cell count of post-warmup requests that
                        COMPLETED — equals ``count`` except in degraded
                        cells where every copy of a request was
                        blackholed (those requests are excluded from
                        ``mean`` and the percentiles)

    ``chunk_size=None`` pre-samples the whole stream; an int streams
    arrivals in chunks of that many steps so peak memory is independent
    of ``cfg.n_arrivals``. ``mesh`` routes execution through the sharded
    cell-plan executor (``repro.distributed.sweep_shard``) —
    bit-identical for any device count. ``mesh=None`` does NOT force the
    single-device engine: it resolves through
    ``repro.launch.mesh.resolve_mesh`` (innermost ``use_sweep_mesh``
    context, else the multi-process default that
    ``distributed.multihost.initialize`` installs, else truly no mesh) —
    the ONE mesh-resolution point every entry point built on ``run``
    (``threshold.*``, benchmarks, shims) rides. ``kernel`` picks the
    chunk-body implementation (``"auto"`` / ``"on"`` / ``"off"`` /
    ``"interpret"``, see the module design note and
    ``repro.kernels.cell_update.ops.resolve_kernel_mode``) — every mode
    is bit-identical, on or off a mesh. ``pipeline`` controls the
    sampling/compute overlap (``repro.core.chunkflow``): ``"on"``
    prefetches each next chunk's inputs on a producer thread through the
    fused jitted sampler, ``"off"`` samples serially per chunk,
    ``"auto"`` turns it on exactly when there is something to overlap
    (a chunked stream with more than one chunk). All three are
    bit-identical — the pipeline moves WHEN sampling happens, never
    what is sampled.

    Key-splitting / CRN contract: unchanged from the legacy ``sweep``
    (see the module design note) — ``Scenario.paper_default`` consumes
    the key identically to the pre-scenario engine, and every policy /
    service model consumes the SAME draws, so with ``chunk_size=None``,
    seed s, variant j sees bit-identical inputs to
    ``simulate_grid(split(key, n_seeds)[s], dist, rhos, cfg, ks[j])``.
    With ``chunk_size=T``, chunk c draws from ``fold_in(key, c)`` at
    ``n_arrivals=T`` through the same per-seed samplers.

    ``warmup_frac`` and ``client_overhead`` come from the Scenario, NOT
    from ``cfg`` (the legacy shims copy them over).
    """
    dist_list, warmup_frac, variants = scenario_mod.combine(scenario)
    if pipeline not in ("auto", "on", "off"):
        raise ValueError(f"pipeline must be 'auto', 'on' or 'off', "
                         f"got {pipeline!r}")
    if pipeline == "auto":
        pipeline = ("on" if chunk_size is not None
                    and cfg.n_arrivals > int(chunk_size) else "off")
    mesh = launch_mesh.resolve_mesh(mesh)
    rhos = jnp.asarray(rhos)
    k_max = max(v.k for v in variants)
    with_shared = scenario_mod.any_server_dependent(variants)
    with_degr = scenario_mod.any_degraded(variants)
    has_dists = scenario_mod.any_dist_ids(variants)
    d = len(dist_list)
    if d == 1:
        sampler = _sweep_sampler(key, dist_list[0], cfg, k_max, n_seeds,
                                 chunk_size, with_shared=with_shared,
                                 with_degr=with_degr)
    elif has_dists:
        # heterogeneous grid: the dist union stacks service TABLES only;
        # the plan's seed axis stays n_seeds and each cell routes to its
        # system's table via its dist_id (no per-dist output axis — the
        # variant axis already carries "which system")
        sampler = _dist_table_sampler(key, dist_list, cfg, k_max, n_seeds,
                                      chunk_size, with_shared=with_shared,
                                      with_degr=with_degr)
    else:
        sampler = _sweep_dists_sampler(key, dist_list, cfg, k_max, n_seeds,
                                       chunk_size, with_shared=with_shared,
                                       with_degr=with_degr)

    n_seeds_total = n_seeds if has_dists else d * n_seeds
    kwargs = dict(variants=variants, warmup_frac=warmup_frac,
                  percentiles=tuple(percentiles), n_bins=n_bins,
                  chunk_size=chunk_size,
                  use_kernel=cell_ops.resolve_kernel_mode(kernel),
                  pipeline=pipeline)
    if mesh is not None:
        from repro.distributed.sweep_shard import _sweep_cells_sharded
        out = _sweep_cells_sharded(sampler, n_seeds_total, rhos, cfg,
                                   mesh=mesh, **kwargs)
    else:
        out = _run_engine(sampler, n_seeds_total, rhos, cfg, **kwargs)
    if d > 1 and not has_dists:
        out = {k: (v.reshape((d, n_seeds) + v.shape[1:])
                   if isinstance(v, jax.Array) else v)
               for k, v in out.items()}
    return out


def _warn_deprecated_shim(name: str) -> None:
    warnings.warn(
        f"queueing.{name} is a deprecated paper-default shim; use "
        f"queueing.run with a Scenario (bit-identical output)",
        DeprecationWarning, stacklevel=3)


def sweep(key: Array, dist: ServiceDist, rhos: Array, cfg: SimConfig, *,
          ks: tuple[int, ...] = (1, 2), n_seeds: int = 2,
          percentiles: tuple[float, ...] = DEFAULT_PERCENTILES,
          n_bins: int = DEFAULT_BINS,
          chunk_size: int | None = None,
          kernel: str = "auto") -> dict[str, Array]:
    """Fused multi-(k, seed, load) sweep of the PAPER's model.

    .. deprecated:: Thin shim over ``run(key, Scenario.paper_default(
       dist, ks=ks, ...), rhos, cfg, ...)`` — bit-identical output
       (emits ``DeprecationWarning``); prefer ``run`` (it also
       expresses cancellation / dispatch-to-idle policies,
       server-dependent service and mixed grids).

    Summary shapes, chunking and the CRN contract are exactly ``run``'s
    (single-dist layout): ``(n_seeds, len(rhos), len(ks))``.
    """
    _warn_deprecated_shim("sweep")
    scn = Scenario.paper_default(dist, ks=tuple(int(k) for k in ks),
                                 client_overhead=cfg.client_overhead,
                                 warmup_frac=cfg.warmup_frac)
    return run(key, scn, rhos, cfg, n_seeds=n_seeds,
               percentiles=percentiles, n_bins=n_bins,
               chunk_size=chunk_size, kernel=kernel)


def sweep_dists(key: Array, dist_list, rhos: Array, cfg: SimConfig, *,
                ks: tuple[int, ...] = (1, 2), n_seeds: int = 2,
                percentiles: tuple[float, ...] = DEFAULT_PERCENTILES,
                n_bins: int = DEFAULT_BINS,
                chunk_size: int | None = None,
                kernel: str = "auto") -> dict[str, Array]:
    """Sweep MANY service-time distributions in one engine call by stacking
    them along the seed axis; summaries gain a leading dist axis
    ``(len(dist_list), n_seeds, len(rhos), len(ks))``.

    .. deprecated:: Thin shim over ``run`` with a multi-``dists``
       ``Scenario.paper_default`` — bit-identical output (emits
       ``DeprecationWarning``); prefer ``run``.
    """
    _warn_deprecated_shim("sweep_dists")
    dist_list = tuple(dist_list)
    scn = Scenario.paper_default(dist_list, ks=tuple(int(k) for k in ks),
                                 client_overhead=cfg.client_overhead,
                                 warmup_frac=cfg.warmup_frac)
    out = run(key, scn, rhos, cfg, n_seeds=n_seeds,
              percentiles=percentiles, n_bins=n_bins,
              chunk_size=chunk_size, kernel=kernel)
    if len(dist_list) == 1:  # run() adds the dist axis only for d > 1
        out = {k: (v[None] if isinstance(v, jax.Array) else v)
               for k, v in out.items()}
    return out


def mean_response(key: Array, dist: ServiceDist, rhos: Array, cfg: SimConfig,
                  k: int, n_seeds: int = 1,
                  chunk_size: int | None = None,
                  kernel: str = "auto") -> Array:
    """Post-warmup mean response (B,) averaged over ``n_seeds`` seeds."""
    scn = Scenario.paper_default(dist, ks=(int(k),),
                                 client_overhead=cfg.client_overhead,
                                 warmup_frac=cfg.warmup_frac)
    out = run(key, scn, rhos, cfg, n_seeds=n_seeds,
              percentiles=(), chunk_size=chunk_size, kernel=kernel)
    return jnp.mean(out["mean"][:, :, 0], axis=0)


def replication_gain(key: Array, dist: ServiceDist, rhos: Array,
                     cfg: SimConfig, k: int = 2, n_seeds: int = 2,
                     chunk_size: int | None = None,
                     mesh: jax.sharding.Mesh | None = None,
                     kernel: str = "auto") -> Array:
    """mean_k1(rho) - mean_k(rho), CRN-paired per seed. Positive = k helps.

    .. deprecated:: Thin shim over ``run`` with a paper-default
       ``Scenario`` at ``ks=(1, k)`` (emits ``DeprecationWarning``);
       prefer ``run`` + a paired-gain reduction (or
       ``threshold.scenario_gain``).

    ``mesh`` routes the sweep through the sharded cell-plan executor
    (bit-identical to the local path; see the module CRN contract)."""
    _warn_deprecated_shim("replication_gain")
    scn = Scenario.paper_default(dist, ks=(1, int(k)),
                                 client_overhead=cfg.client_overhead,
                                 warmup_frac=cfg.warmup_frac)
    out = run(key, scn, rhos, cfg, n_seeds=n_seeds, percentiles=(),
              chunk_size=chunk_size, mesh=mesh, kernel=kernel)
    m = out["mean"]  # (S, B, 2)
    return jnp.mean(m[:, :, 0] - m[:, :, 1], axis=0)
