"""Discrete-event simulation of the paper's replication queueing model (§2.1).

Model (exactly as in the paper): ``N`` independent identical FIFO servers,
Poisson arrivals at rate ``N * rho`` (so each server sees utilization ``rho``
without replication), each arriving request is copied to ``k`` distinct
servers chosen uniformly at random, every copy is served to completion
(no cancellation — this is what doubles utilization), and the request's
response time is the minimum over its copies' (queueing delay + service
time). An optional fixed ``client_overhead`` is added to every request when
k > 1 (paper Figure 4).

Common random numbers (CRN): the arrival process, the first copy's server
choice, and the first copy's service time are identical for every ``k``
under the same seed, which makes paired k=2 vs k=1 comparisons (and hence
threshold estimation) low-variance.

Fused sweep engine — design note
--------------------------------

Every paper figure sweeps the same simulator over a (seed, load, k) grid,
and the pre-refactor code ran one sequential ``lax.scan`` per grid cell
from Python (``replication_gain`` alone ran ``2 * n_seeds`` full passes).
``sweep`` replaces those loops with ONE ``lax.scan`` over arrivals whose
carry stacks the per-server next-free times for the whole grid:

    free:  (S, B, K, N)   S seeds x B loads x K replication factors
                          x N servers

The scan step ``vmap``s a single-cell update (gather k server-free times,
max with the arrival time, add service, scatter back, min-reduce) over the
three grid axes. Randomness is sampled ONCE per seed at ``k_max = max(ks)``
and every k-slice consumes a prefix of the same copy set / service draws,
so the CRN coupling of the sequential path is preserved exactly: the k=1
slice sees bit-identical inputs to the old ``simulate_grid(key, ..., k=1)``.

The engine never materializes an ``(S, B, K, M)`` response array. Instead
it folds each response into streaming statistics inside the scan:

  * a Kahan-compensated post-warmup sum (=> exact-to-float32 means), and
  * a log-spaced histogram sketch of ``n_bins`` buckets spanning
    [HIST_LO, HIST_HI], from which percentiles are read as geometric bin
    midpoints (relative error <= half a bin width, ~0.5% at the default
    2048 bins over 8 decades).

Memory is therefore O(S*B*K*(N + n_bins)) independent of the number of
arrivals M, while the sequential path needed O(B*M) per call.

Crucially the jitted engine core is distribution-agnostic: service times
are sampled in a small per-distribution jit and passed in as arrays, so
sweeping 15 service-time families (Figure 2) compiles the expensive scan
exactly once instead of 2 * n_seeds times per family.

``simulate`` / ``simulate_grid`` remain for callers that need raw
per-arrival response times (tests, exact percentiles); they are thin
wrappers over the same single-cell step function.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.distributions import ServiceDist

Array = jax.Array

# Log-spaced histogram sketch bounds (unit-mean service times => responses
# live well inside [1e-3, 1e5]; values outside clamp to the edge bins).
HIST_LO = 1e-3
HIST_HI = 1e5
DEFAULT_BINS = 2048
DEFAULT_PERCENTILES = (50.0, 90.0, 99.0, 99.9)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_servers: int = 20
    n_arrivals: int = 100_000
    warmup_frac: float = 0.1
    client_overhead: float = 0.0  # latency penalty added to replicated requests


def _arrival_part(key: Array, n: int, m: int, k_max: int):
    """Distribution-independent randomness: unit-rate exponential gaps
    (scaled by the actual rate at sim time so the same key yields a coupled
    arrival process across loads) and the per-request copy sets."""
    k_gap, k_srv0, k_srvx, _ = jax.random.split(key, 4)
    unit_gaps = jax.random.exponential(k_gap, (m,))
    first = jax.random.randint(k_srv0, (m,), 0, n)
    if k_max > 1:
        # distinct extra copies: choose k-1 distinct offsets in [1, n).
        # The same score tensor is used for every k, so copy sets are nested
        # (k=2's extra server is also one of k=3's) — CRN across k.
        scores = jax.random.uniform(k_srvx, (m, n - 1))
        _, offs = jax.lax.top_k(scores, k_max - 1)  # (m, k_max-1) in [0, n-1)
        extra = (first[:, None] + 1 + offs) % n
        servers = jnp.concatenate([first[:, None], extra], axis=1)
    else:
        servers = first[:, None]
    return unit_gaps, servers


def _service_part(key: Array, dist: ServiceDist, cfg: SimConfig, k_max: int):
    """Per-copy fold_in keys so copy j's service times are identical for
    every k_max (CRN: k=1 and k=2 share the first copy's service draw)."""
    m = cfg.n_arrivals
    _, _, _, k_svc = jax.random.split(key, 4)
    return jnp.stack(
        [dist.sample(jax.random.fold_in(k_svc, j), (m,)) for j in range(k_max)],
        axis=1)


def _sample_inputs(key: Array, dist: ServiceDist, cfg: SimConfig, k_max: int):
    """Draw all randomness up front. Column 0 of servers/services is shared
    by every k (CRN)."""
    unit_gaps, servers = _arrival_part(key, cfg.n_servers, cfg.n_arrivals,
                                       k_max)
    services = _service_part(key, dist, cfg, k_max)
    return unit_gaps, servers, services


def _step_cell(free: Array, t: Array, srv: Array, svc: Array, mask: Array,
               overhead: Array) -> tuple[Array, Array]:
    """One arrival at one (seed, load, k) grid cell. free (N,), t scalar,
    srv/svc/mask (k_max,) -> (new free, response)."""
    start = jnp.maximum(free[srv], t)
    finish = start + svc
    # srv entries are distinct; masked copies rewrite their old value (no-op)
    free = free.at[srv].set(jnp.where(mask, finish, free[srv]))
    resp = jnp.min(jnp.where(mask, finish, jnp.inf)) - t + overhead
    return free, resp


def _scan_sim(arrivals: Array, servers: Array, services: Array, n_servers: int,
              overhead: float) -> Array:
    """Run the FIFO replication DES. arrivals (M,), servers (M,k), services
    (M,k) -> response times (M,)."""
    k = servers.shape[1]
    ovh = jnp.asarray(overhead if k > 1 else 0.0, jnp.float32)
    mask = jnp.ones((k,), bool)

    def step(free: Array, inp):
        t, srv, svc = inp
        return _step_cell(free, t, srv, svc, mask, ovh)

    free0 = jnp.zeros((n_servers,))
    _, resp = jax.lax.scan(step, free0, (arrivals, servers, services))
    return resp


@partial(jax.jit, static_argnames=("dist", "cfg", "k"))
def simulate(key: Array, dist: ServiceDist, rho: Array, cfg: SimConfig,
             k: int = 1) -> Array:
    """Response times (M,) for a single load ``rho`` and replication ``k``."""
    unit_gaps, servers, services = _sample_inputs(key, dist, cfg, k)
    rate = cfg.n_servers * rho
    arrivals = jnp.cumsum(unit_gaps / rate)
    return _scan_sim(arrivals, servers[:, :k], services[:, :k],
                     cfg.n_servers, cfg.client_overhead)


@partial(jax.jit, static_argnames=("dist", "cfg", "k"))
def simulate_grid(key: Array, dist: ServiceDist, rhos: Array, cfg: SimConfig,
                  k: int = 1) -> Array:
    """Response times (B, M) for a grid of loads, one coupled sample path."""
    unit_gaps, servers, services = _sample_inputs(key, dist, cfg, k)
    rates = cfg.n_servers * rhos  # (B,)
    arrivals = jnp.cumsum(unit_gaps)[None, :] / rates[:, None]  # (B, M)
    sim = jax.vmap(
        lambda a: _scan_sim(a, servers[:, :k], services[:, :k],
                            cfg.n_servers, cfg.client_overhead))
    return sim(arrivals)


def _warm(resp: Array, cfg: SimConfig) -> Array:
    start = int(cfg.n_arrivals * cfg.warmup_frac)
    return resp[..., start:]


def summarize(resp: Array, cfg: SimConfig,
              percentiles=DEFAULT_PERCENTILES) -> dict[str, Array]:
    """Post-warmup mean + percentiles along the last axis."""
    r = _warm(resp, cfg)
    out = {"mean": jnp.mean(r, axis=-1)}
    for p in percentiles:
        out[f"p{p:g}"] = jnp.percentile(r, p, axis=-1)
    return out


# ---------------------------------------------------------------------------
# Fused sweep engine
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_servers", "n_arrivals", "k_max",
                                   "n_seeds"))
def _sample_sweep_arrivals(key: Array, n_servers: int, n_arrivals: int,
                           k_max: int, n_seeds: int):
    """(S,M) unit gaps + (S,M,k_max) copy sets. Distribution-independent and
    keyed only on the shape-bearing config fields (NOT the whole SimConfig),
    so its (one, comparatively expensive) compile is shared by every family
    — and every client_overhead / warmup variant — a benchmark sweeps."""
    keys = jax.random.split(key, n_seeds)
    return jax.vmap(
        lambda kk: _arrival_part(kk, n_servers, n_arrivals, k_max))(keys)


def _sample_sweep_services(key: Array, dist: ServiceDist, cfg: SimConfig,
                           k_max: int, n_seeds: int):
    """(S,M,k_max) service draws. Deliberately NOT jitted: eager sampling
    reuses jax's per-op caches across distributions, so sweeping 15 families
    costs 15 x ~20ms instead of 15 x ~1s of per-family jit compiles (the
    PRNG bits are identical either way)."""
    keys = jax.random.split(key, n_seeds)
    return jnp.stack([_service_part(keys[s], dist, cfg, k_max)
                      for s in range(n_seeds)], axis=0)


def _sample_sweep_inputs(key: Array, dist: ServiceDist, cfg: SimConfig,
                         k_max: int, n_seeds: int):
    """Per-seed randomness for the engine: (S,M) gaps, (S,M,k_max) servers /
    services. Bit-identical to ``n_seeds`` sequential ``_sample_inputs``
    calls on ``jax.random.split(key, n_seeds)``."""
    unit_gaps, servers = _sample_sweep_arrivals(
        key, cfg.n_servers, cfg.n_arrivals, k_max, n_seeds)
    services = _sample_sweep_services(key, dist, cfg, k_max, n_seeds)
    return unit_gaps, servers, services


@partial(jax.jit, static_argnames=("n_servers", "n_bins"))
def _sweep_engine(unit_gaps: Array, servers: Array, services: Array,
                  rates: Array, k_mask: Array, ovh_vec: Array,
                  warmup_start: Array, qs: Array, *, n_servers: int,
                  n_bins: int):
    """Distribution-agnostic fused core. One scan over M arrivals with the
    stacked (S,B,K,N) server-free carry; streaming post-warmup mean (Kahan)
    and log-histogram quantile sketch. Returns (mean (S,B,K),
    quantiles (Q,S,B,K))."""
    S, M = unit_gaps.shape
    B = rates.shape[0]
    K = k_mask.shape[0]
    need_hist = qs.shape[0] > 0

    cum = jnp.cumsum(unit_gaps, axis=1)  # (S, M) unit-rate arrival times

    # vmap the single-cell step over k, then loads, then seeds.
    cell_k = jax.vmap(_step_cell, in_axes=(0, None, None, None, 0, 0))
    cell_bk = jax.vmap(cell_k, in_axes=(0, 0, None, None, None, None))
    cell_sbk = jax.vmap(cell_bk, in_axes=(0, 0, 0, 0, None, None))

    log_lo = jnp.log(jnp.float32(HIST_LO))
    scale = (n_bins - 1) / (jnp.log(jnp.float32(HIST_HI)) - log_lo)
    cells = S * B * K
    cell_base = jnp.arange(cells, dtype=jnp.int32) * n_bins

    def step(carry, inp):
        free, ssum, comp, hist = carry
        i, c, srv, svc = inp
        t = c[:, None] / rates[None, :]                       # (S, B)
        free, resp = cell_sbk(free, t, srv, svc, k_mask, ovh_vec)
        warm = (i >= warmup_start).astype(resp.dtype)
        # Kahan-compensated sum: sequential f32 accumulation over ~1e5
        # terms would otherwise cost ~1e-4 relative error on the mean,
        # which is the signal threshold bisection keys on.
        y = resp * warm - comp
        tot = ssum + y
        comp = (tot - ssum) - y
        ssum = tot
        if need_hist:
            idx = ((jnp.log(resp) - log_lo) * scale).astype(jnp.int32)
            idx = jnp.clip(idx, 0, n_bins - 1)
            flat = cell_base + idx.reshape(-1)
            hist = hist.at[flat].add(warm)
        return (free, ssum, comp, hist), None

    zeros = jnp.zeros((S, B, K))
    hist0 = jnp.zeros((cells * n_bins,) if need_hist else (0,))
    carry0 = (jnp.zeros((S, B, K, n_servers)), zeros, zeros, hist0)
    xs = (jnp.arange(M), cum.T, jnp.moveaxis(servers, 1, 0),
          jnp.moveaxis(services, 1, 0))
    (free, ssum, comp, hist), _ = jax.lax.scan(step, carry0, xs)

    count = (M - warmup_start).astype(ssum.dtype)
    mean = ssum / count
    if not need_hist:
        return mean, jnp.zeros((0, S, B, K))
    hist = hist.reshape(S, B, K, n_bins)
    cdf = jnp.cumsum(hist, axis=-1)                           # (S,B,K,n_bins)
    targets = qs[:, None, None, None] / 100.0 * count         # (Q,1,1,1)
    # first bin where the cdf reaches the target mass
    bin_idx = jnp.argmax(cdf[None] >= targets[..., None], axis=-1)
    # geometric midpoint of the selected bin
    quant = jnp.exp(log_lo + (bin_idx + 0.5) / scale)
    return mean, quant


def sweep(key: Array, dist: ServiceDist, rhos: Array, cfg: SimConfig, *,
          ks: tuple[int, ...] = (1, 2), n_seeds: int = 2,
          percentiles: tuple[float, ...] = DEFAULT_PERCENTILES,
          n_bins: int = DEFAULT_BINS) -> dict[str, Array]:
    """Fused multi-(k, seed, load) sweep. Returns post-warmup summaries,
    each of shape ``(n_seeds, len(rhos), len(ks))``:

      ``mean``          streaming mean response
      ``p<q>``          histogram-sketch percentile per entry of
                        ``percentiles`` (pass ``()`` to skip the sketch
                        entirely — e.g. threshold estimation needs means
                        only)
      ``count``         post-warmup arrivals per cell (scalar)

    CRN layout: seed s, k-slice j of this sweep sees bit-identical inputs
    to ``simulate_grid(split(key, n_seeds)[s], dist, rhos, cfg, ks[j])``.
    """
    ks = tuple(int(k) for k in ks)
    k_max = max(ks)
    rhos = jnp.asarray(rhos)
    unit_gaps, servers, services = _sample_sweep_inputs(
        key, dist, cfg, k_max, n_seeds)
    return _sweep_summaries(unit_gaps, servers, services, rhos, cfg,
                            ks=ks, percentiles=tuple(percentiles),
                            n_bins=n_bins)


def _sweep_summaries(unit_gaps: Array, servers: Array, services: Array,
                     rhos: Array, cfg: SimConfig, *, ks: tuple[int, ...],
                     percentiles: tuple[float, ...],
                     n_bins: int) -> dict[str, Array]:
    """Run the engine on pre-sampled inputs (see ``sweep`` / ``sweep_dists``)."""
    k_max = max(ks)
    k_mask = jnp.asarray([[j < k for j in range(k_max)] for k in ks])
    ovh_vec = jnp.asarray(
        [cfg.client_overhead if k > 1 else 0.0 for k in ks], jnp.float32)
    warmup_start = jnp.asarray(int(cfg.n_arrivals * cfg.warmup_frac))
    qs = jnp.asarray(percentiles, jnp.float32)
    mean, quant = _sweep_engine(
        unit_gaps, servers, services, cfg.n_servers * rhos, k_mask, ovh_vec,
        warmup_start, qs, n_servers=cfg.n_servers, n_bins=n_bins)
    out = {"mean": mean,
           "count": cfg.n_arrivals - int(cfg.n_arrivals * cfg.warmup_frac)}
    for qi, p in enumerate(percentiles):
        out[f"p{p:g}"] = quant[qi]
    return out


def sweep_dists(key: Array, dist_list, rhos: Array, cfg: SimConfig, *,
                ks: tuple[int, ...] = (1, 2), n_seeds: int = 2,
                percentiles: tuple[float, ...] = DEFAULT_PERCENTILES,
                n_bins: int = DEFAULT_BINS) -> dict[str, Array]:
    """Sweep MANY service-time distributions in one engine call by stacking
    them along the seed axis. Summaries come back with a leading dist axis:
    ``(len(dist_list), n_seeds, len(rhos), len(ks))``. Every distribution
    sees the same per-seed keys (paired comparisons across dists)."""
    ks = tuple(int(k) for k in ks)
    k_max = max(ks)
    rhos = jnp.asarray(rhos)
    # every distribution sees the same key, hence the same arrival process
    # and copy sets (CRN across dists): sample them once and tile.
    gaps1, servers1 = _sample_sweep_arrivals(
        key, cfg.n_servers, cfg.n_arrivals, k_max, n_seeds)
    d = len(dist_list)
    unit_gaps = jnp.tile(gaps1, (d, 1))
    servers = jnp.tile(servers1, (d, 1, 1))
    services = jnp.concatenate(
        [_sample_sweep_services(key, dd, cfg, k_max, n_seeds)
         for dd in dist_list], axis=0)
    out = _sweep_summaries(unit_gaps, servers, services, rhos, cfg, ks=ks,
                           percentiles=tuple(percentiles), n_bins=n_bins)
    return {k: (v.reshape((d, n_seeds) + v.shape[1:])
                if isinstance(v, jax.Array) else v)
            for k, v in out.items()}


def mean_response(key: Array, dist: ServiceDist, rhos: Array, cfg: SimConfig,
                  k: int, n_seeds: int = 1) -> Array:
    """Post-warmup mean response (B,) averaged over ``n_seeds`` seeds."""
    out = sweep(key, dist, rhos, cfg, ks=(k,), n_seeds=n_seeds,
                percentiles=())
    return jnp.mean(out["mean"][:, :, 0], axis=0)


def replication_gain(key: Array, dist: ServiceDist, rhos: Array,
                     cfg: SimConfig, k: int = 2, n_seeds: int = 2) -> Array:
    """mean_k1(rho) - mean_k(rho), CRN-paired per seed. Positive = k helps."""
    out = sweep(key, dist, rhos, cfg, ks=(1, k), n_seeds=n_seeds,
                percentiles=())
    m = out["mean"]  # (S, B, 2)
    return jnp.mean(m[:, :, 0] - m[:, :, 1], axis=0)
