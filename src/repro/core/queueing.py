"""Discrete-event simulation of the paper's replication queueing model (§2.1).

Model (exactly as in the paper): ``N`` independent identical FIFO servers,
Poisson arrivals at rate ``N * rho`` (so each server sees utilization ``rho``
without replication), each arriving request is copied to ``k`` distinct
servers chosen uniformly at random, every copy is served to completion
(no cancellation — this is what doubles utilization), and the request's
response time is the minimum over its copies' (queueing delay + service
time). An optional fixed ``client_overhead`` is added to every request when
k > 1 (paper Figure 4).

The simulator is a single ``lax.scan`` over arrivals with the vector of
per-server next-free times as carry, ``vmap``-able over a batch of loads /
seeds. Common random numbers (CRN): the arrival process, the first copy's
server choice, and the first copy's service time are identical for every
``k`` under the same seed, which makes paired k=2 vs k=1 comparisons (and
hence threshold estimation) low-variance.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.distributions import ServiceDist

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_servers: int = 20
    n_arrivals: int = 100_000
    warmup_frac: float = 0.1
    client_overhead: float = 0.0  # latency penalty added to replicated requests


def _sample_inputs(key: Array, dist: ServiceDist, cfg: SimConfig, k_max: int):
    """Draw all randomness up front. Column 0 of servers/services is shared
    by every k (CRN)."""
    n, m = cfg.n_servers, cfg.n_arrivals
    k_gap, k_srv0, k_srvx, k_svc = jax.random.split(key, 4)
    # Unit-rate exponential gaps; scaled by the actual rate at sim time so the
    # same key yields a coupled arrival process across loads.
    unit_gaps = jax.random.exponential(k_gap, (m,))
    first = jax.random.randint(k_srv0, (m,), 0, n)
    if k_max > 1:
        # distinct extra copies: choose k-1 distinct offsets in [1, n).
        # The same score tensor is used for every k, so copy sets are nested
        # (k=2's extra server is also one of k=3's) — CRN across k.
        scores = jax.random.uniform(k_srvx, (m, n - 1))
        _, offs = jax.lax.top_k(scores, k_max - 1)  # (m, k_max-1) in [0, n-1)
        extra = (first[:, None] + 1 + offs) % n
        servers = jnp.concatenate([first[:, None], extra], axis=1)
    else:
        servers = first[:, None]
    # Per-copy fold_in keys so copy j's service times are identical for every
    # k_max (CRN: k=1 and k=2 share the first copy's service draw).
    services = jnp.stack(
        [dist.sample(jax.random.fold_in(k_svc, j), (m,)) for j in range(k_max)],
        axis=1)
    return unit_gaps, servers, services


def _scan_sim(arrivals: Array, servers: Array, services: Array, n_servers: int,
              overhead: float) -> Array:
    """Run the FIFO replication DES. arrivals (M,), servers (M,k), services
    (M,k) -> response times (M,)."""

    def step(free: Array, inp):
        t, srv, svc = inp
        start = jnp.maximum(free[srv], t)
        finish = start + svc
        free = free.at[srv].set(finish)  # srv entries are distinct
        return free, jnp.min(finish) - t

    free0 = jnp.zeros((n_servers,))
    _, resp = jax.lax.scan(step, free0, (arrivals, servers, services))
    k = servers.shape[1]
    if k > 1 and overhead != 0.0:
        resp = resp + overhead
    return resp


@partial(jax.jit, static_argnames=("dist", "cfg", "k"))
def simulate(key: Array, dist: ServiceDist, rho: Array, cfg: SimConfig,
             k: int = 1) -> Array:
    """Response times (M,) for a single load ``rho`` and replication ``k``."""
    unit_gaps, servers, services = _sample_inputs(key, dist, cfg, k)
    rate = cfg.n_servers * rho
    arrivals = jnp.cumsum(unit_gaps / rate)
    return _scan_sim(arrivals, servers[:, :k], services[:, :k],
                     cfg.n_servers, cfg.client_overhead)


@partial(jax.jit, static_argnames=("dist", "cfg", "k"))
def simulate_grid(key: Array, dist: ServiceDist, rhos: Array, cfg: SimConfig,
                  k: int = 1) -> Array:
    """Response times (B, M) for a grid of loads, one coupled sample path."""
    unit_gaps, servers, services = _sample_inputs(key, dist, cfg, k)
    rates = cfg.n_servers * rhos  # (B,)
    arrivals = jnp.cumsum(unit_gaps)[None, :] / rates[:, None]  # (B, M)
    sim = jax.vmap(
        lambda a: _scan_sim(a, servers[:, :k], services[:, :k],
                            cfg.n_servers, cfg.client_overhead))
    return sim(arrivals)


def _warm(resp: Array, cfg: SimConfig) -> Array:
    start = int(cfg.n_arrivals * cfg.warmup_frac)
    return resp[..., start:]


def summarize(resp: Array, cfg: SimConfig,
              percentiles=(50.0, 90.0, 99.0, 99.9)) -> dict[str, Array]:
    """Post-warmup mean + percentiles along the last axis."""
    r = _warm(resp, cfg)
    out = {"mean": jnp.mean(r, axis=-1)}
    for p in percentiles:
        out[f"p{p:g}"] = jnp.percentile(r, p, axis=-1)
    return out


def mean_response(key: Array, dist: ServiceDist, rhos: Array, cfg: SimConfig,
                  k: int, n_seeds: int = 1) -> Array:
    """Post-warmup mean response (B,) averaged over ``n_seeds`` seeds."""
    keys = jax.random.split(key, n_seeds)
    means = []
    for s in range(n_seeds):
        resp = simulate_grid(keys[s], dist, rhos, cfg, k)
        means.append(jnp.mean(_warm(resp, cfg), axis=-1))
    return jnp.mean(jnp.stack(means), axis=0)


def replication_gain(key: Array, dist: ServiceDist, rhos: Array,
                     cfg: SimConfig, k: int = 2, n_seeds: int = 2) -> Array:
    """mean_k1(rho) - mean_k(rho), CRN-paired per seed. Positive = k helps."""
    keys = jax.random.split(key, n_seeds)
    gains = []
    for s in range(n_seeds):
        r1 = simulate_grid(keys[s], dist, rhos, cfg, 1)
        rk = simulate_grid(keys[s], dist, rhos, cfg, k)
        gains.append(jnp.mean(_warm(r1, cfg), -1) - jnp.mean(_warm(rk, cfg), -1))
    return jnp.mean(jnp.stack(gains), axis=0)
