"""Declarative scenario spec for the sweep engine.

The paper's queueing model (§2.1) is ONE point in a larger policy space:
every copy is served to completion (no cancellation), copies always go
out (replicate-all), and copies' service times are i.i.d. draws. The
most-cited follow-ups sweep the rest of that space — Shah et al. ("When
Do Redundant Requests Reduce Latency?") show the answer flips once
service times carry a server-independent *request* component, and
Joshi et al. study replicate-vs-queue tradeoffs with cancellation. A
``Scenario`` names a point (or, as a sequence, a *grid*) in that space
declaratively, and ``repro.core.queueing.run`` executes it on the
fused/chunked/sharded cell-plan engine.

Replication policies (``Policy``):

  * ``REPLICATE_ALL`` — the paper's model: every copy is dispatched and
    served to completion; the loser copies keep occupying their servers
    after the winner finishes (this is what doubles utilization).
  * ``CANCEL_ON_COMPLETE`` — the Joshi et al. regime: when the winning
    copy finishes at ``t_win``, every loser vacates its queue slot — a
    loser already in service frees its server at ``t_win``, a loser
    still queued (its server busy past ``t_win``) is dequeued and
    consumes no server time at all.
  * ``REPLICATE_TO_IDLE`` — opportunistic replication: the primary copy
    always dispatches; extra copies dispatch only to servers that are
    idle at the arrival instant, and dispatched copies run to
    completion.
  * ``TIMEOUT_RETRY`` — the NON-redundant robustness baseline: one copy
    at a time, resent after a deadline ``delay`` with exponential
    backoff (attempt ``j`` dispatches ``delay * sum_{i<j} min(2^i,
    BACKOFF_CAP)`` after the arrival, cap 8x — see
    ``repro.kernels.cell_update.ref``). ``ks`` bounds the number of
    ATTEMPTS; the final attempt is exempt from blackhole loss (it
    models the out-of-band escalation every real retry layer has), so
    retried requests always complete.
  * ``HEDGE_AFTER_DELAY`` — Joshi-style deferred hedging: the primary
    dispatches at the arrival; duplicate ``j`` dispatches at
    ``t + j * delay`` ONLY if nothing has completed by then.
    ``delay=0`` degenerates BIT-IDENTICALLY to ``REPLICATE_ALL`` (all
    copies fire at ``t``; the engine special-cases ``delay <= 0`` so
    the dispatch gate cannot flip on a zero-service draw).

Degradation model (``Degradation``) — the paper's "exceptional
conditions" as first-class sweep coordinates:

  * with probability ``p_slow`` a copy is served by a STRAGGLER: its
    service time is inflated ``x slow_factor``;
  * with probability ``p_fail`` a copy BLACKHOLES: it is lost in
    transit — it never occupies its server and never responds. A
    request whose every dispatched copy blackholes never completes;
    the engine reports such cells' summaries over COMPLETED requests
    plus a per-cell ``completed`` count (``TIMEOUT_RETRY``'s final
    attempt is exempt, so retry cells always complete).

  CRN contract: both events are driven by ONE uniform draw per
  (arrival, copy) sampled from a DEDICATED ``fold_in`` index
  (``queueing._DEGRADE_FOLD``) — never from the service-time key
  stream — so healthy cells (``p_slow = p_fail = 0``) consume exactly
  the pre-degradation draws and keep their bits, and degraded cells
  stay CRN-paired with healthy ones copy-for-copy. The draw decides
  blackhole on ``u < p_fail`` and straggler on ``u >= 1 - p_slow``
  (disjoint since ``p_fail + p_slow <= 1``), so raising one
  probability never reshuffles the other's events.

Service models (``ServiceModel``):

  * ``IID`` — the paper's model: each copy's service time is an
    independent draw from the service distribution.
  * ``SERVER_DEPENDENT`` — Shah et al.'s decomposition: a request
    carries a shared component ``X_shared`` (one extra draw per
    arrival, identical for every copy) blended with the per-copy draw:
    ``svc_j = mix * X_shared + (1 - mix) * X_j``. ``mix=0`` is
    bit-identical to ``IID``; ``mix=1`` makes every copy's service time
    identical, so replication buys only queue diversity while still
    multiplying load — the regime where redundancy hurts.

A ``Scenario`` also carries the grid knobs that used to ride
``sweep(..., ks=)`` / ``SimConfig``: the replication factors ``ks``,
the per-request ``client_overhead`` charged when k > 1 (paper Fig 4),
and the ``warmup_frac`` of arrivals dropped from summaries. Machine
shape (``n_servers`` / ``n_arrivals``) stays in ``SimConfig`` — a
Scenario describes *what* is simulated, the config *how much*.

``Scenario`` is registered as a static pytree node (hashable, no array
leaves), so it can cross ``jit`` boundaries as a static argument and
key ``lru_cache``s. Per-cell execution lowers each scenario to
``Variant`` coordinates — one per entry of ``ks`` — which
``repro.core.cellplan`` stores as per-cell policy/model codes next to
(seed, load, k).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Sequence, Union

import jax

from repro.core.distributions import ServiceDist


class Policy(enum.IntEnum):
    """Replication-policy codes (per-cell coordinates in the cell plan;
    the fused cell-update kernel reads them as scalar-prefetch operands,
    so the values must stay small non-negative ints)."""

    REPLICATE_ALL = 0
    CANCEL_ON_COMPLETE = 1
    REPLICATE_TO_IDLE = 2
    TIMEOUT_RETRY = 3
    HEDGE_AFTER_DELAY = 4


class ServiceModel(enum.IntEnum):
    """Service-model codes (per-cell coordinates in the cell plan; like
    ``Policy`` codes they ride the fused cell-update kernel as
    scalar-prefetch operands)."""

    IID = 0
    SERVER_DEPENDENT = 1


REPLICATE_ALL = Policy.REPLICATE_ALL
CANCEL_ON_COMPLETE = Policy.CANCEL_ON_COMPLETE
REPLICATE_TO_IDLE = Policy.REPLICATE_TO_IDLE
TIMEOUT_RETRY = Policy.TIMEOUT_RETRY
HEDGE_AFTER_DELAY = Policy.HEDGE_AFTER_DELAY
IID = ServiceModel.IID
SERVER_DEPENDENT = ServiceModel.SERVER_DEPENDENT

# Policies whose dispatch schedule reads the per-variant ``delay`` knob.
TIMED_POLICIES = (Policy.TIMEOUT_RETRY, Policy.HEDGE_AFTER_DELAY)


@dataclasses.dataclass(frozen=True)
class Degradation:
    """Per-copy failure/straggler model (see the module design note).

    ``p_slow``/``p_fail`` are per-COPY probabilities; ``slow_factor``
    multiplies a straggler copy's service time. The healthy default
    (``HEALTHY``) is exactly the pre-degradation engine: both selects
    in ``step_cell`` are inert and no extra randomness is sampled, so
    healthy cells are bit-identical to pre-PR-7 captures.
    """

    p_slow: float = 0.0
    slow_factor: float = 1.0
    p_fail: float = 0.0

    def __post_init__(self):
        p_slow, p_fail = float(self.p_slow), float(self.p_fail)
        slow_factor = float(self.slow_factor)
        if not 0.0 <= p_slow <= 1.0 or not 0.0 <= p_fail <= 1.0:
            raise ValueError(
                f"p_slow/p_fail must be in [0, 1], got {p_slow}/{p_fail}")
        if p_slow + p_fail > 1.0:
            raise ValueError(
                "p_slow + p_fail must be <= 1 (the events share one "
                f"uniform draw), got {p_slow} + {p_fail}")
        if slow_factor < 1.0:
            raise ValueError(
                f"slow_factor must be >= 1, got {slow_factor}")
        if p_slow == 0.0:
            slow_factor = 1.0  # inert -> canonical (hash/provenance)
        object.__setattr__(self, "p_slow", p_slow)
        object.__setattr__(self, "p_fail", p_fail)
        object.__setattr__(self, "slow_factor", slow_factor)

    @property
    def healthy(self) -> bool:
        return self.p_slow == 0.0 and self.p_fail == 0.0


HEALTHY = Degradation()

_POLICY_NAMES = {p.name.lower(): p for p in Policy}
_MODEL_NAMES = {m.name.lower(): m for m in ServiceModel}


def parse_policy(name: Union[str, int, Policy]) -> Policy:
    """CLI-friendly lookup: 'cancel_on_complete' -> Policy (case-insensitive)."""
    if isinstance(name, str):
        return _POLICY_NAMES[name.lower()]
    return Policy(name)


def parse_service_model(name: Union[str, int, ServiceModel]) -> ServiceModel:
    """CLI-friendly lookup: 'server_dependent' -> ServiceModel."""
    if isinstance(name, str):
        return _MODEL_NAMES[name.lower()]
    return ServiceModel(name)


@dataclasses.dataclass(frozen=True)
class Variant:
    """One execution variant — a (k, policy, model, mix, overhead,
    degradation, delay) point.

    The engine's cell plan crosses variants with (seed, load): variant
    ``j`` of a scenario grid occupies the plan's k-axis slot ``j``.
    ``delay`` is the TIMED_POLICIES deadline/hedge delay; the
    degradation triple rides as three more per-cell float coordinates.
    """

    k: int
    policy: Policy = Policy.REPLICATE_ALL
    service_model: ServiceModel = ServiceModel.IID
    mix: float = 0.0
    overhead: float = 0.0  # client overhead; the engine charges it iff k > 1
    p_slow: float = 0.0
    slow_factor: float = 1.0
    p_fail: float = 0.0
    delay: float = 0.0
    dist_id: int = 0  # index into the grid's dist union ("which system")

    @property
    def needs_shared_draw(self) -> bool:
        return self.service_model == ServiceModel.SERVER_DEPENDENT

    @property
    def needs_degradation_draw(self) -> bool:
        return self.p_slow > 0.0 or self.p_fail > 0.0


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A declarative point in the replication policy space.

    ``dists`` is one ``ServiceDist`` or a tuple of them; multiple
    distributions stack along the engine's seed axis exactly as
    ``sweep_dists`` did (summaries gain a leading dist axis). A bare
    ``ServiceDist`` is normalized to a 1-tuple, and ``mix`` is
    normalized to 0.0 under ``IID`` (where it is inert) so that
    behaviorally identical scenarios compare, hash, and record
    provenance identically.
    """

    dists: tuple[ServiceDist, ...]
    policy: Policy = Policy.REPLICATE_ALL
    service_model: ServiceModel = ServiceModel.IID
    mix: float = 0.5
    ks: tuple[int, ...] = (1, 2)
    client_overhead: float = 0.0
    warmup_frac: float = 0.1
    degradation: Degradation = HEALTHY
    delay: float = 0.0  # TIMED_POLICIES deadline; normalized to 0 otherwise

    def __post_init__(self):
        d = self.dists
        if isinstance(d, ServiceDist):
            d = (d,)
        d = tuple(d)
        if not d or not all(isinstance(x, ServiceDist) for x in d):
            raise ValueError("Scenario.dists needs >= 1 ServiceDist")
        ks = tuple(int(k) for k in self.ks)
        if not ks or min(ks) < 1:
            raise ValueError(f"Scenario.ks must be >= 1, got {self.ks}")
        if not 0.0 <= float(self.mix) <= 1.0:
            raise ValueError(f"Scenario.mix must be in [0, 1], got {self.mix}")
        if not 0.0 <= float(self.warmup_frac) < 1.0:
            raise ValueError(
                f"Scenario.warmup_frac must be in [0, 1), got "
                f"{self.warmup_frac}")
        model = ServiceModel(self.service_model)
        policy = Policy(self.policy)
        degr = self.degradation
        if not isinstance(degr, Degradation):
            raise TypeError(
                f"Scenario.degradation must be a Degradation, got {degr!r}")
        delay = float(self.delay)
        if delay < 0.0:
            raise ValueError(f"Scenario.delay must be >= 0, got {delay}")
        object.__setattr__(self, "dists", d)
        object.__setattr__(self, "ks", ks)
        object.__setattr__(self, "policy", policy)
        object.__setattr__(self, "service_model", model)
        object.__setattr__(self, "mix",
                           float(self.mix) if model == SERVER_DEPENDENT
                           else 0.0)
        object.__setattr__(self, "client_overhead",
                           float(self.client_overhead))
        object.__setattr__(self, "warmup_frac", float(self.warmup_frac))
        # delay is inert outside TIMED_POLICIES -> canonical 0.0 so
        # behaviorally identical scenarios hash/compare identically.
        object.__setattr__(self, "delay",
                           delay if policy in TIMED_POLICIES else 0.0)

    @classmethod
    def paper_default(cls, dists: Union[ServiceDist,
                                        Sequence[ServiceDist], None] = None,
                      *, ks: tuple[int, ...] = (1, 2),
                      client_overhead: float = 0.0,
                      warmup_frac: float = 0.1) -> "Scenario":
        """The paper's §2.1 model: replicate-all, no cancellation, i.i.d.
        service. ``run(key, Scenario.paper_default(dist, ks=ks), ...)``
        is bit-identical to the legacy ``sweep(key, dist, ..., ks=ks)``.
        Defaults to exponential service (Theorem 1's case)."""
        if dists is None:
            from repro.core.distributions import exponential
            dists = exponential()
        return cls(dists=dists, policy=Policy.REPLICATE_ALL,
                   service_model=ServiceModel.IID, mix=0.0, ks=ks,
                   client_overhead=client_overhead,
                   warmup_frac=warmup_frac)

    @property
    def k_max(self) -> int:
        return max(self.ks)

    @property
    def n_dists(self) -> int:
        return len(self.dists)

    def variant_for(self, k: int) -> Variant:
        """The per-cell coordinates of this scenario at replication ``k``."""
        return Variant(k=int(k), policy=self.policy,
                       service_model=self.service_model, mix=self.mix,
                       overhead=self.client_overhead,
                       p_slow=self.degradation.p_slow,
                       slow_factor=self.degradation.slow_factor,
                       p_fail=self.degradation.p_fail,
                       delay=self.delay)

    def variants(self) -> tuple[Variant, ...]:
        """One ``Variant`` per entry of ``ks`` (the plan's k-axis order)."""
        return tuple(self.variant_for(k) for k in self.ks)


jax.tree_util.register_static(Scenario)
jax.tree_util.register_static(Variant)
jax.tree_util.register_static(Degradation)

ScenarioLike = Union[Scenario, Sequence[Scenario]]


def combine(scenario: ScenarioLike) -> tuple[tuple[ServiceDist, ...], float,
                                             tuple[Variant, ...]]:
    """Normalize one Scenario or a sequence (a *mixed grid*) for the engine.

    A sequence concatenates each scenario's variants along the plan's
    k-axis — mixed-policy / mixed-model grids run in ONE engine call and
    one compiled body. All scenarios of a grid must share
    ``warmup_frac`` (they share the warmup cutoff); ``ks`` / policy /
    model / mix / overhead vary per variant.

    Scenarios may also differ in ``dists`` — the HETEROGENEOUS grid:
    each scenario then contributes exactly one distribution ("its
    system"), the distinct dists are deduped into a union tuple, and
    every variant carries its ``dist_id`` index into that union as one
    more per-cell coordinate (``repro.core.queueing`` samples one
    service table per union member and routes each cell to its own —
    this is how different SYSTEMS share one compiled mixed grid).

    Returns ``(dists, warmup_frac, variants)``.
    """
    scns: tuple[Scenario, ...]
    if isinstance(scenario, Scenario):
        scns = (scenario,)
    else:
        scns = tuple(scenario)
    if not scns or not all(isinstance(s, Scenario) for s in scns):
        raise TypeError("expected a Scenario or a non-empty sequence of "
                        f"Scenarios, got {scenario!r}")
    first = scns[0]
    for s in scns[1:]:
        if s.warmup_frac != first.warmup_frac:
            raise ValueError(
                "all scenarios of a mixed grid must share warmup_frac "
                f"(got {s.warmup_frac} vs {first.warmup_frac})")
    if all(s.dists == first.dists for s in scns):
        # homogeneous grid: every cell reads dist stack 0 (legacy path;
        # multi-dist stacks ride the seed axis exactly as before)
        variants = tuple(v for s in scns for v in s.variants())
        return first.dists, first.warmup_frac, variants
    for s in scns:
        if len(s.dists) != 1:
            raise ValueError(
                "scenarios of a heterogeneous mixed grid must each "
                f"carry exactly one dist, got {s.dists}")
    union: list[ServiceDist] = []
    variants_l: list[Variant] = []
    for s in scns:
        d = s.dists[0]
        if d not in union:
            union.append(d)
        did = union.index(d)
        variants_l.extend(dataclasses.replace(v, dist_id=did)
                          for v in s.variants())
    return tuple(union), first.warmup_frac, tuple(variants_l)


def provenance(scenario: ScenarioLike) -> Union[dict, list]:
    """JSON-serializable description of a scenario (benchmark rows record
    this next to each measurement): policy / service model / mix / ks /
    overhead per scenario."""
    if not isinstance(scenario, Scenario):
        return [provenance(s) for s in scenario]
    prov = {"policy": scenario.policy.name,
            "service_model": scenario.service_model.name,
            "mix": scenario.mix, "ks": list(scenario.ks),
            "client_overhead": scenario.client_overhead,
            "dists": [d.name for d in scenario.dists]}
    if not scenario.degradation.healthy or scenario.delay:
        prov["degradation"] = {"p_slow": scenario.degradation.p_slow,
                               "slow_factor": scenario.degradation.slow_factor,
                               "p_fail": scenario.degradation.p_fail}
        prov["delay"] = scenario.delay
    return prov


def any_server_dependent(variants: Iterable[Variant]) -> bool:
    """Whether the engine must sample the extra shared-component column."""
    return any(v.needs_shared_draw for v in variants)


def any_degraded(variants: Iterable[Variant]) -> bool:
    """Whether the engine must sample the per-copy degradation uniforms."""
    return any(v.needs_degradation_draw for v in variants)


def any_timed(variants: Iterable[Variant]) -> bool:
    """Whether the grid contains a TIMED_POLICIES variant — a STATIC
    flag: the scan body compiles its timed-dispatch block only then,
    keeping every non-timed grid on the exact pre-timed compiled
    program (see ``cell_update.ref.step_cell``)."""
    return any(v.policy in TIMED_POLICIES for v in variants)


def variant_codes(variants):
    """Per-variant ``(policies, models)`` code lists for
    ``cellplan.make_cell_plan`` — the ONE place Variants lower to plan
    codes. Returns ``(None, None)`` (paper default everywhere) when
    given a legacy ``ks`` tuple of plain ints."""
    variants = tuple(variants)
    if not variants or not isinstance(variants[0], Variant):
        return None, None
    return ([int(v.policy) for v in variants],
            [int(v.service_model) for v in variants])


def variant_dist_ids(variants):
    """Per-variant ``dist_id`` list for ``cellplan.make_cell_plan``, or
    ``None`` (dist 0 everywhere) for a legacy ``ks`` tuple of ints."""
    variants = tuple(variants)
    if not variants or not isinstance(variants[0], Variant):
        return None
    return [int(v.dist_id) for v in variants]


def any_dist_ids(variants) -> bool:
    """Whether the grid is HETEROGENEOUS (some variant reads a dist
    union slot other than 0) — a STATIC flag: the engine samples one
    service table per union member and threads per-cell table indices
    only then, keeping every homogeneous grid on the exact pre-dist_id
    compiled program."""
    return any(isinstance(v, Variant) and v.dist_id != 0 for v in variants)
