"""Cell-plan construction for the sweep engine.

The fused engine's summaries are stacked over three axes — seeds ``S``
(dist-stacked for ``sweep_dists``), loads ``B``, replication factors
``K`` — but every (s, b, k) grid cell is an independent simulation:
per-cell server free-times, Kahan mean state, and histogram rows never
interact. A ``CellPlan`` makes that independence explicit by flattening
the stacked axes into ONE cell axis of length ``S * B * K`` (C-order:
seed slowest, k fastest, matching ``reshape(S, B, K)``), padded up to a
multiple of ``pad_to`` so the cell axis divides a device mesh evenly.

Each cell carries its coordinates (``seed_idx`` / ``load_idx`` /
``k_idx``) plus a validity mask. Since the scenario API (PR 5), the
k-axis is really a *variant* axis: next to (seed, load, k) every cell
also carries its replication-policy and service-model CODES
(``policy_code`` / ``model_code``, see ``repro.core.scenario``), so a
mixed-policy grid is just a plan whose cells disagree on those two
columns — the chunk body branches on them per cell via selects inside
one compiled scan, and the fused Pallas cell-update kernel
(``repro.kernels.cell_update``) receives the same codes as
scalar-prefetch operands, one pair per grid cell, selecting the policy
arm inside the kernel body with identical select ops. Pad cells alias cell 0's coordinates (including its
policy/model codes) so they simulate real, finite work (no NaN/inf
poisoning a shared buffer or a collective) but are marked invalid and
sliced away by ``unflatten`` before any summary is read — a pad cell
cannot contribute to a Kahan mean or a hist_sketch bin of a real cell
because no per-cell state is ever reduced across the cell axis.

Both execution layers consume the same plan: the single-device driver in
``repro.core.queueing`` builds an unpadded plan (``pad_to=1``) and the
sharded driver in ``repro.distributed.sweep_shard`` pads to the mesh
size. Cell RANDOMNESS is keyed by the seed coordinate alone (chunk seed
keys indexed with ``seed_idx``), never by position on the cell axis or
device placement — which is what makes sharded and unsharded execution
bit-identical for any device count.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CellPlan:
    """Flattened (seed, load, variant) sweep grid with mesh padding."""

    n_seeds: int
    n_loads: int
    n_ks: int
    n_cells: int       # S * B * K real cells
    n_padded: int      # n_cells rounded up to a multiple of pad_to
    seed_idx: Array    # (n_padded,) int32 — seed coordinate per cell
    load_idx: Array    # (n_padded,) int32 — load coordinate per cell
    k_idx: Array       # (n_padded,) int32 — variant coordinate per cell
    valid: Array       # (n_padded,) bool  — False for pad cells
    policy_code: Array  # (n_padded,) int32 — scenario.Policy per cell
    model_code: Array   # (n_padded,) int32 — scenario.ServiceModel per cell
    dist_id: Array      # (n_padded,) int32 — dist-union index per cell

    @property
    def stacked_shape(self) -> tuple[int, int, int]:
        return (self.n_seeds, self.n_loads, self.n_ks)

    def sharding_rule(self, mesh):
        """Declare this plan's placement on a ``"cells"`` mesh: returns
        the ``repro.launch.mesh.SweepShardingRules`` whose specs /
        constructors the sharded executor consumes (cell-axis trees
        shard ``P("cells")``, chunk scalars replicate). The ONE place
        plan placement is decided — callers never hand-build
        ``NamedSharding``s. Requires ``n_padded`` to be a multiple of
        the mesh size (``make_cell_plan(pad_to=mesh.devices.size)``)."""
        from repro.launch.mesh import SweepShardingRules

        rules = SweepShardingRules(mesh)
        if self.n_padded % rules.n_devices:
            raise ValueError(
                f"plan has {self.n_padded} padded cells, not a multiple "
                f"of the {rules.n_devices}-device mesh; build it with "
                f"pad_to=mesh.devices.size")
        return rules


def make_cell_plan(n_seeds: int, n_loads: int, n_ks: int, *,
                   pad_to: int = 1,
                   policies=None, models=None,
                   dist_ids=None) -> CellPlan:
    """Flatten an (S, B, K) grid into a padded cell axis.

    Cell ``c`` maps to coordinates ``(c // (B*K), (c // K) % B, c % K)``
    — C-order, so ``unflatten`` is a plain ``reshape(S, B, K)`` of the
    first ``n_cells`` entries. Pad cells (when ``S*B*K`` is not a
    multiple of ``pad_to``) copy cell 0's coordinates and are flagged
    ``valid=False``.

    ``policies`` / ``models`` / ``dist_ids`` are per-VARIANT code
    sequences of length ``n_ks`` (``repro.core.scenario`` ints); each
    cell inherits the codes of its variant slot, pad cells inherit cell
    0's. ``None`` means all cells run the paper default (code 0:
    replicate-all, i.i.d. service, dist-union slot 0).
    """
    if min(n_seeds, n_loads, n_ks, pad_to) < 1:
        raise ValueError(
            f"all plan axes must be >= 1, got {(n_seeds, n_loads, n_ks)} "
            f"pad_to={pad_to}")
    for name, codes in (("policies", policies), ("models", models),
                        ("dist_ids", dist_ids)):
        if codes is not None and len(codes) != n_ks:
            raise ValueError(f"{name} must have one code per variant "
                             f"({n_ks}), got {len(codes)}")
    n_cells = n_seeds * n_loads * n_ks
    n_padded = -(-n_cells // pad_to) * pad_to
    c = np.arange(n_padded)
    k_idx = c % n_ks
    load_idx = (c // n_ks) % n_loads
    seed_idx = c // (n_ks * n_loads)
    pad = slice(n_cells, n_padded)
    seed_idx[pad] = load_idx[pad] = k_idx[pad] = 0
    policy = np.zeros(n_ks, np.int32) if policies is None else np.asarray(
        [int(p) for p in policies], np.int32)
    model = np.zeros(n_ks, np.int32) if models is None else np.asarray(
        [int(m) for m in models], np.int32)
    did = np.zeros(n_ks, np.int32) if dist_ids is None else np.asarray(
        [int(d) for d in dist_ids], np.int32)
    return CellPlan(
        n_seeds=n_seeds, n_loads=n_loads, n_ks=n_ks,
        n_cells=n_cells, n_padded=n_padded,
        seed_idx=jnp.asarray(seed_idx, jnp.int32),
        load_idx=jnp.asarray(load_idx, jnp.int32),
        k_idx=jnp.asarray(k_idx, jnp.int32),
        valid=jnp.asarray(c < n_cells),
        policy_code=jnp.asarray(policy[k_idx], jnp.int32),
        model_code=jnp.asarray(model[k_idx], jnp.int32),
        dist_id=jnp.asarray(did[k_idx], jnp.int32))


def device_row_maps(idx, n_devices: int):
    """Per-device input-row sets + device-local remap for a global
    ``(n_padded,)`` input-row index array (the plan's ``seed_idx``, or
    the heterogeneous-grid svc-row index ``dist_id * n_seeds +
    seed_idx``).

    Returns ``(rows, local)``: ``rows[d]`` lists the global input rows
    device ``d``'s cells gather — unique, sorted, padded to the common
    width ``R = max_d |unique(d)|`` by repeating the last entry so every
    device's block has the same shape — and ``local[c]`` is the position
    of cell ``c``'s row inside its OWN device's list. For any global
    input block ``x`` (rows = seed rows), device ``d``'s local block
    ``x[rows[d]]`` then satisfies

        x[rows[d]][local[c]] == x[idx[c]]   for every cell c on d,

    i.e. remapping indices to device-local row positions gathers
    exactly the same sampled values — the chunk body reads inputs ONLY
    through per-cell row gathers, so the remap cannot change bits; it
    only changes WHICH rows each host must materialize (the per-host
    sampling reduction of the multi-host executor).
    """
    idx = np.asarray(idx)
    n_padded = idx.shape[0]
    if n_padded % n_devices:
        raise ValueError(f"{n_padded} cells do not tile {n_devices} "
                         f"devices")
    per = n_padded // n_devices
    uniq = [np.unique(idx[d * per:(d + 1) * per])
            for d in range(n_devices)]
    width = max(u.size for u in uniq)
    rows = np.stack([np.pad(u, (0, width - u.size), mode="edge")
                     for u in uniq]).astype(np.int32)
    local = np.empty((n_padded,), np.int32)
    for d, u in enumerate(uniq):
        seg = idx[d * per:(d + 1) * per]
        local[d * per:(d + 1) * per] = np.searchsorted(u, seg)
    return rows, local


def unflatten(plan: CellPlan, x: Array) -> Array:
    """Per-cell values ``(n_padded, ...)`` -> stacked ``(S, B, K, ...)``,
    dropping pad cells. The inverse of ``flatten`` on valid cells."""
    return x[:plan.n_cells].reshape(plan.stacked_shape + x.shape[1:])


def flatten(plan: CellPlan, x: Array) -> Array:
    """Stacked ``(S, B, K, ...)`` -> per-cell ``(n_padded, ...)``. Pad
    cells receive copies of cell 0's row (finite, mask-dropped later)."""
    flat = jnp.reshape(x, (plan.n_cells,) + x.shape[3:])
    n_pad = plan.n_padded - plan.n_cells
    if n_pad:
        flat = jnp.concatenate(
            [flat, jnp.broadcast_to(flat[:1], (n_pad,) + flat.shape[1:])])
    return flat
