"""Closed-form results from the paper.

* Theorem 1 (M/M/1 + replication): with unit-mean exponential service and
  per-server arrival rate rho, mean response is 1/(1-rho) unreplicated and
  1/(2(1-2rho)) with k=2 (min of two independent Exp(1-2rho) samples), so
  replication helps iff rho < 1/3.
* The general-k M/M/1 approximation (k-way independent queues).
* Client-side overhead break-even (paper Figure 4, exponential case).
* The §3.1 TCP-handshake model: per-packet loss p, initial timeouts
  (3 s SYN, 3 s SYN-ACK, 3·RTT ACK), exponential backoff; duplication moves
  p -> p_pair (the measured correlated pair-loss probability).
* Light-load means for the timed policies (``hedge_mean_light`` /
  ``retry_mean_light``): the closed forms the engine's TIMEOUT_RETRY /
  HEDGE_AFTER_DELAY codes are pinned against at low rho.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

THRESHOLD_EXPONENTIAL = 1.0 / 3.0
# Paper: deterministic-service threshold from queueing-model simulation.
THRESHOLD_DETERMINISTIC = 0.2582


def mm1_mean(rho) -> Array:
    """Mean response of an M/M/1 queue with unit-mean service."""
    rho = jnp.asarray(rho)
    return jnp.where(rho < 1.0, 1.0 / (1.0 - rho), jnp.inf)


def mm1_response_cdf(t, rho) -> Array:
    """P(response <= t) for M/M/1: Exp(1 - rho)."""
    t, rho = jnp.asarray(t), jnp.asarray(rho)
    return 1.0 - jnp.exp(-(1.0 - rho) * t)


def mm1_replicated_mean(rho, k: int = 2) -> Array:
    """Mean of min over k independent M/M/1 responses, each at load k*rho."""
    rho = jnp.asarray(rho)
    rate = 1.0 - k * rho  # each copy's response ~ Exp(1 - k rho)
    return jnp.where(rate > 0.0, 1.0 / (k * rate), jnp.inf)


def hedge_mean_light(d) -> Array:
    """Light-load mean response of ``HEDGE_AFTER_DELAY`` with a copy
    budget of 2, unit-mean exponential service and hedge delay ``d``
    (no queueing, no faults).

    The primary starts service immediately (S1 ~ Exp(1)); the hedge
    fires at ``d`` only if the primary has not finished. If S1 <= d the
    response is S1; otherwise, by memorylessness, the residual primary
    and the fresh hedge race as min of two Exp(1) ~ Exp(2) from ``d``:

      E[T] = E[S1; S1<=d] + P(S1>d) (d + 1/2)
           = (1 - e^{-d} - d e^{-d}) + e^{-d} (d + 1/2)
           = 1 - e^{-d}/2.

    Monotone increasing in ``d``: 1/2 at d=0 (= REPLICATE_ALL's
    min-of-two) up to 1 (no hedging) as d -> inf — the monotonicity the
    engine's hedge-delay sweep is pinned against.
    """
    d = jnp.asarray(d)
    return 1.0 - jnp.exp(-d) / 2.0


def retry_mean_light(d, f=0.0) -> Array:
    """Light-load mean response of ``TIMEOUT_RETRY`` with an attempt
    budget of 2, unit-mean exponential service, deadline ``d`` and
    blackhole probability ``f`` (each dispatched copy is lost in
    transit with prob ``f``, independently; the LAST in-budget attempt
    is escalated out-of-band and cannot be lost — the engine's
    ``alive_eff`` rule).

    The first attempt dispatches at 0, the retry at ``d`` (backoff
    offsets [0, 1]) only if nothing has completed. Conditioning on the
    first attempt's fate:

      alive (1-f):  identical to the hedge race -> 1 - e^{-d}/2
                    (see ``hedge_mean_light``);
      lost (f):     nothing can complete before the retry, which is
                    exempt -> T = d + S2, mean d + 1.

      E[T] = (1-f) (1 - e^{-d}/2) + f (1 + d).

    Setting f=0 recovers the hedge mean — at light load the two
    policies differ only under faults, which is exactly the
    fault-masking gap fig_fault_masking measures (under load the retry
    baseline also pays the duplicate-work tax).
    """
    d, f = jnp.asarray(d), jnp.asarray(f)
    return (1.0 - f) * (1.0 - jnp.exp(-d) / 2.0) + f * (1.0 + d)


def mm1_cancel_bounds(rho, k: int = 2) -> tuple[Array, Array]:
    """(lower, upper) analytic bounds on the mean response of M/M/1-style
    replication WITH cancellation-on-complete (``Policy.CANCEL_ON_COMPLETE``,
    unit-mean exponential service, per-server load ``rho``).

    * Lower ``1/k``: the response includes the winning copy's full service
      time, which is bounded below by the min over the k copies' draws —
      mean ``1/k`` for exponentials. Tight as ``rho -> 0`` (both copies
      start immediately, response -> E[min] = 1/k).
    * Upper ``1/(1-rho)``: the unreplicated M/M/1 mean. For exponential
      (memoryless) service with independent copies and
      cancel-on-complete, redundancy never hurts — the exact-analysis
      line of work on redundancy-d systems (Gardner et al.; Joshi et
      al.'s replicate-vs-queue tradeoffs) — so the k=1 closed form is an
      upper bound AT EVERY STABLE LOAD, including loads past the
      replicate-all threshold 1/3 and past rho = 1/2 where replicate-all
      is not even stable.

    These sandwich the simulator's ``CANCEL_ON_COMPLETE`` mean; the gap
    closes at light load (both -> 1/k as rho -> 0 only for the lower;
    the simulation approaches the lower bound).
    """
    rho = jnp.asarray(rho)
    lo = jnp.full_like(rho, 1.0 / k, dtype=jnp.float32)
    return lo, mm1_mean(rho)


def exponential_threshold(k: int = 2, overhead: float = 0.0) -> float:
    """Largest rho with mm1_replicated_mean(rho,k) + overhead < mm1_mean(rho).

    With overhead c: 1/(k(1-k rho)) + c = 1/(1-rho). For k=2, c=0 this gives
    exactly 1/3 (Theorem 1). Solved in closed form for k=2, numerically
    otherwise.
    """
    if k == 2 and overhead == 0.0:
        return THRESHOLD_EXPONENTIAL
    import numpy as np

    lo, hi = 1e-6, 1.0 / k - 1e-9
    f = lambda r: float(mm1_replicated_mean(r, k) + overhead - mm1_mean(r))
    if f(lo) >= 0.0:
        return 0.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if f(mid) < 0.0:
            lo = mid
        else:
            hi = mid
    return float(np.round(0.5 * (lo + hi), 6))


# ---------------------------------------------------------------------------
# §3.1 TCP connection establishment
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TCPModel:
    rtt: float = 0.03           # seconds
    p_single: float = 0.0048    # measured single-packet loss prob [Chan et al.]
    p_pair: float = 0.0007      # measured back-to-back pair loss prob
    syn_timeout: float = 3.0    # Linux initial SYN / SYN-ACK RTO
    max_retries: int = 8


def _packet_completion_time(key: Array, p: float, timeout: float, rtt: float,
                            shape: tuple[int, ...],
                            max_retries: int) -> Array:
    """Time until a packet is first delivered, with exponential backoff."""
    # retry r (r=0..R) succeeds w.p. (1-p) p^r; its completion time is
    # sum_{j<r} timeout*2^j + rtt/2.
    u = jax.random.uniform(key, shape)
    # invert the geometric: r = floor(log(1-u)/log(p)) clipped
    r = jnp.floor(jnp.log1p(-u) / jnp.log(p)).astype(jnp.int32)
    r = jnp.clip(r, 0, max_retries)
    backoff = timeout * (2.0 ** r.astype(jnp.float32) - 1.0)  # geometric sum
    return backoff + rtt / 2.0


def handshake_times(key: Array, model: TCPModel, n: int,
                    duplicated: bool) -> Array:
    """Monte-Carlo handshake completion times (n,) under the §3.1 model."""
    p = model.p_pair if duplicated else model.p_single
    k1, k2, k3 = jax.random.split(key, 3)
    syn = _packet_completion_time(k1, p, model.syn_timeout, model.rtt, (n,),
                                  model.max_retries)
    synack = _packet_completion_time(k2, p, model.syn_timeout, model.rtt, (n,),
                                     model.max_retries)
    ack = _packet_completion_time(k3, p, 3.0 * model.rtt, model.rtt, (n,),
                                  model.max_retries)
    return syn + synack + ack


def handshake_mean_saving(model: TCPModel) -> float:
    """First-order expected saving (the paper's back-of-envelope):
    (3 + 3 + 3*RTT) * (p_single - p_pair)."""
    dp = model.p_single - model.p_pair
    return (model.syn_timeout * 2 + 3.0 * model.rtt) * dp


# Cost-effectiveness benchmark from Vulimiri et al. [28, 29]:
BENEFIT_THRESHOLD_MS_PER_KB = 16.0
