"""Threshold-load estimation (paper §2.1), for ANY scenario.

The threshold load is "the largest utilization below which replication always
helps mean response time". The paper's results: 1/3 for exponential service
(Theorem 1), ~25.82% for deterministic service (conjectured global worst
case), approaching 50% for sufficiently heavy-tailed service. Shah et al.'s
server-dependent service model and the cancellation policies move the
threshold — pass a ``Scenario`` to estimate it anywhere in the policy space
(e.g. the threshold collapses toward ~0.28 as the server-dependent ``mix``
approaches 1, and cancellation pushes it past the paper's 0.5 bracket).

Every estimator takes EITHER a bare ``ServiceDist`` — estimated under the
paper's model, with ``client_overhead``/``warmup_frac`` read from the
``SimConfig`` exactly as before (bit-identical to the pre-scenario API) —
or a ``repro.core.scenario.Scenario`` whose policy / service model / mix /
overhead define the comparison; its ``ks`` are overridden to ``(1, k)``.

Three estimators, all driven by ``repro.core.queueing.run`` (one jitted
scan per evaluation, batched over seeds x loads x k; every estimator takes
``chunk_size`` and streams the engine when it is set, and ``mesh`` to
route every probe batch through the sharded cell-plan executor
``repro.distributed.sweep_shard`` — the probe loads ride the engine's
flattened cell axis, so one sharded call still serves a whole bracket, and
results stay bit-identical to the unsharded path. ``mesh=None`` is NOT
"no mesh": it defers to ``run``'s ambient resolution
(``repro.launch.mesh.resolve_mesh`` — a ``use_sweep_mesh`` context or the
multi-process default installed by ``distributed.multihost.initialize``),
so estimators need no mesh plumbing of their own to execute sharded, or
even multi-host):

  * ``threshold_bisect`` — bisection on the sign of the CRN-paired gain
    mean_k1(rho) - mean_k(rho). Both bracket probes ride in a single
    batched engine call, and the bisection itself is SPECULATIVE: each
    engine call evaluates the current midpoint AND both possible next
    midpoints as one batched 3-load sweep, so two bisection levels
    resolve per call (the engine's wall clock is dominated by the scan
    over arrivals, not the load axis — a 3-load call costs ~the same as
    a 1-load call). Precise; used by tests.
  * ``threshold_grid``  — ONE fused sweep over the whole load grid +
    crossing interpolation.
  * ``threshold_grid_batch`` — many distributions in ONE engine call
    (stacked along the seed axis); used by the Figure 2/3 benchmarks which
    need dozens of thresholds.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.queueing import Scenario, SimConfig, run

Array = jax.Array


def _paired_gain(mean: Array) -> Array:
    """(S, B, 2) sweep means -> (B,) seed-averaged CRN-paired gain."""
    return jnp.mean(mean[:, :, 0] - mean[:, :, 1], axis=0)


def _as_scenario(dist_or_scenario, cfg: SimConfig, k: int) -> Scenario:
    """Normalize an estimator's target to a ``Scenario`` at ks=(1, k).

    Bare distributions get the paper default with the legacy
    ``SimConfig`` overhead/warmup knobs (bit-identical to the
    pre-scenario estimators). Multi-``dists`` scenarios are rejected —
    their summaries carry a leading dist axis the single-threshold
    reductions here cannot interpret; use ``threshold_grid_batch``."""
    if isinstance(dist_or_scenario, Scenario):
        if dist_or_scenario.n_dists > 1:
            raise ValueError(
                "this estimator takes a single-dist Scenario (got "
                f"{dist_or_scenario.n_dists} dists); use "
                "threshold_grid_batch for multi-dist scenarios")
        return dataclasses.replace(dist_or_scenario, ks=(1, int(k)))
    return Scenario.paper_default(dist_or_scenario, ks=(1, int(k)),
                                  client_overhead=cfg.client_overhead,
                                  warmup_frac=cfg.warmup_frac)


def scenario_gain(key: Array, dist_or_scenario, rhos: Array,
                  cfg: SimConfig, *, k: int = 2, n_seeds: int = 2,
                  chunk_size: int | None = None, mesh=None,
                  kernel: str = "auto") -> Array:
    """(B,) seed-averaged CRN-paired gain mean_k1(rho) - mean_k(rho) under
    the scenario's policy / service model (positive = replication helps).
    The scenario-aware generalization of ``queueing.replication_gain``.
    ``kernel`` picks the engine's chunk-body implementation (see
    ``queueing.run``) — every mode is bit-identical, so thresholds are
    too.

    A SEQUENCE of single-dist Scenarios compares many SYSTEMS in one
    mixed-grid engine call (per-cell ``dist_id``; see
    ``scenario.combine``): each scenario is replaced with ``ks=(1, k)``,
    the paired columns interleave on the variant axis, and the result is
    ``(B, n_scenarios)`` — one gain curve per system, CRN-paired within
    each system."""
    if (not isinstance(dist_or_scenario, Scenario)
            and isinstance(dist_or_scenario, (list, tuple))
            and all(isinstance(s, Scenario) for s in dist_or_scenario)):
        scns = tuple(_as_scenario(s, cfg, k) for s in dist_or_scenario)
        out = run(key, scns, rhos, cfg, n_seeds=n_seeds, percentiles=(),
                  chunk_size=chunk_size, mesh=mesh, kernel=kernel)
        m = out["mean"]  # (S, B, 2 * n_scenarios), pairs interleaved
        return jnp.mean(m[:, :, 0::2] - m[:, :, 1::2], axis=0)
    scn = _as_scenario(dist_or_scenario, cfg, k)
    out = run(key, scn, rhos, cfg, n_seeds=n_seeds, percentiles=(),
              chunk_size=chunk_size, mesh=mesh, kernel=kernel)
    return _paired_gain(out["mean"])


def threshold_bisect(key: Array, dist_or_scenario, cfg: SimConfig, *,
                     k: int = 2, lo: float = 0.02, hi: float = 0.499,
                     iters: int = 10, n_seeds: int = 3,
                     speculative: bool = True,
                     chunk_size: int | None = None,
                     mesh=None, kernel: str = "auto") -> float:
    """Speculative bisection on the CRN-paired replication gain.

    Assumes the gain changes sign once on [lo, hi] (true for every family the
    paper studies). Returns the estimated crossing point; if replication
    helps on the whole interval, returns ``hi`` (threshold >= hi).

    With ``speculative=True`` each engine call evaluates the midpoint plus
    the two candidate next midpoints (the quarter points) in one batched
    sweep: the midpoint's sign picks the surviving half, whose quarter
    point — already evaluated — resolves a second level. ``iters`` counts
    bisection LEVELS either way, so the interval shrinks by 2**iters with
    about half the engine calls.
    """
    scn = _as_scenario(dist_or_scenario, cfg, k)
    kw = dict(n_seeds=n_seeds, percentiles=(), chunk_size=chunk_size,
              mesh=mesh, kernel=kernel)
    keys = jax.random.split(key, iters + 1)
    # both bracket probes in one batched (seeds x {lo,hi} x {1,k}) sweep
    bracket = run(keys[-1], scn, jnp.asarray([lo, hi]), cfg, **kw)
    g_lo, g_hi = (float(g) for g in _paired_gain(bracket["mean"]))
    if g_hi > 0.0:
        return hi
    if g_lo < 0.0:
        return lo
    a, b = lo, hi
    level = call = 0
    while level < iters:
        mid = 0.5 * (a + b)
        if speculative and level + 1 < iters:
            # midpoint + both possible next midpoints, one engine call
            probes = jnp.asarray([0.5 * (a + mid), mid, 0.5 * (mid + b)])
            out = run(keys[call], scn, probes, cfg, **kw)
            g_q_lo, g_mid, g_q_hi = (float(g)
                                     for g in _paired_gain(out["mean"]))
            if g_mid > 0.0:
                a, g_next, nxt = mid, g_q_hi, float(probes[2])
            else:
                b, g_next, nxt = mid, g_q_lo, float(probes[0])
            if g_next > 0.0:
                a = nxt
            else:
                b = nxt
            level += 2
        else:
            out = run(keys[call], scn, jnp.asarray([mid]), cfg, **kw)
            if float(_paired_gain(out["mean"])[0]) > 0.0:
                a = mid
            else:
                b = mid
            level += 1
        call += 1
    return 0.5 * (a + b)


def policy_table(key: Array, dist_or_scenario, cfg: SimConfig, *,
                 rhos: Array | None = None, ks: tuple[int, ...] = (1, 2),
                 delays: tuple[float, ...] = (0.0, 0.5, 1.0, 2.0),
                 percentile: float = 99.0, n_seeds: int = 2,
                 chunk_size: int | None = None, mesh=None,
                 kernel: str = "auto") -> dict:
    """Precompute a (rho x k x hedge-delay) policy table in ONE mixed-grid
    ``queueing.run`` sweep — the entry point the adaptive serving
    controller (``repro.serving.controller.PolicyTable``) is built on.

    The variant axis enumerates every candidate operating point:
    ``k=1`` is the bare no-replication baseline, and each ``k > 1``
    fans out over ``delays`` as ``HEDGE_AFTER_DELAY`` variants
    (``delay=0`` degenerates bit-identically to the paper's immediate
    ``REPLICATE_ALL``, so the paper point is always one column of the
    table). All variants of all loads ride one compiled engine call and
    share the engine's CRN arrival/service draws, so column comparisons
    are paired exactly like ``scenario_gain``'s.

    Returns a dict of NUMPY arrays (the serve-time consumer is pure
    numpy — no JAX dispatch on a request hot path):

      ``rhos``       (B,) the load grid
      ``k``          (V,) replication factor per variant
      ``delay``      (V,) hedge delay per variant, engine units (mean
                     service times; 0 = immediate replication)
      ``tail``       (B, V) seed-averaged p``percentile`` response
      ``mean``       (B, V) seed-averaged mean response
      ``percentile`` the tail percentile measured

    ``dist_or_scenario`` follows the other estimators: a bare dist gets
    the paper default with the ``SimConfig`` overhead/warmup knobs; a
    (single-dist) ``Scenario`` contributes its service model / mix /
    degradation / overhead to every variant."""
    import numpy as np

    if rhos is None:
        rhos = jnp.linspace(0.05, 0.75, 8)
    rhos = jnp.asarray(rhos)
    base = _as_scenario(dist_or_scenario, cfg, 2)
    from repro.core.scenario import Policy
    scns, entries = [], []
    for k in ks:
        k = int(k)
        if k < 1:
            raise ValueError(f"policy_table ks must be >= 1, got {k}")
        if k == 1:
            scns.append(dataclasses.replace(
                base, ks=(1,), policy=Policy.REPLICATE_ALL, delay=0.0))
            entries.append((1, 0.0))
        else:
            for d in delays:
                scns.append(dataclasses.replace(
                    base, ks=(k,), policy=Policy.HEDGE_AFTER_DELAY,
                    delay=float(d)))
                entries.append((k, float(d)))
    out = run(key, scns, rhos, cfg, n_seeds=n_seeds,
              percentiles=(float(percentile),), chunk_size=chunk_size,
              mesh=mesh, kernel=kernel)
    tail = np.asarray(out[f"p{float(percentile):g}"]).mean(axis=0)  # (B, V)
    mean = np.asarray(out["mean"]).mean(axis=0)
    return {"rhos": np.asarray(rhos, dtype=np.float64),
            "k": np.asarray([e[0] for e in entries], dtype=np.int64),
            "delay": np.asarray([e[1] for e in entries], dtype=np.float64),
            "tail": tail.astype(np.float64),
            "mean": mean.astype(np.float64),
            "percentile": float(percentile)}


def crossing_load(rhos: Array, g: Array) -> float:
    """Threshold load from a sampled gain curve: linear interpolation of
    the first sign change of ``g(rho)`` (``rhos[-1]`` if replication
    helps everywhere sampled, ``rhos[0]`` if it never helps). The public
    companion of ``scenario_gain`` — feed it one column of a mixed-grid
    gain matrix to read each system's crossover off the same sweep."""
    return _interp_crossing(rhos, g)


def _interp_crossing(rhos: Array, g: Array) -> float:
    """Linear interpolation of the first sign change of g(rho)."""
    g = jnp.asarray(g)
    neg = jnp.where(g < 0.0)[0]
    if neg.size == 0:
        return float(rhos[-1])  # helps everywhere we looked: threshold >= max
    i = int(neg[0])
    if i == 0:
        return float(rhos[0])
    # linear interpolation between the last positive and first negative point
    x0, x1 = float(rhos[i - 1]), float(rhos[i])
    y0, y1 = float(g[i - 1]), float(g[i])
    return x0 + (x1 - x0) * y0 / (y0 - y1)


def _default_rhos() -> Array:
    return jnp.linspace(0.05, 0.495, 24)


def threshold_grid(key: Array, dist_or_scenario, cfg: SimConfig, *,
                   k: int = 2, rhos: Array | None = None, n_seeds: int = 2,
                   chunk_size: int | None = None, mesh=None,
                   kernel: str = "auto") -> float:
    """ONE fused sweep over the load grid + crossing interpolation."""
    if rhos is None:
        rhos = _default_rhos()
    g = scenario_gain(key, dist_or_scenario, rhos, cfg, k=k,
                      n_seeds=n_seeds, chunk_size=chunk_size, mesh=mesh,
                      kernel=kernel)
    return _interp_crossing(rhos, g)


def threshold_grid_batch(key: Array, dists_or_scenario, cfg: SimConfig, *,
                         k: int = 2, rhos: Array | None = None,
                         n_seeds: int = 2,
                         chunk_size: int | None = None,
                         mesh=None, kernel: str = "auto") -> list[float]:
    """Thresholds for MANY distributions from a single fused engine call
    (distributions stack along the engine's seed axis, so e.g. all 15
    Figure 2 families run in one scan — sharded over the cell axis when
    ``mesh`` is given). Accepts a list of distributions (paper model) or
    one multi-``dists`` ``Scenario``; returns one threshold per dist."""
    if rhos is None:
        rhos = _default_rhos()
    if isinstance(dists_or_scenario, Scenario):
        # multi-dist scenarios are THE point of the batch estimator
        scn = dataclasses.replace(dists_or_scenario, ks=(1, int(k)))
    else:
        dist_tuple = tuple(dists_or_scenario)  # once: may be a generator
        scn = dataclasses.replace(_as_scenario(dist_tuple[0], cfg, k),
                                  dists=dist_tuple)
    out = run(key, scn, rhos, cfg, n_seeds=n_seeds, percentiles=(),
              chunk_size=chunk_size, mesh=mesh, kernel=kernel)
    m = out["mean"]  # (D, S, B, 2) — or (S, B, 2) for a single dist
    if m.ndim == 3:
        m = m[None]
    g = jnp.mean(m[:, :, :, 0] - m[:, :, :, 1], axis=1)  # (D, B)
    return [_interp_crossing(rhos, g[d]) for d in range(g.shape[0])]
