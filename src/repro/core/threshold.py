"""Threshold-load estimation (paper §2.1).

The threshold load is "the largest utilization below which replication always
helps mean response time". The paper's results: 1/3 for exponential service
(Theorem 1), ~25.82% for deterministic service (conjectured global worst
case), approaching 50% for sufficiently heavy-tailed service.

Three estimators, all driven by the fused sweep engine in
``repro.core.queueing`` (one jitted scan per evaluation, batched over
seeds x loads x k; every estimator takes ``chunk_size`` and streams the
engine when it is set, and ``mesh`` to route every probe batch through
the sharded cell-plan executor ``repro.distributed.sweep_shard`` — the
probe loads ride the engine's flattened cell axis, so one sharded call
still serves a whole bracket, and results stay bit-identical to the
unsharded path):

  * ``threshold_bisect`` — bisection on the sign of the CRN-paired gain
    mean_k1(rho) - mean_k2(rho). Both bracket probes ride in a single
    batched sweep call, and the bisection itself is SPECULATIVE: each
    engine call evaluates the current midpoint AND both possible next
    midpoints as one batched 3-load sweep, so two bisection levels
    resolve per call (the engine's wall clock is dominated by the scan
    over arrivals, not the load axis — a 3-load call costs ~the same as
    a 1-load call). Precise; used by tests.
  * ``threshold_grid``  — ONE fused sweep over the whole load grid +
    crossing interpolation.
  * ``threshold_grid_batch`` — many distributions in ONE engine call
    (stacked along the seed axis); used by the Figure 2/3 benchmarks which
    need dozens of thresholds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distributions import ServiceDist
from repro.core.queueing import SimConfig, replication_gain, sweep, sweep_dists

Array = jax.Array


def _paired_gain(mean: Array) -> Array:
    """(S, B, 2) sweep means -> (B,) seed-averaged CRN-paired gain."""
    return jnp.mean(mean[:, :, 0] - mean[:, :, 1], axis=0)


def _engines(mesh):
    """(sweep, sweep_dists) — local pair, or the sharded cell-plan
    executors bound to ``mesh`` (bit-identical; lazy import keeps
    core free of the distributed layer unless sharding is requested)."""
    if mesh is None:
        return sweep, sweep_dists
    from functools import partial

    from repro.distributed import sweep_shard
    return (partial(sweep_shard.sweep_sharded, mesh=mesh),
            partial(sweep_shard.sweep_dists_sharded, mesh=mesh))


def threshold_bisect(key: Array, dist: ServiceDist, cfg: SimConfig, *,
                     k: int = 2, lo: float = 0.02, hi: float = 0.499,
                     iters: int = 10, n_seeds: int = 3,
                     speculative: bool = True,
                     chunk_size: int | None = None,
                     mesh=None) -> float:
    """Speculative bisection on the CRN-paired replication gain.

    Assumes the gain changes sign once on [lo, hi] (true for every family the
    paper studies). Returns the estimated crossing point; if replication
    helps on the whole interval, returns ``hi`` (threshold >= hi).

    With ``speculative=True`` each engine call evaluates the midpoint plus
    the two candidate next midpoints (the quarter points) in one batched
    sweep: the midpoint's sign picks the surviving half, whose quarter
    point — already evaluated — resolves a second level. ``iters`` counts
    bisection LEVELS either way, so the interval shrinks by 2**iters with
    about half the engine calls.
    """
    sweep_fn, _ = _engines(mesh)
    keys = jax.random.split(key, iters + 1)
    # both bracket probes in one batched (seeds x {lo,hi} x {1,k}) sweep
    bracket = sweep_fn(keys[-1], dist, jnp.asarray([lo, hi]), cfg,
                       ks=(1, k), n_seeds=n_seeds, percentiles=(),
                       chunk_size=chunk_size)
    g_lo, g_hi = (float(g) for g in _paired_gain(bracket["mean"]))
    if g_hi > 0.0:
        return hi
    if g_lo < 0.0:
        return lo
    a, b = lo, hi
    level = call = 0
    while level < iters:
        mid = 0.5 * (a + b)
        if speculative and level + 1 < iters:
            # midpoint + both possible next midpoints, one engine call
            probes = jnp.asarray([0.5 * (a + mid), mid, 0.5 * (mid + b)])
            out = sweep_fn(keys[call], dist, probes, cfg, ks=(1, k),
                           n_seeds=n_seeds, percentiles=(),
                           chunk_size=chunk_size)
            g_q_lo, g_mid, g_q_hi = (float(g)
                                     for g in _paired_gain(out["mean"]))
            if g_mid > 0.0:
                a, g_next, nxt = mid, g_q_hi, float(probes[2])
            else:
                b, g_next, nxt = mid, g_q_lo, float(probes[0])
            if g_next > 0.0:
                a = nxt
            else:
                b = nxt
            level += 2
        else:
            g = replication_gain(keys[call], dist, jnp.asarray([mid]), cfg,
                                 k=k, n_seeds=n_seeds, chunk_size=chunk_size,
                                 mesh=mesh)
            if float(g[0]) > 0.0:
                a = mid
            else:
                b = mid
            level += 1
        call += 1
    return 0.5 * (a + b)


def _interp_crossing(rhos: Array, g: Array) -> float:
    """Linear interpolation of the first sign change of g(rho)."""
    g = jnp.asarray(g)
    neg = jnp.where(g < 0.0)[0]
    if neg.size == 0:
        return float(rhos[-1])  # helps everywhere we looked: threshold >= max
    i = int(neg[0])
    if i == 0:
        return float(rhos[0])
    # linear interpolation between the last positive and first negative point
    x0, x1 = float(rhos[i - 1]), float(rhos[i])
    y0, y1 = float(g[i - 1]), float(g[i])
    return x0 + (x1 - x0) * y0 / (y0 - y1)


def _default_rhos() -> Array:
    return jnp.linspace(0.05, 0.495, 24)


def threshold_grid(key: Array, dist: ServiceDist, cfg: SimConfig, *,
                   k: int = 2, rhos: Array | None = None, n_seeds: int = 2,
                   chunk_size: int | None = None, mesh=None) -> float:
    """ONE fused sweep over the load grid + crossing interpolation."""
    if rhos is None:
        rhos = _default_rhos()
    g = replication_gain(key, dist, rhos, cfg, k=k, n_seeds=n_seeds,
                         chunk_size=chunk_size, mesh=mesh)
    return _interp_crossing(rhos, g)


def threshold_grid_batch(key: Array, dist_list, cfg: SimConfig, *,
                         k: int = 2, rhos: Array | None = None,
                         n_seeds: int = 2,
                         chunk_size: int | None = None,
                         mesh=None) -> list[float]:
    """Thresholds for MANY distributions from a single fused engine call
    (distributions stack along the engine's seed axis, so e.g. all 15
    Figure 2 families run in one scan — sharded over the cell axis when
    ``mesh`` is given)."""
    if rhos is None:
        rhos = _default_rhos()
    _, sweep_dists_fn = _engines(mesh)
    out = sweep_dists_fn(key, dist_list, rhos, cfg, ks=(1, k),
                         n_seeds=n_seeds, percentiles=(),
                         chunk_size=chunk_size)
    m = out["mean"]  # (D, S, B, 2)
    g = jnp.mean(m[:, :, :, 0] - m[:, :, :, 1], axis=1)  # (D, B)
    return [_interp_crossing(rhos, g[d]) for d in range(len(dist_list))]
