"""Threshold-load estimation (paper §2.1).

The threshold load is "the largest utilization below which replication always
helps mean response time". The paper's results: 1/3 for exponential service
(Theorem 1), ~25.82% for deterministic service (conjectured global worst
case), approaching 50% for sufficiently heavy-tailed service.

Two estimators:
  * ``threshold_bisect`` — bisection on the sign of the CRN-paired gain
    mean_k1(rho) - mean_k2(rho). Precise; used by tests.
  * ``threshold_grid``  — one coupled grid sweep + crossing interpolation.
    Cheap; used by the Figure 2/3 benchmarks which need dozens of thresholds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distributions import ServiceDist
from repro.core.queueing import SimConfig, replication_gain

Array = jax.Array


def threshold_bisect(key: Array, dist: ServiceDist, cfg: SimConfig, *,
                     k: int = 2, lo: float = 0.02, hi: float = 0.499,
                     iters: int = 10, n_seeds: int = 3) -> float:
    """Bisection on the CRN-paired replication gain.

    Assumes the gain changes sign once on [lo, hi] (true for every family the
    paper studies). Returns the estimated crossing point; if replication
    helps on the whole interval, returns ``hi`` (threshold >= hi).
    """
    def gain_at(rho: float, skey: Array) -> float:
        g = replication_gain(skey, dist, jnp.asarray([rho]), cfg, k=k,
                             n_seeds=n_seeds)
        return float(g[0])

    keys = jax.random.split(key, iters + 2)
    if gain_at(hi, keys[-1]) > 0.0:
        return hi
    if gain_at(lo, keys[-2]) < 0.0:
        return lo
    a, b = lo, hi
    for i in range(iters):
        mid = 0.5 * (a + b)
        if gain_at(mid, keys[i]) > 0.0:
            a = mid
        else:
            b = mid
    return 0.5 * (a + b)


def threshold_grid(key: Array, dist: ServiceDist, cfg: SimConfig, *,
                   k: int = 2, rhos: Array | None = None,
                   n_seeds: int = 2) -> float:
    """Grid sweep + linear interpolation of the first sign change."""
    if rhos is None:
        rhos = jnp.linspace(0.05, 0.495, 24)
    g = replication_gain(key, dist, rhos, cfg, k=k, n_seeds=n_seeds)
    g = jnp.asarray(g)
    neg = jnp.where(g < 0.0)[0]
    if neg.size == 0:
        return float(rhos[-1])  # helps everywhere we looked: threshold >= max
    i = int(neg[0])
    if i == 0:
        return float(rhos[0])
    # linear interpolation between the last positive and first negative point
    x0, x1 = float(rhos[i - 1]), float(rhos[i])
    y0, y1 = float(g[i - 1]), float(g[i])
    return x0 + (x1 - x0) * y0 / (y0 - y1)
