"""Core of the reproduction: the paper's replication-for-latency technique.

- ``distributions`` / ``queueing`` / ``threshold``: §2.1 queueing model.
- ``scenario``: declarative policy-space spec (replication policy,
  service model, ks/overhead/warmup) executed by ``queueing.run``.
- ``analytic``: Theorem 1 closed forms + §3.1 TCP handshake model.
- ``hedging``: the runtime combinator (hedged dispatch, threshold policy).
- ``storage_sim`` / ``dns`` / ``netsim``: the paper's application studies.
"""
from repro.core import analytic, distributions, dns, hedging, queueing, scenario, storage_sim, threshold  # noqa: F401
