"""Double-buffered sampling/compute pipeline for the chunked sweep engine.

The chunk loop of ``repro.core.queueing._run_engine`` (and of the
sharded executor ``repro.distributed.sweep_shard``) alternates two
phases per chunk: SAMPLE the chunk's randomness on the host, then
DISPATCH the chunk body to the device. Run serially, the device sits
idle during every sample phase. ``iter_staged`` overlaps them: a
producer thread draws chunk ``c+1``'s inputs (through the engine's
FUSED jitted sampler — one dispatch per chunk instead of dozens of
eager ops) while the main thread dispatches chunk ``c``'s compute, with
a bounded ring of staging slots providing backpressure — the
``TransferBufferPool`` idiom: a fixed pool of in-flight buffers, a slot
is acquired before producing into it and released once the consumer has
dispatched the chunk that used it, so at most ``depth`` sampled chunks
(plus the one being consumed) ever exist at once and peak memory stays
O(depth x chunk inputs), independent of the stream length.

Bit-identity: the pipeline changes WHEN inputs are sampled, never WHAT
is sampled — chunk ``c`` still draws from ``fold_in(key, c)`` through
the same sampler, and the fused sampler is bit-identical to the eager
one (pinned by tests/test_multihost.py) — so ``pipeline="on"`` and
``pipeline="off"`` produce bit-identical summaries.

``PipelineStats`` records the last run's pipeline + sampling shape (per
chunk: rows/bytes actually sampled vs the full input block) so the
benchmark harness can carry per-host sampled-bytes provenance in
BENCH_*.json rows (``stats_provenance``). This module is deliberately
engine-agnostic (no ``queueing`` import): both execution layers feed it
plain callables.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading

# Staging slots the producer may fill ahead of the consumer (double
# buffering). More buys nothing: sampling one chunk is faster than
# simulating one, so the producer is never the bottleneck at depth 2.
DEFAULT_DEPTH = 2


@dataclasses.dataclass
class PipelineStats:
    """Pipeline + per-host sampling provenance of the last engine run.

    ``*_rows_sampled`` count the input-block rows THIS process actually
    drew per chunk; ``*_rows_total`` the full block's rows (what every
    process sampled before the per-host reduction). ``bytes_*`` are the
    same reduction in bytes of the (gaps, servers, services) inputs.
    """

    enabled: bool
    depth: int
    n_chunks: int
    seed_rows_sampled: int
    seed_rows_total: int
    svc_rows_sampled: int
    svc_rows_total: int
    bytes_sampled_per_chunk: int
    bytes_full_per_chunk: int
    process_count: int = 1
    process_index: int = 0

    @property
    def locality_factor(self) -> float:
        """full-block bytes / per-host sampled bytes (>= 1.0; the
        multi-host sampling reduction of the ISSUE's acceptance bar)."""
        return self.bytes_full_per_chunk / max(self.bytes_sampled_per_chunk,
                                               1)


_LAST_STATS: list[PipelineStats | None] = [None]


def record_stats(stats: PipelineStats) -> None:
    """Engine layers call this once per run; benchmarks read it back."""
    _LAST_STATS[0] = stats


def last_stats() -> PipelineStats | None:
    return _LAST_STATS[0]


def stats_provenance() -> dict | None:
    """The last run's stats as a JSON-ready dict (``run.py --json`` rows
    attach it as the ``sampling`` field)."""
    st = last_stats()
    if st is None:
        return None
    out = dataclasses.asdict(st)
    out["locality_factor"] = round(st.locality_factor, 3)
    return out


def iter_staged(produce, n_chunks: int, *, depth: int = DEFAULT_DEPTH,
                enabled: bool = True):
    """Yield ``produce(c)`` for ``c in range(n_chunks)``, prefetching up
    to ``depth`` chunks ahead on a producer thread when ``enabled``.

    The producer acquires a staging slot (blocking when ``depth`` chunks
    are already in flight), fills it with ``produce(c)``, and the
    consumer releases the slot after the yield returns — i.e. once the
    caller has dispatched that chunk's compute and come back for the
    next one. Order is preserved exactly (a single producer fills slots
    in chunk order). A producer exception is re-raised here, in the
    consumer, at the chunk that failed; closing the generator early
    (consumer exception) stops the producer promptly via the stop flag
    the slot-acquire loop polls.

    ``enabled=False`` (or a single chunk, where there is nothing to
    overlap) degrades to the plain serial loop — the pipeline-off
    reference path.
    """
    if not enabled or n_chunks <= 1 or depth < 1:
        for c in range(n_chunks):
            yield produce(c)
        return

    free = threading.Semaphore(depth)       # staging slots (buffer pool)
    ready: queue_mod.SimpleQueue = queue_mod.SimpleQueue()
    stop = threading.Event()

    def producer() -> None:
        for c in range(n_chunks):
            while not free.acquire(timeout=0.1):
                if stop.is_set():
                    return
            if stop.is_set():
                return
            try:
                ready.put((c, produce(c), None))
            except BaseException as e:  # surface in the consumer
                ready.put((c, None, e))
                return

    th = threading.Thread(target=producer, name="chunkflow-producer",
                          daemon=True)
    th.start()
    try:
        for c in range(n_chunks):
            got_c, payload, err = ready.get()
            assert got_c == c, (got_c, c)
            if err is not None:
                raise err
            yield payload
            free.release()  # chunk dispatched; its slot is reusable
    finally:
        stop.set()
        free.release()  # wake a producer blocked on a full ring
        th.join(timeout=30.0)
