"""Replicated DNS queries (paper §3.2).

Elastic-resource ("individual view") model: servers are public resolvers
whose load we do not influence, so there is no queueing — each server i has a
stationary response-time distribution and queries to different servers are
independent apart from a shared client/access-link component (which is what
keeps the k=10 tail from vanishing to zero, matching the paper's measured
6.5x / 50x — not 10^6x — tail reductions).

  response_i = shared + base_i + Exp(jitter_i),  or TIMEOUT w.p. loss_i
  shared     = 0 w.p. 1-p_shared, else Exp(shared_ms)

A query replicated to servers S completes at min_{i in S} response_i, and
anything above 2 s counts as 2 s (the paper treats >2 s as lost).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import distributions

Array = jax.Array

TIMEOUT_MS = 2000.0


@dataclasses.dataclass(frozen=True)
class DNSServer:
    base_ms: float
    jitter_ms: float
    loss: float


# A 10-resolver population loosely calibrated so that the *best single
# server* has mean ~= 50-70 ms with a ~1-2% >500 ms tail — the regime of the
# paper's PlanetLab measurement (local resolver + 9 public services).
DEFAULT_SERVERS: tuple[DNSServer, ...] = (
    DNSServer(12.0, 25.0, 0.010),   # local resolver: fast but lossy-ish
    DNSServer(18.0, 30.0, 0.008),
    DNSServer(22.0, 35.0, 0.006),
    DNSServer(25.0, 45.0, 0.008),
    DNSServer(30.0, 50.0, 0.010),
    DNSServer(35.0, 60.0, 0.012),
    DNSServer(40.0, 70.0, 0.010),
    DNSServer(55.0, 90.0, 0.015),
    DNSServer(70.0, 110.0, 0.015),
    DNSServer(90.0, 140.0, 0.020),
)


@dataclasses.dataclass(frozen=True)
class DNSPopulation:
    servers: tuple[DNSServer, ...] = DEFAULT_SERVERS
    p_shared: float = 0.02          # access-link congestion episodes
    shared_ms: float = 250.0
    query_bytes: int = 500          # per paper's cost arithmetic (~0.5 KB)


def sample_latencies(key: Array, pop: DNSPopulation, n: int) -> Array:
    """(n, n_servers) per-query per-server response times in ms."""
    ns = len(pop.servers)
    k_sh, k_b, k_j, k_l = jax.random.split(key, 4)
    shared_on = jax.random.uniform(k_sh, (n, 1)) < pop.p_shared
    shared = jnp.where(shared_on,
                       jax.random.exponential(k_b, (n, 1)) * pop.shared_ms, 0.0)
    base = jnp.asarray([s.base_ms for s in pop.servers])
    jitter = jnp.asarray([s.jitter_ms for s in pop.servers])
    loss = jnp.asarray([s.loss for s in pop.servers])
    lat = base[None, :] + jax.random.exponential(k_j, (n, ns)) * jitter[None, :]
    lost = jax.random.uniform(k_l, (n, ns)) < loss[None, :]
    lat = jnp.where(lost, TIMEOUT_MS, lat + shared)
    return jnp.minimum(lat, TIMEOUT_MS)


def rank_servers(key: Array, pop: DNSPopulation, n_probe: int = 20000) -> Array:
    """Stage 1 of the paper's experiment: rank servers by mean response."""
    lat = sample_latencies(key, pop, n_probe)
    return jnp.argsort(jnp.mean(lat, axis=0))


def replicated_response(lat: Array, ranking: Array, k: int) -> Array:
    """Stage 2: query the top-k ranked servers in parallel, take the min."""
    top = ranking[:k]
    return jnp.min(lat[:, top], axis=1)


def empirical_k_dists(key: Array, pop: DNSPopulation,
                      ks=range(1, 11), *, n_samples: int = 200_000,
                      n_quantiles: int = 512
                      ) -> tuple[distributions.EmpiricalDist, ...]:
    """Fit one unit-mean quantile-table ``EmpiricalDist`` per replication
    level: rank the population once, sample one shared latency table,
    and fit ``distributions.empirical`` on ``min`` over the top-k
    servers for each ``k``. Fitting the *min* (rather than composing
    per-server fits) preserves the shared-component correlation that
    bounds the k=10 tail. The fits are engine food — e.g. the Fig 15
    benchmark runs all ten as one heterogeneous mixed grid, and each
    fit's ``.scale`` recovers milliseconds."""
    k_rank, k_lat = jax.random.split(key)
    ranking = rank_servers(k_rank, pop)
    lat = sample_latencies(k_lat, pop, int(n_samples))
    return tuple(
        distributions.empirical(replicated_response(lat, ranking, k),
                                n_quantiles=n_quantiles,
                                name=f"dns(k={int(k)})")
        for k in ks)


def marginal_savings_ms_per_kb(means: Array, pop: DNSPopulation) -> Array:
    """Fig 17: mean saving of the (k+1)-th server per KB of extra traffic."""
    extra_kb = pop.query_bytes / 1024.0
    return (means[:-1] - means[1:]) / extra_kb
