"""Service-time models for the paper's storage studies (§2.2 disk-backed DB,
§2.3 memcached).

These produce a unit-mean ``ServiceDist`` + a normalized client-side
duplication overhead so they can be run straight through the §2.1 queueing
simulator; `ms_scale` converts results back to milliseconds for reporting.

Model: a request for a file of size s (KB) is
  * a cache hit  w.p. h: service = mem_base + s / mem_bw
  * a cache miss w.p. 1-h: service = seek (variable) + s / disk_bw
and the client pays (client_base + s * client_per_kb) extra latency per
duplicated request (NIC/kernel/CPU processing of the second copy), which is
the §2.1 "client-side overhead" knob. With 4 KB files that overhead is ~1% of
mean service (replication wins, Fig 5); with 400 KB files or an all-in-memory
store it is a large fraction (replication stops helping, Figs 10-12).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import distributions
from repro.core.distributions import ServiceDist

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StorageConfig:
    mean_file_kb: float = 4.0
    file_dist: str = "deterministic"     # deterministic | pareto
    file_pareto_alpha: float = 2.1
    cache_disk_ratio: float = 0.1        # cache size / total data size
    seek_ms: float = 8.0                 # mean disk seek+rotate
    seek_cv: float = 0.5                 # coefficient of variation of seek
    disk_kb_per_ms: float = 50.0         # ~50 MB/s sequential
    mem_base_ms: float = 0.15
    mem_kb_per_ms: float = 2000.0        # ~2 GB/s
    client_base_ms: float = 0.02
    client_ms_per_kb: float = 0.016      # gigabit NIC + kernel processing

    @property
    def hit_rate(self) -> float:
        # cache:disk ratio r => cache holds r/(1) of the data when r < 1
        # (uniform access => hit rate r); r >= 1 => everything fits.
        return min(1.0, self.cache_disk_ratio)


MEMCACHED = StorageConfig(
    mean_file_kb=0.1, cache_disk_ratio=2.0, mem_base_ms=0.18,
    mem_kb_per_ms=2000.0, client_base_ms=0.016, client_ms_per_kb=0.0)


def _sample_ms(cfg: StorageConfig, key: Array, shape: tuple[int, ...]) -> Array:
    k_size, k_hit, k_seek = jax.random.split(key, 3)
    if cfg.file_dist == "deterministic":
        size = jnp.full(shape, cfg.mean_file_kb)
    elif cfg.file_dist == "pareto":
        a = cfg.file_pareto_alpha
        xm = (a - 1.0) / a * cfg.mean_file_kb
        u = jax.random.uniform(k_size, shape, minval=jnp.finfo(jnp.float32).tiny)
        size = xm * u ** (-1.0 / a)
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown file_dist {cfg.file_dist}")
    hit = jax.random.uniform(k_hit, shape) < cfg.hit_rate
    # seek with mean seek_ms and CV seek_cv: Gamma(1/cv^2) * seek_ms*cv^2
    # (non-negative for ANY cv — the old shifted-exponential model went
    # below zero whenever cv > 1, e.g. fig9's seek_cv=1.5)
    if cfg.seek_cv == 0.0:
        seek = jnp.full(shape, cfg.seek_ms)
    else:
        a = 1.0 / cfg.seek_cv**2
        seek = jax.random.gamma(k_seek, a, shape) * (cfg.seek_ms / a)
    t_mem = cfg.mem_base_ms + size / cfg.mem_kb_per_ms
    t_disk = seek + size / cfg.disk_kb_per_ms
    return jnp.where(hit, t_mem, t_disk)


def mean_service_ms(cfg: StorageConfig) -> float:
    h = cfg.hit_rate
    t_mem = cfg.mem_base_ms + cfg.mean_file_kb / cfg.mem_kb_per_ms
    t_disk = cfg.seek_ms + cfg.mean_file_kb / cfg.disk_kb_per_ms
    return h * t_mem + (1.0 - h) * t_disk


def client_overhead_ms(cfg: StorageConfig) -> float:
    return cfg.client_base_ms + cfg.client_ms_per_kb * cfg.mean_file_kb


def service_dist(cfg: StorageConfig) -> tuple[ServiceDist, float, float]:
    """(unit-mean ServiceDist, ms_scale, normalized client overhead).

    Feed the ServiceDist + overhead into `queueing.SimConfig`; multiply
    simulated responses by ms_scale to get milliseconds.
    """
    scale = mean_service_ms(cfg)

    def sample(key: Array, shape: tuple[int, ...]) -> Array:
        return _sample_ms(cfg, key, shape) / scale

    name = (f"storage(file={cfg.mean_file_kb:g}KB,{cfg.file_dist},"
            f"cache={cfg.cache_disk_ratio:g})")
    dist = ServiceDist(name, sample)
    overhead = client_overhead_ms(cfg) / scale
    return dist, scale, overhead


def empirical_service_dist(cfg: StorageConfig, key: Array | None = None, *,
                           n_samples: int = 200_000,
                           n_quantiles: int = 512,
                           ) -> tuple[distributions.EmpiricalDist, float,
                                      float]:
    """Quantile-table twin of ``service_dist``: fit a unit-mean
    ``EmpiricalDist`` to ``_sample_ms`` draws so the storage system rides
    the engine's per-cell dist_id coordinate (and the fused kernel) like
    any other distribution.

    Returns ``(dist, ms_scale, normalized client overhead)`` where
    ``ms_scale == dist.scale`` is the fitted sample mean in ms.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    ms = _sample_ms(cfg, key, (int(n_samples),))
    name = (f"storage(file={cfg.mean_file_kb:g}KB,{cfg.file_dist},"
            f"cache={cfg.cache_disk_ratio:g})")
    dist = distributions.empirical(ms, n_quantiles=n_quantiles, name=name)
    return dist, dist.scale, client_overhead_ms(cfg) / dist.scale
