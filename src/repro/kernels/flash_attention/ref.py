"""Pure-jnp oracle for the flash attention kernel (causal GQA, optional
sliding window and logit softcap)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int | None = None,
                        softcap: float | None = None) -> jax.Array:
    """q (B, S, H, hd); k/v (B, S, KV, hd) -> (B, S, H, hd)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = j <= i
    if window is not None:
        mask &= j > i - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, s, h, hd)
