"""Jitted public wrapper: (B, S, H, hd) layout -> kernel's (B, H, S, hd)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd

_ON_TPU = None


def _interpret_default() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.devices()[0].platform == "tpu"
    return not _ON_TPU


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int | None = None, softcap: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q (B, S, H, hd); k/v (B, S, KV, hd) -> (B, S, H, hd).

    On non-TPU backends the kernel runs in interpret mode (CPU validation).
    """
    if interpret is None:
        interpret = _interpret_default()
    s = q.shape[1]
    bq = next(bb for bb in (block_q, 64, 32, 16, 8, 4, 2, 1) if s % bb == 0)
    bk = next(bb for bb in (block_kv, 64, 32, 16, 8, 4, 2, 1) if s % bb == 0)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, window=window, softcap=softcap,
                               block_q=bq, block_kv=bk, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
