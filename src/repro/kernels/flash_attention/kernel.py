"""Pallas TPU flash-attention kernel (causal GQA, sliding window, softcap).

TPU adaptation of FlashAttention: the grid is (batch, q_heads, q_blocks,
kv_blocks) with the kv dimension innermost — on TPU the innermost grid
dimension executes sequentially on a core, so the online-softmax
accumulators live in VMEM scratch and persist across kv steps. Block shapes
are (block_q, head_dim) / (block_kv, head_dim) tiles staged HBM->VMEM by
``pl.BlockSpec``; head_dim is the MXU lane dimension (128-aligned for every
assigned arch: hd in {64, 128, 256}).

Fully-masked (q_block, kv_block) pairs (above the causal diagonal or outside
the sliding window) are skipped with ``pl.when`` — no MXU work is issued for
them, which for long sequences halves the FLOPs vs dense attention (and for
window w << S makes the kernel O(S*w)).

GQA is expressed in the index_map: query head h reads kv head h * KV // H,
so no KV replication is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_q: int, block_kv: int, n_kv: int,
                 window: int | None, softcap: float | None, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_kv
    # causal: this kv block intersects rows only if k_start <= q_end;
    # window: only if the newest kv in block is within the window of the
    # oldest q row.
    needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = jnp.logical_and(
            needed, k_start + block_kv - 1 > q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos <= q_pos
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(3) - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_q", "block_kv", "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         window: int | None = None,
                         softcap: float | None = None, block_q: int = 128,
                         block_kv: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q (B, H, S, hd); k/v (B, KV, S, hd) -> (B, H, S, hd)."""
    b, h, s, hd = q.shape
    n_kv = k.shape[1]
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0, (s, block_q, block_kv)
    grid = (b, h, s // block_q, s // block_kv)

    kernel = functools.partial(
        _attn_kernel, scale=hd**-0.5, block_q=block_q, block_kv=block_kv,
        n_kv=n_kv, window=window, softcap=softcap, seq_len=s)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h_, iq, ik, n_kv=n_kv, h_tot=h:
                         (b_, h_ * n_kv // h_tot, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h_, iq, ik, n_kv=n_kv, h_tot=h:
                         (b_, h_ * n_kv // h_tot, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
