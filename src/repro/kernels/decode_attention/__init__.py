from repro.kernels.decode_attention import kernel, ops, ref  # noqa: F401
