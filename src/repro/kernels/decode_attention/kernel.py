"""Pallas TPU decode-attention (flash-decoding style) kernel.

One new token attends to a (possibly ring-buffered) KV cache. The grid is
(batch, kv_heads, kv_blocks) with kv_blocks innermost-sequential; the online
softmax state for the G = H/KV grouped query heads lives in VMEM scratch.
All G heads of a KV group are processed per instance as one (G, block_kv)
MXU matmul — for GQA decode this is what keeps the MXU busy (G x hd tiles)
while the KV cache streams HBM->VMEM once, which is the roofline-limiting
stream of decode.

Validity masking is slot-based (ring buffers): a slot participates iff its
recorded position is in [max(0, pos-window+1), pos].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _decode_kernel(pos_ref, slots_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float,
                   window: int | None, softcap: float | None):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0, 0]
    slots = slots_ref[0]                                # (bk,)
    q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.logical_and(slots >= 0, slots <= pos)
    if window is not None:
        valid = jnp.logical_and(valid, slots > pos - window)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_kv", "interpret"))
def decode_attention_grouped(q: jax.Array, k: jax.Array, v: jax.Array,
                             slot_pos: jax.Array, pos: jax.Array, *,
                             window: int | None = None,
                             softcap: float | None = None,
                             block_kv: int = 256,
                             interpret: bool = False) -> jax.Array:
    """q (B, KV, G, hd); k/v (B, KV, L, hd); slot_pos (1, L) -> like q."""
    b, n_kv, g, hd = q.shape
    length = k.shape[2]
    block_kv = min(block_kv, length)
    assert length % block_kv == 0, (length, block_kv)
    grid = (b, n_kv, length // block_kv)

    kernel = functools.partial(_decode_kernel, scale=hd**-0.5, window=window,
                               softcap=softcap)
    pos_arr = jnp.asarray(pos, dtype=jnp.int32).reshape(1, 1)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_, ik: (0, 0)),
            pl.BlockSpec((1, block_kv), lambda b_, h_, ik: (0, ik)),
            pl.BlockSpec((1, 1, g, hd), lambda b_, h_, ik: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h_, ik: (b_, h_, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h_, ik: (b_, h_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, h_, ik: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, slot_pos.reshape(1, -1), q, k, v)
