"""Pure-jnp oracle for the decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         slot_pos: jax.Array, pos: jax.Array, *,
                         window: int | None = None,
                         softcap: float | None = None) -> jax.Array:
    """q (B, 1, H, hd); k/v (B, L, KV, hd); slot_pos (L,) -> (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(q.dtype),
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= slot_pos > pos - window
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v)
    return out.reshape(b, 1, h, hd)
