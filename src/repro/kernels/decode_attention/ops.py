"""Jitted public wrapper for decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_grouped


def _interpret_default() -> bool:
    return jax.devices()[0].platform != "tpu"


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     slot_pos: jax.Array, pos: jax.Array, *,
                     window: int | None = None,
                     softcap: float | None = None,
                     block_kv: int = 256,
                     interpret: bool | None = None) -> jax.Array:
    """q (B, 1, H, hd); k/v (B, L, KV, hd); slot_pos (L,) -> (B, 1, H, hd)."""
    if interpret is None:
        interpret = _interpret_default()
    b, _, h, hd = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    length = k.shape[1]
    bk = next(bb for bb in (block_kv, 128, 64, 32, 16, 8, 4, 2, 1)
              if length % bb == 0)
    qg = q.reshape(b, n_kv, g, hd)
    kt = jnp.swapaxes(k, 1, 2)  # (B, KV, L, hd)
    vt = jnp.swapaxes(v, 1, 2)
    out = decode_attention_grouped(qg, kt, vt, slot_pos, pos, window=window,
                                   softcap=softcap, block_kv=bk,
                                   interpret=interpret)
    return out.reshape(b, 1, h, hd)
