"""Pallas TPU kernel: one chunk of the sweep engine's cell update, fused.

The scan-body reference (``ref.cell_update_ref``) round-trips the whole
per-cell carry — the (C, N) server free-time grid, the Kahan (sum,
comp) pair, and the (C, n_bins) histogram counts — through HBM-backed
scan state on EVERY arrival. This kernel keeps all of it in VMEM for a
whole chunk and touches HBM once per (cell, chunk):

  grid = (C, T // block_t)        cells outer, time-blocks inner
                                  (innermost axis is sequential on a
                                  TPU core, so VMEM scratch persists
                                  across a cell's time-blocks)

  VMEM carry per cell             free_s  (1, N)        f32
  (scratch, init at it == 0,      ssum_s / comp_s (1,1) f32
  flushed to HBM at the last      hist_s  (n_hi, 128)   f32
  time-block):                    (n_hi = n_bins / 128 — the
                                  hist_sketch accumulator layout)

  HBM traffic per (cell, chunk)   read + write of the carry blocks
                                  plus one pass over the seed-level
                                  inputs — vs O(T) carry round-trips
                                  in the scan body.

Per-cell plan coordinates ride as SCALAR-PREFETCH operands (seed_idx,
k_count, policy_code, model_code, rates, overhead, mix, and the PR-7
degradation / timed-policy parameters p_slow, slow_factor, p_fail,
delay — see ``repro.core.cellplan``): the seed coordinate drives the
input
BlockSpec index maps, so each cell's grid row streams exactly its
seed's (block_t,) slice of the sampled inputs and the (C, T)
expansion is never materialized — the same "gather by coordinate, not
by position" rule that makes sharded execution bit-identical.

Bit-identity with the scan body (the contract the parity tests pin):

  * The step body mirrors ``ref.step_cell`` op-for-op; all float ops
    are elementwise or min/max over the tiny copy axis, so the
    (1, k)-shaped retiling cannot change bits.
  * The free-time gather is a one-hot ``max(where(...))`` — an exact
    PICK of an element, no arithmetic on it.
  * The occupancy scatter is a Python-unrolled sequence of selects in
    copy order, matching XLA's last-wins ``.at[srv].set`` semantics
    (srv entries are distinct by construction, so order only matters
    for the masked no-op copies that rewrite their own old value).
  * The Kahan fold is ``ref.kahan_fold`` — literally the same
    function — gated so zero-weight (padding / pre-warmup) steps are
    bitwise no-ops.
  * Histogram counts are 0/1 indicator-matmul accumulations of
    integers in f32 (exact below 2**24 per bin), so any accumulation
    order gives identical bits; the bin indices come from the same
    ``hist_sketch.ops.bin_indices``.

The CRN / fold_in contract is untouched: sampling stays host-side and
seed-level (see ``queueing.py``); the kernel only changes WHERE the
deterministic update runs. That includes the degradation model's CRN
contract (``ref.step_cell``'s design note): the per-copy failure /
straggler uniforms arrive as extra ``services`` columns drawn from the
dedicated ``_DEGRADE_FOLD`` branch, the kernel never samples, and a
healthy grid carries no such columns — so healthy cells keep their
pre-degradation bits through this kernel exactly as through the scan. Off-TPU the kernel runs in Pallas interpret
mode, which executes the same jnp ops through XLA CPU — that is what
keeps kernel-mode CI runs bit-exact against the scan body rather than
"close".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.scenario import Policy, ServiceModel
from repro.kernels.cell_update.ref import kahan_fold, retry_offsets
from repro.kernels.hist_sketch import ops as hist_ops
from repro.kernels.hist_sketch.kernel import LANE


def _cell_kernel(seed_ref, kcnt_ref, pol_ref, mdl_ref, rate_ref, ovh_ref,
                 mix_ref, psl_ref, sfa_ref, pfl_ref, dly_ref,
                 free_in, ssum_in, comp_in, cnt_in, *rest, n_servers: int,
                 k_max: int, n_svc: int, block_t: int, n_hi: int,
                 need_hist: bool, has_shared: bool):
    if need_hist:
        (hist_in, cum_ref, warm_ref, valid_ref, srv_ref, svc_ref,
         free_out, ssum_out, comp_out, cnt_out, hist_out,
         free_s, ssum_s, comp_s, cnt_s, hist_s) = rest
    else:
        (cum_ref, warm_ref, valid_ref, srv_ref, svc_ref,
         free_out, ssum_out, comp_out, cnt_out,
         free_s, ssum_s, comp_s, cnt_s) = rest
    ic = pl.program_id(0)
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        free_s[...] = free_in[...]
        ssum_s[...] = ssum_in[...]
        comp_s[...] = comp_in[...]
        cnt_s[...] = cnt_in[...]
        if need_hist:
            hist_s[...] = hist_in[0]

    # this cell's plan coordinates (scalar prefetch)
    rate = rate_ref[ic]
    ovh = ovh_ref[ic]
    mix = mix_ref[ic]
    kcnt = kcnt_ref[ic]
    psl = psl_ref[ic]
    sfa = sfa_ref[ic]
    pfl = pfl_ref[ic]
    dly = dly_ref[ic]
    is_sd = mdl_ref[ic] == int(ServiceModel.SERVER_DEPENDENT)
    is_cancel = pol_ref[ic] == int(Policy.CANCEL_ON_COMPLETE)
    is_idle = pol_ref[ic] == int(Policy.REPLICATE_TO_IDLE)
    is_retry = pol_ref[ic] == int(Policy.TIMEOUT_RETRY)
    is_timed = is_retry | (pol_ref[ic] == int(Policy.HEDGE_AFTER_DELAY))

    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k_max), 1)
    mask = iota_k < kcnt            # k_mask rows are prefixes by plan
    primary = iota_k == 0
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (k_max, n_servers), 1)
    # timed-policy dispatch-time coefficients (see ref.step_cell).
    # Pallas kernels cannot capture non-scalar constants, so the backoff
    # offsets are assembled from scalar selects — exact small floats,
    # same values as the ref's literal array.
    retry_coeff = jnp.zeros((1, k_max), jnp.float32)
    for j, off in enumerate(retry_offsets(k_max)):
        retry_coeff = jnp.where(iota_k == j, off, retry_coeff)
    coeff = jnp.where(is_retry, retry_coeff, iota_k.astype(jnp.float32))
    # TIMEOUT_RETRY's LAST in-budget attempt ignores its blackhole draw
    last_attempt = is_retry & (iota_k == kcnt - 1)
    n_base = k_max + (1 if has_shared else 0)
    has_degr = n_svc > n_base

    cum_blk = cum_ref[0]            # (block_t,) this seed's time block
    warm_blk = warm_ref[0]          # (block_t,)
    valid_blk = valid_ref[0]        # (block_t,)
    srv_blk = srv_ref[0]            # (block_t, k_max)
    svc_blk = svc_ref[0]            # (block_t, n_svc)

    def step(s, carry):
        if need_hist:
            free, ssum, comp, cnt, resp_blk, wl_blk = carry
        else:
            free, ssum, comp, cnt = carry
        t = cum_blk[s] / rate
        srv = jax.lax.dynamic_slice(srv_blk, (s, 0), (1, k_max))
        svc_row = jax.lax.dynamic_slice(svc_blk, (s, 0), (1, n_svc))
        shared = svc_row[0, k_max] if has_shared else svc_row[0, 0]
        degr = (svc_row[:, n_base:n_base + k_max] if has_degr
                else jnp.zeros((1, k_max), jnp.float32))
        svc = svc_row[:, :k_max]
        w = warm_blk[s]
        # padding steps zero the effective delay (see ref.step_cell)
        dly_eff = jnp.where(valid_blk[s] > 0, dly, 0.0)
        # exact gather: one-hot pick of free[srv] (no arithmetic on it)
        oh = srv[0, :, None] == iota_n                      # (k, N)
        cur = jnp.max(jnp.where(oh, free, -jnp.inf), axis=1)[None, :]
        # step_cell, op-for-op on (1, k) lanes
        svc = jnp.where(is_sd, mix * shared + (1.0 - mix) * svc, svc)
        svc = jnp.where(degr >= 1.0 - psl, svc * sfa, svc)
        alive = degr >= pfl
        start = jnp.maximum(cur, t)
        finish = start + svc
        t_win = jnp.min(jnp.where(mask & alive, finish, jnp.inf))
        dispatch = mask & (primary | (cur <= t))
        val_all = jnp.where(mask & alive, finish, cur)
        val_cancel = jnp.where(mask & alive, jnp.maximum(cur, t_win), cur)
        val_idle = jnp.where(dispatch & alive, finish, cur)
        # timed policies: sequential dispatch, unrolled in copy order
        # with scalar extracts (mirrors ref.step_cell's Python loop)
        disp_t = t + dly_eff * coeff
        alive_eff = alive | last_attempt
        fired_finish = jnp.maximum(cur, disp_t) + svc
        fire_all = dly_eff <= 0.0
        best = jnp.inf
        made = jnp.zeros((1, k_max), bool)
        for j in range(k_max):
            made_j = mask[0, j] if j == 0 else (
                mask[0, j] & (fire_all | (best > disp_t[0, j])))
            best = jnp.minimum(
                best, jnp.where(made_j & alive_eff[0, j],
                                fired_finish[0, j], jnp.inf))
            made = made | ((iota_k == j) & made_j)
        val_timed = jnp.where(made & alive_eff, fired_finish, cur)
        new_val = jnp.where(
            is_cancel, val_cancel,
            jnp.where(is_idle, val_idle,
                      jnp.where(is_timed, val_timed, val_all)))
        # scatter: unrolled selects in copy order == XLA's last-wins
        # .at[srv].set (srv entries distinct; masked copies rewrite
        # their own old value either way)
        for j in range(k_max):
            free = jnp.where(oh[j][None, :], new_val[0, j], free)
        resp_win = t_win - t + ovh
        resp_idle = (jnp.min(jnp.where(dispatch & alive, finish, jnp.inf))
                     - t + ovh)
        resp_timed = best - t + ovh
        resp = jnp.where(is_idle, resp_idle,
                         jnp.where(is_timed, resp_timed, resp_win))
        w_live = w * jnp.isfinite(resp).astype(jnp.float32)
        ssum, comp = kahan_fold(ssum, comp, resp, w_live)
        cnt = cnt + w_live
        if need_hist:
            resp_blk = jax.lax.dynamic_update_slice(
                resp_blk, resp.reshape(1, 1), (s, 0))
            wl_blk = jax.lax.dynamic_update_slice(
                wl_blk, w_live.reshape(1, 1), (s, 0))
            return free, ssum, comp, cnt, resp_blk, wl_blk
        return free, ssum, comp, cnt

    carry = (free_s[...], ssum_s[0, 0], comp_s[0, 0], cnt_s[0, 0])
    if need_hist:
        carry += (jnp.zeros((block_t, 1), jnp.float32),
                  jnp.zeros((block_t, 1), jnp.float32))
    carry = jax.lax.fori_loop(0, block_t, step, carry)
    free_s[...] = carry[0]
    ssum_s[0, 0] = carry[1]
    comp_s[0, 0] = carry[2]
    cnt_s[0, 0] = carry[3]
    if need_hist:
        # hist_sketch accumulation (see that kernel's design note):
        # idx == -1 (padding / pre-warmup / incomplete) matches no
        # indicator row — the completed weight, not the raw warmup
        # weight, gates the bins (same as the ref's w_live)
        idx = hist_ops.bin_indices(carry[4], carry[5],
                                   n_bins=n_hi * LANE)       # (block_t, 1)
        hi = idx // LANE
        lo = idx - hi * LANE
        a = (hi == jax.lax.broadcasted_iota(
            jnp.int32, (block_t, n_hi), 1)).astype(jnp.float32)
        b = (lo == jax.lax.broadcasted_iota(
            jnp.int32, (block_t, LANE), 1)).astype(jnp.float32)
        hist_s[...] += jax.lax.dot_general(
            a, b, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(it == pl.num_programs(1) - 1)
    def _flush():
        free_out[...] = free_s[...]
        ssum_out[...] = ssum_s[...]
        comp_out[...] = comp_s[...]
        cnt_out[...] = cnt_s[...]
        if need_hist:
            hist_out[0] = hist_s[...]


@functools.partial(jax.jit, static_argnames=("n_servers", "n_bins",
                                             "block_t", "interpret",
                                             "has_shared", "has_dists"))
def cell_update_tc(free: jax.Array, ssum: jax.Array, comp: jax.Array,
                   cnt: jax.Array, hist: jax.Array, cum: jax.Array,
                   warm: jax.Array, valid: jax.Array,
                   servers: jax.Array, services: jax.Array,
                   seed_idx: jax.Array, k_count: jax.Array,
                   policy: jax.Array, model: jax.Array, rates: jax.Array,
                   ovh: jax.Array, mix: jax.Array, p_slow: jax.Array,
                   slow_factor: jax.Array, p_fail: jax.Array,
                   delay: jax.Array, svc_idx: jax.Array = None, *,
                   n_servers: int,
                   n_bins: int, block_t: int, interpret: bool = False,
                   has_shared: bool = False, has_dists: bool = False):
    """One chunk of the fused cell update. Carry free (C,N) / ssum, comp,
    cnt (C,) / hist (C, n_bins) (shape (0,0) skips the sketch); inputs
    cum (S,T) cumulative offsets, warm (T,) 0/1 post-warmup weights,
    valid (T,) 0/1 real-step flags, servers (S,T,k_max), services
    (S,T,n_svc) laid out ``[copies][shared if has_shared][degradation
    uniforms if present]``; per-cell scalar-prefetch coordinates (C,)
    each (the degradation / timed-policy parameters ride the same
    prefetch path as the policy codes). Requires ``T % block_t == 0``
    and (with the sketch) ``n_bins % 128 == 0`` — ``ops.cell_update``
    pads/validates. Returns the updated carry, free NOT yet rebased
    (the caller rebases, same as the ref).

    ``has_dists`` (static) is the heterogeneous-grid path: ``services``
    stacks one (n_seeds, T, n_svc) table per dist-union member along
    axis 0 and ``svc_idx`` (C,) joins the scalar-prefetch operands SOLELY
    to drive the services BlockSpec index map — the kernel BODY never
    reads it (exactly like ``seed_idx``), each cell's grid row simply
    streams its system's service slice. ``has_dists=False`` keeps the
    11-operand prefetch layout, so homogeneous grids compile the exact
    pre-dist_id program.
    """
    c_cells = free.shape[0]
    t_total = cum.shape[1]
    k_max = servers.shape[-1]
    n_svc = services.shape[-1]
    need_hist = hist.size > 0
    assert t_total % block_t == 0, (t_total, block_t)
    n_tb = t_total // block_t
    n_hi = (n_bins // LANE) if need_hist else 0

    kernel = functools.partial(
        _cell_kernel, n_servers=n_servers, k_max=k_max, n_svc=n_svc,
        block_t=block_t, n_hi=n_hi, need_hist=need_hist,
        has_shared=has_shared)
    if has_dists:
        # svc_idx is prefetch operand 1, for the services index map
        # only; the body is the homogeneous kernel unchanged.
        base_kernel = kernel

        def kernel(seed_ref, svcid_ref, *rest):
            return base_kernel(seed_ref, *rest)

        def svc_time(ic, it, seed, svcid, *_):
            return (svcid[ic], it, 0)
    else:
        def svc_time(ic, it, seed, *_):
            return (seed[ic], it, 0)

    def cell_row(ic, it, *_):
        return (ic, 0)

    def seed_time(ic, it, seed, *_):
        return (seed[ic], it)

    in_specs = [
        pl.BlockSpec((1, n_servers), cell_row),                  # free
        pl.BlockSpec((1, 1), cell_row),                          # ssum
        pl.BlockSpec((1, 1), cell_row),                          # comp
        pl.BlockSpec((1, 1), cell_row),                          # cnt
    ]
    if need_hist:
        in_specs.append(
            pl.BlockSpec((1, n_hi, LANE), lambda ic, it, *_: (ic, 0, 0)))
    in_specs += [
        pl.BlockSpec((1, block_t), seed_time),                   # cum
        pl.BlockSpec((1, block_t), lambda ic, it, *_: (0, it)),  # warm
        pl.BlockSpec((1, block_t), lambda ic, it, *_: (0, it)),  # valid
        pl.BlockSpec((1, block_t, k_max),
                     lambda ic, it, seed, *_: (seed[ic], it, 0)),
        pl.BlockSpec((1, block_t, n_svc), svc_time),
    ]
    out_specs = [
        pl.BlockSpec((1, n_servers), cell_row),
        pl.BlockSpec((1, 1), cell_row),
        pl.BlockSpec((1, 1), cell_row),
        pl.BlockSpec((1, 1), cell_row),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((c_cells, n_servers), jnp.float32),
        jax.ShapeDtypeStruct((c_cells, 1), jnp.float32),
        jax.ShapeDtypeStruct((c_cells, 1), jnp.float32),
        jax.ShapeDtypeStruct((c_cells, 1), jnp.float32),
    ]
    scratch = [pltpu.VMEM((1, n_servers), jnp.float32),
               pltpu.VMEM((1, 1), jnp.float32),
               pltpu.VMEM((1, 1), jnp.float32),
               pltpu.VMEM((1, 1), jnp.float32)]
    if need_hist:
        out_specs.append(
            pl.BlockSpec((1, n_hi, LANE), lambda ic, it, *_: (ic, 0, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((c_cells, n_hi, LANE), jnp.float32))
        scratch.append(pltpu.VMEM((n_hi, LANE), jnp.float32))

    operands = [free, ssum.reshape(c_cells, 1), comp.reshape(c_cells, 1),
                cnt.reshape(c_cells, 1)]
    if need_hist:
        operands.append(hist.reshape(c_cells, n_hi, LANE))
    operands += [cum, warm.reshape(1, t_total), valid.reshape(1, t_total),
                 servers, services]

    prefetch = [seed_idx]
    if has_dists:
        prefetch.append(svc_idx)
    prefetch += [k_count, policy, model, rates, ovh, mix, p_slow,
                 slow_factor, p_fail, delay]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(c_cells, n_tb),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch)
    out = pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                         interpret=interpret)(*prefetch, *operands)
    free_o, ssum_o, comp_o, cnt_o = (out[0], out[1][:, 0], out[2][:, 0],
                                     out[3][:, 0])
    hist_o = out[4].reshape(c_cells, n_hi * LANE) if need_hist else hist
    return free_o, ssum_o, comp_o, cnt_o, hist_o
