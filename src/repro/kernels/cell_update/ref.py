"""Reference implementation of the fused cell update.

``step_cell`` is THE single-arrival physics of the replication DES —
free-time gather, policy/model selects, occupancy scatter, response
min — shared by every execution path (``queueing.simulate*``, the
sweep engine's scan body below, and the Pallas kernel, which mirrors
it op-for-op). ``cell_update_ref`` is the ``lax.scan`` chunk body the
kernel must match BIT FOR BIT; it is also the dispatch fallback
(``use_kernel="off"``), so CPU/CI runs and TPU kernel runs are anchored
to the same bits.

Bit-exactness ground rules shared with ``kernel.py``:

  * Every floating-point op sequence here is elementwise or a
    min/max reduction over the tiny copy axis — no order-sensitive
    float reductions — so the kernel can re-tile shapes freely without
    changing bits.
  * The Kahan update is GATED on the warmup weight via selects: a
    zero-weight step leaves (ssum, comp) bitwise untouched (not just
    algebraically — the ungated update would fold the compensation
    term into the sum). That makes the summaries invariant to trailing
    zero-weight padding, which the kernel path relies on (it always
    pads chunks to a block multiple) and which keeps padded and
    unpadded layouts bit-identical.
  * ``optimization_barrier`` hides the compensated sum from XLA's
    algebraic simplifier exactly as in the pre-kernel engine (see the
    inline comment).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.scenario import Policy, ServiceModel
from repro.kernels.hist_sketch import ops as hist_ops

Array = jax.Array

# TIMEOUT_RETRY exponential backoff: attempt j dispatches at
# t + delay * sum_{i<j} min(2**i, _BACKOFF_CAP). The cap bounds the
# inter-attempt wait at 8 deadlines (offsets 0, 1, 3, 7, 15, 23, ...).
_BACKOFF_CAP = 8.0


def retry_offsets(k_max: int) -> list[float]:
    """Static backoff-offset coefficients per attempt (exact small
    floats, shared by the scan body, the Pallas kernel and
    ``analytic.retry_mean_light`` so all three agree bit-for-bit)."""
    c, out = 0.0, []
    for j in range(k_max):
        out.append(c)
        c += min(2.0 ** j, _BACKOFF_CAP)
    return out


def step_cell(free: Array, t: Array, srv: Array, svc: Array,
              svc_shared: Array, degr_u: Array, mask: Array, overhead: Array,
              policy: Array, model: Array, mix: Array, p_slow: Array,
              slow_factor: Array, p_fail: Array, delay: Array,
              valid: Array = True, *,
              has_timed: bool = False) -> tuple[Array, Array]:
    """One arrival at one (seed, load, variant) grid cell. free (N,), t /
    svc_shared / overhead / policy / model / mix / p_slow / slow_factor /
    p_fail / delay / valid scalars, srv/svc/degr_u/mask (k_max,) ->
    (new free, response). ``valid`` is False only on chunk-padding
    steps: it zeroes the effective delay there, forcing the timed
    policies' ``fire_all`` arm. A deferred dispatch at
    ``t + delay * coeff`` could otherwise let a zero-service padding
    step bump a server's free time past the chunk-end arrival time,
    where a next-chunk arrival WOULD observe it — every other policy's
    padding write is bounded by ``max(cur, t_chunk_end)``, which later
    arrivals cannot see, and with ``delay = 0`` the timed write is too.
    On real steps ``jnp.where(True, delay, 0)`` is bitwise ``delay``.

    ``policy`` / ``model`` are the cell's ``scenario.Policy`` /
    ``scenario.ServiceModel`` codes; every variant's update is computed
    and the codes select one (mixed grids share this single trace). The
    ``Policy.REPLICATE_ALL`` + ``ServiceModel.IID`` path is the paper's
    model, op-for-op identical to the pre-scenario engine (the bit-
    identity anchor of ``Scenario.paper_default``).

    Degradation-model CRN design note (the PR-7 contract): ``degr_u``
    is one uniform per copy drawn from a DEDICATED ``fold_in`` index
    (``queueing._DEGRADE_FOLD``), sampled only when a grid contains a
    degraded variant — the service/arrival key streams are untouched,
    so healthy cells keep their pre-degradation bits exactly. One draw
    drives both events on disjoint intervals (``u < p_fail`` blackhole,
    ``u >= 1 - p_slow`` straggler; healthy cells pass zeros, making
    both selects inert). A blackholed copy is lost in transit: it never
    occupies its server (its free-time entry keeps the old value, like
    a masked copy) and never responds; a request with no surviving copy
    yields ``resp = inf``, which the caller excludes from the mean /
    histogram and from the per-cell completed count.

    Timed policies (``TIMEOUT_RETRY`` / ``HEDGE_AFTER_DELAY``) share a
    sequential dispatch loop over the copy budget: copy ``j`` fires at
    ``t + delay * coeff_j`` (backoff offsets for retry, ``j * delay``
    for hedging) ONLY if no earlier surviving copy has finished by its
    dispatch time. ``delay <= 0`` forces every copy to fire — which is
    what makes ``HEDGE_AFTER_DELAY(delay=0)`` bit-identical to
    ``REPLICATE_ALL`` (same dispatch set, same ``max(cur, t) + svc``
    finishes, and min-folds are exact so the sequential best equals the
    reduction ``t_win`` bit-for-bit). TIMEOUT_RETRY's LAST in-budget
    attempt ignores its blackhole draw (out-of-band escalation), so
    retry cells always complete.

    ``has_timed`` is STATIC: the timed-policy block (and its extra
    select in the policy chains) is compiled only when the grid
    actually contains a TIMED_POLICIES variant. This is a bit-identity
    requirement, not an optimisation — merely having the extra select
    live in the traced graph shifts XLA's fusion choices around the
    free-time scatter, which was observed to move a saturated cell's
    sample path by 1 ULP. Gating it out keeps every non-timed grid on
    the exact pre-timed compiled program; timed grids are verified
    scan-vs-kernel bit-identical separately (tests/test_faults.py).
    """
    k_max = srv.shape[0]
    iota = jnp.arange(k_max)
    cur = free[srv]
    # SERVER_DEPENDENT (Shah et al.): blend the shared request component
    # into every copy. mix=0 (and the IID select arm) is bit-exact svc.
    svc = jnp.where(model == int(ServiceModel.SERVER_DEPENDENT),
                    mix * svc_shared + (1.0 - mix) * svc, svc)
    # Degradation: straggler inflation on the served time, blackhole
    # aliveness. Healthy cells (p_slow = p_fail = 0, degr_u = 0) keep
    # svc and alive = True through both selects — bitwise inert.
    svc = jnp.where(degr_u >= 1.0 - p_slow, svc * slow_factor, svc)
    alive = degr_u >= p_fail
    start = jnp.maximum(cur, t)
    finish = start + svc
    t_win = jnp.min(jnp.where(mask & alive, finish, jnp.inf))
    # REPLICATE_TO_IDLE dispatches the primary always, extras only to
    # servers idle at the arrival instant.
    dispatch = mask & ((iota == 0) | (cur <= t))
    # Per-policy server-occupancy updates (masked copies rewrite their own
    # old value — a no-op; srv entries are distinct by construction):
    #   REPLICATE_ALL      every surviving copy runs to completion.
    #   CANCEL_ON_COMPLETE losers vacate at the winner's finish: a loser
    #                      in service frees at t_win, a queued loser
    #                      (cur >= t_win) never starts — max(cur, t_win)
    #                      covers both (and equals finish for the winner).
    #                      t_win = inf only when NO copy survives, and
    #                      then no copy selects it.
    #   REPLICATE_TO_IDLE  only dispatched surviving copies occupy.
    #   TIMED (retry/hedge) only fired surviving copies occupy.
    val_all = jnp.where(mask & alive, finish, cur)
    val_cancel = jnp.where(mask & alive, jnp.maximum(cur, t_win), cur)
    val_idle = jnp.where(dispatch & alive, finish, cur)
    if has_timed:
        # Timed policies: sequential dispatch over the copy budget.
        delay = jnp.where(valid, delay, 0.0)  # padding: see docstring
        is_retry = policy == int(Policy.TIMEOUT_RETRY)
        is_timed = is_retry | (policy == int(Policy.HEDGE_AFTER_DELAY))
        kc = jnp.sum(mask)  # prefix mask -> attempt budget
        coeff = jnp.where(is_retry,
                          jnp.asarray(retry_offsets(k_max), jnp.float32),
                          iota.astype(jnp.float32))
        disp_t = t + delay * coeff
        alive_eff = alive | (is_retry & (iota == kc - 1))
        fired_finish = jnp.maximum(cur, disp_t) + svc
        fire_all = delay <= 0.0
        best = jnp.asarray(jnp.inf, fired_finish.dtype)
        made_cols = []
        for j in range(k_max):
            made_j = mask[j] if j == 0 else (
                mask[j] & (fire_all | (best > disp_t[j])))
            best = jnp.minimum(best, jnp.where(made_j & alive_eff[j],
                                               fired_finish[j], jnp.inf))
            made_cols.append(made_j)
        made = jnp.stack(made_cols)
        val_timed = jnp.where(made & alive_eff, fired_finish, cur)
        base_val = jnp.where(is_timed, val_timed, val_all)
    else:
        base_val = val_all
    new_val = jnp.where(
        policy == int(Policy.CANCEL_ON_COMPLETE), val_cancel,
        jnp.where(policy == int(Policy.REPLICATE_TO_IDLE), val_idle,
                  base_val))
    free = free.at[srv].set(new_val)
    resp_win = t_win - t + overhead
    resp_idle = (jnp.min(jnp.where(dispatch & alive, finish, jnp.inf))
                 - t + overhead)
    if has_timed:
        base_resp = jnp.where(is_timed, best - t + overhead, resp_win)
    else:
        base_resp = resp_win
    resp = jnp.where(policy == int(Policy.REPLICATE_TO_IDLE), resp_idle,
                     base_resp)
    return free, resp


def kahan_fold(ssum: Array, comp: Array, resp: Array,
               w: Array) -> tuple[Array, Array]:
    """One gated Kahan step, shared verbatim by the scan body and the
    Pallas kernel (same ops => same bits in both).

    Kahan-compensated sum: sequential f32 accumulation over ~1e5+
    terms would otherwise cost ~1e-4 relative error on the mean,
    which is the signal threshold bisection keys on. Three guards
    keep the update's rounding EXACTLY the same in every compilation
    (the sharded-vs-unsharded and kernel-vs-scan bit-identity
    contracts):

      * the 0/1 warmup weight gates the WHOLE update via selects (a
        ``resp * w - comp`` multiply-subtract invites FMA
        contraction, and an ungated ``y = 0 - comp`` step would fold
        the compensation into the sum — making the bits depend on
        how much zero-weight padding trails the chunk);
      * an ``optimization_barrier`` hides ``tot`` from XLA's
        algebraic simplifier, which would otherwise rewrite
        ``(tot - ssum) - y`` — compensation terms it sees as
        algebraically zero — depending on the surrounding fusion
        context.
    """
    y = resp - comp
    tot = ssum + y
    tot_b, y_b = jax.lax.optimization_barrier((tot, y))
    comp_new = (tot_b - ssum) - y_b
    live = w > 0
    return jnp.where(live, tot_b, ssum), jnp.where(live, comp_new, comp)


def cell_update_ref(free: Array, ssum: Array, comp: Array, cnt: Array,
                    hist: Array, cum: Array, warm: Array, valid: Array,
                    servers: Array, services: Array, seed_idx: Array,
                    rates: Array, k_mask: Array, ovh: Array,
                    policy_code: Array, model_code: Array, mix: Array,
                    p_slow: Array, slow_factor: Array, p_fail: Array,
                    delay: Array, svc_idx: Array = None, *,
                    n_servers: int | None = None,
                    n_bins: int, block: int, has_shared: bool = False,
                    has_timed: bool = False, has_dists: bool = False
                    ) -> tuple[Array, Array, Array, Array, Array]:
    """Scan-body reference for one chunk on the flat cell axis.

    ``cum`` (S,T) are cumulative arrival offsets from the chunk start
    (already masked for padding), ``warm`` (T,) the 0/1 post-warmup
    weights, ``valid`` (T,) the 0/1 real-step flags (0 only on padding
    steps — distinct from ``warm``, which is also 0 on real pre-warmup
    arrivals; see ``step_cell`` on why timed policies need it),
    ``servers`` (S,T,k_max) / ``services`` (S,T,n_svc) the
    sampled inputs (padding steps zeroed); the remaining args are the
    per-cell carry and plan parameters of
    ``queueing._sweep_chunk_cells``, which documents them. The
    ``services`` column layout is ``[k_max per-copy draws][shared
    component if has_shared][k_max degradation uniforms if present]`` —
    ``has_shared`` is a static flag (the column count alone is
    ambiguous at k_max=1) and the degradation columns' presence is
    derived from what remains. ``cnt`` accumulates the per-cell count
    of COMPLETED post-warmup responses: incomplete requests (every
    dispatched copy blackholed -> ``resp = inf``) are excluded from the
    Kahan mean, the histogram, and the count by zeroing their warmup
    weight — for healthy cells the weight is untouched (``w * 1.0``) so
    summaries keep their pre-degradation bits. Returns the updated
    carry with ``free`` NOT yet rebased (the caller rebases).
    ``n_servers`` is accepted (dispatch-signature parity with
    ``ops.cell_update``) but implied by ``free``. ``has_shared`` /
    ``has_timed`` are the static layout / compiled-program flags from
    the variant list (see ``step_cell`` on why ``has_timed`` gates the
    timed block at trace time).

    ``has_dists`` (static) routes the per-step SERVICE gather through
    ``svc_idx`` (C,) instead of ``seed_idx`` — heterogeneous grids stack
    one service table per dist-union member along the seed axis and
    ``svc_idx = dist_id * n_seeds + seed_idx`` picks each cell's table
    row; arrivals/servers/time stay ``seed_idx``-keyed (CRN across
    systems). ``has_dists=False`` never touches ``svc_idx``, keeping the
    homogeneous trace unchanged.
    """
    del n_servers
    k_max = k_mask.shape[1]
    n_base = k_max + (1 if has_shared else 0)
    has_degr = services.shape[-1] > n_base
    need_hist = hist.size > 0
    T = cum.shape[1]
    if need_hist:
        assert T % block == 0, (T, block)

    cell_c = jax.vmap(partial(step_cell, has_timed=has_timed),
                      in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
                               0, 0, 0, 0, 0, None))

    def step(carry, inp):
        free, ssum, comp, cnt = carry
        c, w, v, srv, svc = inp                # (S,), (), (), (S,k), (S,n_svc)
        t = c[seed_idx] / rates                       # (C,)
        svc_c = svc[svc_idx if has_dists else seed_idx]  # (C, n_svc)
        shared_c = svc_c[:, k_max] if has_shared else svc_c[:, 0]
        degr_c = (svc_c[:, n_base:n_base + k_max] if has_degr
                  else jnp.zeros_like(svc_c[:, :k_max]))
        free, resp = cell_c(free, t, srv[seed_idx], svc_c[:, :k_max],
                            shared_c, degr_c, k_mask, ovh, policy_code,
                            model_code, mix, p_slow, slow_factor, p_fail,
                            delay, v > 0)
        w_live = w * jnp.isfinite(resp).astype(jnp.float32)   # (C,)
        ssum, comp = kahan_fold(ssum, comp, resp, w_live)
        cnt = cnt + w_live
        return (free, ssum, comp, cnt), ((resp, w_live) if need_hist
                                         else None)

    xs = (cum.T, warm, valid, jnp.moveaxis(servers, 1, 0),
          jnp.moveaxis(services, 1, 0))
    if need_hist:
        xs = jax.tree.map(
            lambda x: x.reshape((T // block, block) + x.shape[1:]), xs)

        def outer(carry, xs_blk):
            free, ssum, comp, cnt, hist = carry
            (free, ssum, comp, cnt), (resp, w_live) = jax.lax.scan(
                step, (free, ssum, comp, cnt), xs_blk)
            idx = hist_ops.bin_indices(resp, w_live, n_bins=n_bins)
            hist = hist + hist_ops.hist_accum(idx, n_bins=n_bins,
                                              block_t=block)
            return (free, ssum, comp, cnt, hist), None

        (free, ssum, comp, cnt, hist), _ = jax.lax.scan(
            outer, (free, ssum, comp, cnt, hist), xs)
    else:
        (free, ssum, comp, cnt), _ = jax.lax.scan(
            step, (free, ssum, comp, cnt), xs)
    return free, ssum, comp, cnt, hist
