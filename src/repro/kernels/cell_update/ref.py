"""Reference implementation of the fused cell update.

``step_cell`` is THE single-arrival physics of the replication DES —
free-time gather, policy/model selects, occupancy scatter, response
min — shared by every execution path (``queueing.simulate*``, the
sweep engine's scan body below, and the Pallas kernel, which mirrors
it op-for-op). ``cell_update_ref`` is the ``lax.scan`` chunk body the
kernel must match BIT FOR BIT; it is also the dispatch fallback
(``use_kernel="off"``), so CPU/CI runs and TPU kernel runs are anchored
to the same bits.

Bit-exactness ground rules shared with ``kernel.py``:

  * Every floating-point op sequence here is elementwise or a
    min/max reduction over the tiny copy axis — no order-sensitive
    float reductions — so the kernel can re-tile shapes freely without
    changing bits.
  * The Kahan update is GATED on the warmup weight via selects: a
    zero-weight step leaves (ssum, comp) bitwise untouched (not just
    algebraically — the ungated update would fold the compensation
    term into the sum). That makes the summaries invariant to trailing
    zero-weight padding, which the kernel path relies on (it always
    pads chunks to a block multiple) and which keeps padded and
    unpadded layouts bit-identical.
  * ``optimization_barrier`` hides the compensated sum from XLA's
    algebraic simplifier exactly as in the pre-kernel engine (see the
    inline comment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scenario import Policy, ServiceModel
from repro.kernels.hist_sketch import ops as hist_ops

Array = jax.Array


def step_cell(free: Array, t: Array, srv: Array, svc: Array,
              svc_shared: Array, mask: Array, overhead: Array,
              policy: Array, model: Array, mix: Array) -> tuple[Array, Array]:
    """One arrival at one (seed, load, variant) grid cell. free (N,), t /
    svc_shared / overhead / policy / model / mix scalars, srv/svc/mask
    (k_max,) -> (new free, response).

    ``policy`` / ``model`` are the cell's ``scenario.Policy`` /
    ``scenario.ServiceModel`` codes; every variant's update is computed
    and the codes select one (mixed grids share this single trace). The
    ``Policy.REPLICATE_ALL`` + ``ServiceModel.IID`` path is the paper's
    model, op-for-op identical to the pre-scenario engine (the bit-
    identity anchor of ``Scenario.paper_default``).
    """
    cur = free[srv]
    # SERVER_DEPENDENT (Shah et al.): blend the shared request component
    # into every copy. mix=0 (and the IID select arm) is bit-exact svc.
    svc = jnp.where(model == int(ServiceModel.SERVER_DEPENDENT),
                    mix * svc_shared + (1.0 - mix) * svc, svc)
    start = jnp.maximum(cur, t)
    finish = start + svc
    t_win = jnp.min(jnp.where(mask, finish, jnp.inf))
    # REPLICATE_TO_IDLE dispatches the primary always, extras only to
    # servers idle at the arrival instant.
    dispatch = mask & ((jnp.arange(srv.shape[0]) == 0) | (cur <= t))
    # Per-policy server-occupancy updates (masked copies rewrite their own
    # old value — a no-op; srv entries are distinct by construction):
    #   REPLICATE_ALL      every copy runs to completion.
    #   CANCEL_ON_COMPLETE losers vacate at the winner's finish: a loser
    #                      in service frees at t_win, a queued loser
    #                      (cur >= t_win) never starts — max(cur, t_win)
    #                      covers both (and equals finish for the winner).
    #   REPLICATE_TO_IDLE  only dispatched copies occupy their server.
    val_all = jnp.where(mask, finish, cur)
    val_cancel = jnp.where(mask, jnp.maximum(cur, t_win), cur)
    val_idle = jnp.where(dispatch, finish, cur)
    new_val = jnp.where(
        policy == int(Policy.CANCEL_ON_COMPLETE), val_cancel,
        jnp.where(policy == int(Policy.REPLICATE_TO_IDLE), val_idle,
                  val_all))
    free = free.at[srv].set(new_val)
    resp_win = t_win - t + overhead
    resp_idle = jnp.min(jnp.where(dispatch, finish, jnp.inf)) - t + overhead
    resp = jnp.where(policy == int(Policy.REPLICATE_TO_IDLE), resp_idle,
                     resp_win)
    return free, resp


def kahan_fold(ssum: Array, comp: Array, resp: Array,
               w: Array) -> tuple[Array, Array]:
    """One gated Kahan step, shared verbatim by the scan body and the
    Pallas kernel (same ops => same bits in both).

    Kahan-compensated sum: sequential f32 accumulation over ~1e5+
    terms would otherwise cost ~1e-4 relative error on the mean,
    which is the signal threshold bisection keys on. Three guards
    keep the update's rounding EXACTLY the same in every compilation
    (the sharded-vs-unsharded and kernel-vs-scan bit-identity
    contracts):

      * the 0/1 warmup weight gates the WHOLE update via selects (a
        ``resp * w - comp`` multiply-subtract invites FMA
        contraction, and an ungated ``y = 0 - comp`` step would fold
        the compensation into the sum — making the bits depend on
        how much zero-weight padding trails the chunk);
      * an ``optimization_barrier`` hides ``tot`` from XLA's
        algebraic simplifier, which would otherwise rewrite
        ``(tot - ssum) - y`` — compensation terms it sees as
        algebraically zero — depending on the surrounding fusion
        context.
    """
    y = resp - comp
    tot = ssum + y
    tot_b, y_b = jax.lax.optimization_barrier((tot, y))
    comp_new = (tot_b - ssum) - y_b
    live = w > 0
    return jnp.where(live, tot_b, ssum), jnp.where(live, comp_new, comp)


def cell_update_ref(free: Array, ssum: Array, comp: Array, hist: Array,
                    cum: Array, warm: Array, servers: Array,
                    services: Array, seed_idx: Array, rates: Array,
                    k_mask: Array, ovh: Array, policy_code: Array,
                    model_code: Array, mix: Array, *,
                    n_servers: int | None = None, n_bins: int,
                    block: int) -> tuple[Array, Array, Array, Array]:
    """Scan-body reference for one chunk on the flat cell axis.

    ``cum`` (S,T) are cumulative arrival offsets from the chunk start
    (already masked for padding), ``warm`` (T,) the 0/1 post-warmup
    weights, ``servers`` (S,T,k_max) / ``services`` (S,T,n_svc) the
    sampled inputs (padding steps zeroed); the remaining args are the
    per-cell carry and plan parameters of
    ``queueing._sweep_chunk_cells``, which documents them. Returns the
    updated carry with ``free`` NOT yet rebased (the caller rebases).
    ``n_servers`` is accepted (dispatch-signature parity with
    ``ops.cell_update``) but implied by ``free``.
    """
    del n_servers
    k_max = k_mask.shape[1]
    has_shared = services.shape[-1] > k_max
    need_hist = hist.size > 0
    T = cum.shape[1]
    if need_hist:
        assert T % block == 0, (T, block)

    cell_c = jax.vmap(step_cell)        # one lane per cell of the flat axis

    def step(carry, inp):
        free, ssum, comp = carry
        c, w, srv, svc = inp                       # (S,), (), (S,k), (S,n_svc)
        t = c[seed_idx] / rates                       # (C,)
        svc_c = svc[seed_idx]                         # (C, n_svc)
        shared_c = svc_c[:, k_max] if has_shared else svc_c[:, 0]
        free, resp = cell_c(free, t, srv[seed_idx], svc_c[:, :k_max],
                            shared_c, k_mask, ovh, policy_code, model_code,
                            mix)
        ssum, comp = kahan_fold(ssum, comp, resp, w)
        return (free, ssum, comp), (resp if need_hist else None)

    xs = (cum.T, warm, jnp.moveaxis(servers, 1, 0),
          jnp.moveaxis(services, 1, 0))
    if need_hist:
        xs = jax.tree.map(
            lambda x: x.reshape((T // block, block) + x.shape[1:]), xs)

        def outer(carry, xs_blk):
            free, ssum, comp, hist = carry
            (free, ssum, comp), resp = jax.lax.scan(
                step, (free, ssum, comp), xs_blk)
            idx = hist_ops.bin_indices(resp, xs_blk[1][:, None],
                                       n_bins=n_bins)
            hist = hist + hist_ops.hist_accum(idx, n_bins=n_bins,
                                              block_t=block)
            return (free, ssum, comp, hist), None

        (free, ssum, comp, hist), _ = jax.lax.scan(
            outer, (free, ssum, comp, hist), xs)
    else:
        (free, ssum, comp), _ = jax.lax.scan(step, (free, ssum, comp), xs)
    return free, ssum, comp, hist
