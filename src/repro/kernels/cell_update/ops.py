"""Public cell-update ops: kernel-mode resolution, validated kernel
dispatch, and the FLOPs/bytes cost model the roofline benchmark reads.

Kernel MODES (the ``kernel=`` knob of ``repro.core.queueing.run`` and
the benchmarks' ``--kernel`` flag):

  ``"off"``        the ``lax.scan`` reference body (``ref``) — the
                   default everywhere off-TPU.
  ``"on"``         the compiled Pallas kernel (TPU).
  ``"interpret"``  the Pallas kernel through the interpreter — same
                   jnp ops, runs anywhere; bit-exact vs both other
                   modes, so CPU/CI can test the kernel path.
  ``"auto"``       resolves to ``"on"`` on TPU, ``"off"`` elsewhere.

Requesting ``"on"`` off-TPU degrades to ``"interpret"`` (there is no
TPU to compile for), so ``kernel="on"`` is always safe to pass.
"""
from __future__ import annotations

import jax

from repro.kernels.cell_update.kernel import cell_update_tc
from repro.kernels.cell_update.ref import cell_update_ref
from repro.kernels.hist_sketch.kernel import LANE

KERNEL_MODES = ("auto", "on", "off", "interpret")

_ON_TPU = None


def _on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.devices()[0].platform == "tpu"
    return _ON_TPU


def resolve_kernel_mode(kernel: str | bool | None = "auto") -> str:
    """Normalize a ``kernel=`` knob to a concrete mode: ``"on"``,
    ``"off"`` or ``"interpret"`` (never ``"auto"``). Accepts the string
    modes plus ``None``/``False`` (off) and ``True`` (on)."""
    if kernel is None or kernel is False:
        return "off"
    if kernel is True:
        kernel = "on"
    if kernel not in KERNEL_MODES:
        raise ValueError(
            f"kernel must be one of {KERNEL_MODES}, got {kernel!r}")
    if kernel == "auto":
        return "on" if _on_tpu() else "off"
    if kernel == "on" and not _on_tpu():
        return "interpret"
    return kernel


def cell_update(free, ssum, comp, cnt, hist, cum, warm, valid, servers,
                services, seed_idx, rates, k_mask, ovh, policy_code,
                model_code, mix, p_slow, slow_factor, p_fail, delay,
                svc_idx=None, *,
                n_servers: int, n_bins: int, block: int,
                interpret: bool = False, has_shared: bool = False,
                has_timed: bool = False, has_dists: bool = False):
    """Kernel-path twin of ``ref.cell_update_ref`` (same signature, same
    bits): validates the layout, derives the scalar-prefetch operands
    from the plan parameters, and calls the Pallas kernel.

    ``k_mask`` rows are prefix masks by plan construction
    (``queueing._plan_cell_params``), so they compress losslessly to a
    per-cell copy COUNT — an int the kernel prefetches and re-expands
    with an iota compare (boolean, no rounding). The degradation /
    timed-policy parameters (``p_slow``/``slow_factor``/``p_fail``/
    ``delay``) prefetch as-is; ``has_timed`` only routes the scan
    fallback (the kernel's timed ops are always compiled — scalar
    selects keep them inert and bit-invisible for non-timed cells). A
    sketch whose ``n_bins`` is not a multiple of the 128 lane width
    falls back to the reference body (same bits, no kernel).
    """
    t_total = cum.shape[1]
    need_hist = hist.size > 0
    if need_hist and n_bins % LANE != 0:
        return cell_update_ref(
            free, ssum, comp, cnt, hist, cum, warm, valid, servers,
            services, seed_idx, rates, k_mask, ovh, policy_code,
            model_code, mix, p_slow, slow_factor, p_fail, delay, svc_idx,
            n_bins=n_bins, block=block, has_shared=has_shared,
            has_timed=has_timed, has_dists=has_dists)
    if t_total % block != 0:
        raise ValueError(
            f"kernel mode needs the chunk padded to the block multiple "
            f"(T={t_total}, block={block}); _chunk_layout pads when the "
            f"kernel is on")
    k_count = k_mask.astype(jax.numpy.int32).sum(axis=1)
    return cell_update_tc(
        free, ssum, comp, cnt, hist, cum, warm, valid, servers, services,
        seed_idx, k_count, policy_code, model_code, rates, ovh, mix,
        p_slow, slow_factor, p_fail, delay, svc_idx,
        n_servers=n_servers, n_bins=n_bins, block_t=block,
        interpret=interpret, has_shared=has_shared, has_dists=has_dists)


def cell_update_costs(*, n_cells: int, n_servers: int, k_max: int,
                      n_arrivals: int, n_bins: int, n_seeds: int,
                      n_svc: int | None = None, chunk: int | None = None,
                      need_hist: bool = True) -> dict[str, float]:
    """Analytic FLOPs / HBM-byte model of the fused kernel over a whole
    stream, for the roofline benchmark.

    Per arrival per cell the step body costs ~``k_max * (3 * n_servers
    + 12) + 10`` flops (one-hot gather + scatter dominate at
    ``O(k * N)``; the selects/compares of the policy branches are the
    rest), plus ``2 * n_bins`` MAC-flops per histogrammed arrival for
    the indicator matmuls. HBM bytes count one read+write of the
    per-cell carry per chunk plus one pass over the seed-level sampled
    inputs — the kernel's whole point is that the carry term is per
    CHUNK, not per arrival.
    """
    n_svc = k_max if n_svc is None else n_svc
    chunk = n_arrivals if chunk is None else min(chunk, n_arrivals)
    n_chunks = -(-n_arrivals // chunk)
    step_flops = k_max * (3 * n_servers + 12) + 10
    hist_flops = 2 * n_bins if need_hist else 0
    flops = float(n_cells) * n_arrivals * (step_flops + hist_flops)
    carry_floats = n_servers + 2 + (n_bins if need_hist else 0)
    carry_bytes = 2 * n_cells * carry_floats * 4          # r+w per chunk
    input_bytes = n_seeds * chunk * (1 + k_max + n_svc) * 4
    hbm_bytes = float(n_chunks) * (carry_bytes + input_bytes)
    return {"flops": flops, "hbm_bytes": hbm_bytes,
            "intensity": flops / hbm_bytes}
