"""Fused Pallas cell-update kernel for the sweep engine's chunk body.

``ops.cell_update`` runs one chunk of arrivals through the per-cell DES
update — free-time grid, policy/model selects, Kahan mean fold, and
hist-sketch bin accumulation — with the whole per-cell carry resident in
VMEM across the chunk. ``ref`` holds the single source of truth for the
step physics (``step_cell``) and the ``lax.scan`` reference body the
kernel must match bit-for-bit; ``repro.core.queueing`` dispatches
between the two behind its ``use_kernel`` flag.
"""
from repro.kernels.cell_update.ops import (cell_update,  # noqa: F401
                                           cell_update_costs,
                                           resolve_kernel_mode)
from repro.kernels.cell_update.ref import (cell_update_ref,  # noqa: F401
                                           step_cell)
