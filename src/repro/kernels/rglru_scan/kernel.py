"""Pallas TPU kernel for the RG-LRU elementwise linear recurrence.

h_t = a_t * h_{t-1} + b_t over (batch, time, width). The grid is
(batch, width_blocks, time_blocks) with time innermost-sequential: the
(1, block_w) carry lives in VMEM scratch and flows across time blocks, so
HBM traffic is exactly one read of a/b and one write of h (the recurrence is
bandwidth-bound; there is no MXU work). Within a block the time loop is a
``fori_loop`` over VREG-resident (block_w,) lanes — the VPU parallelism is
across the width lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, h_ref, carry_ref, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    def step(t, carry):
        h = a_ref[0, t, :] * carry + b_ref[0, t, :]
        h_ref[0, t, :] = h
        return h

    carry = carry_ref[0]
    carry = jax.lax.fori_loop(0, block_t, step, carry)
    carry_ref[0] = carry


@functools.partial(jax.jit, static_argnames=("block_t", "block_w",
                                             "interpret"))
def chunked_linear_scan_raw(a: jax.Array, b: jax.Array, *, block_t: int,
                            block_w: int, interpret: bool = False):
    bsz, length, width = a.shape
    assert length % block_t == 0 and width % block_w == 0
    grid = (bsz, width // block_w, length // block_t)
    kernel = functools.partial(_scan_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_w),
                         lambda ib, iw, it: (ib, it, iw)),
            pl.BlockSpec((1, block_t, block_w),
                         lambda ib, iw, it: (ib, it, iw)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_w),
                               lambda ib, iw, it: (ib, it, iw)),
        out_shape=jax.ShapeDtypeStruct((bsz, length, width), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b)
