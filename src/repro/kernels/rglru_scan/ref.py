"""Pure-jnp oracle for the RG-LRU linear scan kernel."""
from __future__ import annotations

import jax


def linear_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1 (h_{-1} = 0). a/b (B, L, W)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
