from repro.kernels.rglru_scan import kernel, ops, ref  # noqa: F401
