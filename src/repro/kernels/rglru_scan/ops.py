"""Jitted public wrapper for the RG-LRU chunked linear scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import chunked_linear_scan_raw


def _interpret_default() -> bool:
    return jax.devices()[0].platform != "tpu"


def chunked_linear_scan(a: jax.Array, b: jax.Array, *,
                        block_t: int = 64, block_w: int = 512,
                        interpret: bool | None = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t along axis 1. a/b (B, L, W) -> h (B, L, W)."""
    if interpret is None:
        interpret = _interpret_default()
    _, length, width = a.shape
    bt = next(t for t in (block_t, 32, 16, 8, 4, 2, 1) if length % t == 0)
    bw = next(w for w in (block_w, 256, 128, 64, 32, 16, 8, 4, 2, 1)
              if width % w == 0)
    return chunked_linear_scan_raw(a.astype(jnp.float32),
                                   b.astype(jnp.float32),
                                   block_t=bt, block_w=bw,
                                   interpret=interpret)
