"""Pure-jnp oracle for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_intra_chunk_ref(xc, bc, cc, dtc, cum):
    """Intra-chunk SSD term + per-chunk state contributions.

    xc (B,NC,Q,H,P) f32; bc/cc (B,NC,Q,N); dtc/cum (B,NC,Q,H).
    Returns y_intra (B,NC,Q,H,P), states (B,NC,H,P,N).
    """
    q = xc.shape[2]
    total = cum[:, :, -1:]                                  # (B,NC,1,H)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,NC,Q,Q,H)
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    gate = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bcqn,bcsn->bcqs", cc, bc)
    w = scores[..., None] * gate * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", w, xc)
    sgate = jnp.exp(total - cum) * dtc                      # (B,NC,Q,H)
    states = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", sgate, xc, bc)
    return y_intra, states
