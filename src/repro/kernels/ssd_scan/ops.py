"""Jitted public wrapper for the SSD intra-chunk kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_flat


def _interpret_default() -> bool:
    return jax.devices()[0].platform != "tpu"


def ssd_intra_chunk(xc, bc, cc, dtc, cum, *, interpret: bool | None = None):
    """xc (B,NC,Q,H,P) f32; bc/cc (B,NC,Q,N); dtc/cum (B,NC,Q,H).

    Returns y_intra (B,NC,Q,H,P), states (B,NC,H,P,N) — matches ref.py.
    """
    if interpret is None:
        interpret = _interpret_default()
    b, nc, q, h, p = xc.shape
    n = bc.shape[-1]
    flat = lambda t, s: t.reshape(b * nc, *s)
    y, st = ssd_intra_chunk_flat(
        flat(xc.astype(jnp.float32), (q, h, p)),
        flat(bc.astype(jnp.float32), (q, n)),
        flat(cc.astype(jnp.float32), (q, n)),
        flat(dtc.astype(jnp.float32), (q, h)),
        flat(cum.astype(jnp.float32), (q, h)),
        interpret=interpret)
    return y.reshape(b, nc, q, h, p), st.reshape(b, nc, h, p, n)
