"""Pallas TPU kernel for the SSD (Mamba-2) intra-chunk computation.

The chunked SSD algorithm's hot spot is the per-chunk quadratic term
("state-space duality" — attention-like (Q, Q) weights per head) plus the
per-chunk contributed state. Both are computed here per (batch x chunk,
head) grid cell with the whole chunk resident in VMEM:

    scores  = C B^T                      (Q, Q)   MXU
    w[q,s]  = scores * exp(cum_q - cum_s) * dt_s  (causal-masked)
    y_intra = w X                        (Q, P)   MXU
    state   = (X * exp(total-cum) dt)^T B -> (P, N)  MXU

Chunk sizes Q in {64, 128, 256} with P in {32, 64}, N 128 keep the working
set << VMEM (Q*Q + 2*Q*N + Q*P floats ~ 0.5 MB at Q=256). The inter-chunk
recurrence (tiny (H, P, N) state scan) stays in plain JAX.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, cum_ref, y_ref, st_ref):
    x = x_ref[0, :, 0, :]                                   # (Q, P)
    bmat = b_ref[0]                                         # (Q, N)
    cmat = c_ref[0]                                         # (Q, N)
    dt = dt_ref[0, :, 0]                                    # (Q,)
    cum = cum_ref[0, :, 0]                                  # (Q,)
    q = x.shape[0]

    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    rel = cum[:, None] - cum[None, :]                       # (Q, Q)
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    gate = jnp.where(col <= row, jnp.exp(rel), 0.0)
    w = scores * gate * dt[None, :]
    y_ref[0, :, 0, :] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    total = cum[q - 1]
    sgate = jnp.exp(total - cum) * dt                       # (Q,)
    xs = x * sgate[:, None]                                 # (Q, P)
    st_ref[0, 0] = jax.lax.dot_general(
        xs, bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (P, N)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_flat(xc, bc, cc, dtc, cum, *, interpret: bool = False):
    """xc (BC, Q, H, P); bc/cc (BC, Q, N); dtc/cum (BC, Q, H).

    Returns y (BC, Q, H, P) and states (BC, H, P, N), fp32.
    """
    bcn, q, h, p = xc.shape
    n = bc.shape[-1]
    grid = (bcn, h)

    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, q, 1), lambda i, j: (i, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bcn, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bcn, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xc, bc, cc, dtc, cum)
