"""Pallas TPU kernels for the architecture substrate's compute hot spots.

The paper's own contribution is scheduling-level, and hist_sketch is the
one kernel in its service: the sweep engine's streaming log-histogram
percentile sketch, accumulated in VMEM over blocks of simulator steps
instead of a per-arrival scatter. The LM substrate has four more:
flash_attention (prefill/train), decode_attention (flash-decoding over
ring/dense caches), ssd_scan (Mamba-2 intra-chunk), rglru_scan (RG-LRU
linear recurrence). Each subpackage is
kernel.py (pl.pallas_call + BlockSpec VMEM tiling) / ops.py (jit wrapper,
interpret-mode on CPU) / ref.py (pure-jnp oracle used by tests).
"""
