"""Pure-jnp oracle for the histogram-sketch kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hist_accum_ref(idx: jax.Array, *, n_bins: int) -> jax.Array:
    """idx (T, C) int32 in [-1, n_bins) -> per-cell counts (C, n_bins) f32.

    Bit-exact semantics the kernel must reproduce: each valid (t, c) entry
    adds exactly 1.0 to ``out[c, idx[t, c]]``; ``idx == -1`` entries add
    nothing.
    """
    t, c = idx.shape
    valid = (idx >= 0).astype(jnp.float32)
    cols = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[None, :], (t, c))
    safe = jnp.clip(idx, 0, n_bins - 1)
    return jnp.zeros((c, n_bins), jnp.float32).at[cols, safe].add(valid)
