from repro.kernels.hist_sketch import kernel, ops, ref  # noqa: F401
