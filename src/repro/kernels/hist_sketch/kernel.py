"""Pallas TPU histogram-sketch kernel.

The sweep engine's percentile sketch needs, per grid cell, a count of
responses falling into each of ``n_bins`` log-spaced buckets. The obvious
per-step ``hist.at[idx].add(w)`` scatter is the one op class TPUs hate —
PR 2 paid for it on every arrival. This kernel replaces the scatter with
MXU-friendly dense algebra over a *block of steps*:

    one-hot(idx)[t, b] = [idx_hi[t] == b // LANE] * [idx_lo[t] == b % LANE]

with ``LANE = 128`` (the TPU lane width), so the (block_t, n_bins) one-hot
never materializes. Instead two skinny indicator matrices

    A[t, h] = [idx[t] // LANE == h]        (block_t, n_bins // LANE)
    B[t, l] = [idx[t] %  LANE == l]        (block_t, LANE)

are contracted over the step axis, ``acc += A^T @ B`` — one small matmul
per (cell, step-block) — and the (n_bins // LANE, LANE) accumulator lives
in VMEM scratch for the whole pass over steps (the grid's step axis is
innermost, hence sequential on a TPU core).

Masking rides on the index encoding: callers pass ``idx = -1`` for steps
that must not count (warmup, chunk padding). Floor division maps -1 to
``hi = -1``, which matches no histogram row, so masked steps contribute
exactly zero — no weights input needed.

Counts are accumulated in float32; 0/1 matmuls are exact until a single
(cell, bin) exceeds 2**24 entries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _hist_kernel(idx_ref, out_ref, acc_ref, *, n_hi: int, block_t: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[...]                       # (block_t, 1) int32
    hi = idx // LANE                         # -1 -> -1: matches no row
    lo = idx - hi * LANE                     # in [0, LANE)
    a = (hi == jax.lax.broadcasted_iota(
        jnp.int32, (block_t, n_hi), 1)).astype(jnp.float32)
    b = (lo == jax.lax.broadcasted_iota(
        jnp.int32, (block_t, LANE), 1)).astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (n_hi, LANE)

    @pl.when(it == pl.num_programs(1) - 1)
    def _finish():
        out_ref[0] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("n_bins", "block_t", "interpret"))
def hist_accum_tc(idx: jax.Array, *, n_bins: int, block_t: int = 512,
                  interpret: bool = False) -> jax.Array:
    """idx (T, C) int32 in [-1, n_bins) -> per-cell counts (C, n_bins) f32.

    ``idx == -1`` entries are skipped. Requires ``T % block_t == 0`` and
    ``n_bins % 128 == 0`` (use ``ops.hist_accum`` for padding / fallback).
    """
    t, c = idx.shape
    assert t % block_t == 0, (t, block_t)
    assert n_bins % LANE == 0, n_bins
    n_hi = n_bins // LANE
    grid = (c, t // block_t)

    kernel = functools.partial(_hist_kernel, n_hi=n_hi, block_t=block_t)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, 1), lambda ic, it: (it, ic))],
        out_specs=pl.BlockSpec((1, n_hi, LANE), lambda ic, it: (ic, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, n_hi, LANE), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_hi, LANE), jnp.float32)],
        interpret=interpret,
    )(idx)
    return out.reshape(c, n_bins)
