"""Public histogram-sketch ops: log binning, padded kernel dispatch with
interpret-mode fallback on CPU, and percentile read-out.

This package owns the sketch geometry (``HIST_LO`` / ``HIST_HI`` /
``DEFAULT_BINS``): ``n_bins`` log-spaced buckets spanning [HIST_LO,
HIST_HI]; values outside clamp to the edge bins. ``repro.core.queueing``
re-exports the constants for backwards compatibility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hist_sketch.kernel import LANE, hist_accum_tc
from repro.kernels.hist_sketch.ref import hist_accum_ref

# Unit-mean service times => responses live well inside [1e-3, 1e5].
HIST_LO = 1e-3
HIST_HI = 1e5
DEFAULT_BINS = 2048

_ON_TPU = None


def _interpret_default() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.devices()[0].platform == "tpu"
    return not _ON_TPU


def _log_scale(n_bins: int, lo: float, hi: float):
    log_lo = jnp.log(jnp.float32(lo))
    scale = (n_bins - 1) / (jnp.log(jnp.float32(hi)) - log_lo)
    return log_lo, scale


def bin_indices(values: jax.Array, warm: jax.Array | None = None, *,
                n_bins: int = DEFAULT_BINS, lo: float = HIST_LO,
                hi: float = HIST_HI) -> jax.Array:
    """Log-bin indices (same shape as ``values``, int32 in [-1, n_bins)).

    Entries where ``warm`` (broadcastable 0/1 weight) is zero are encoded
    as -1, which the accumulators skip.
    """
    log_lo, scale = _log_scale(n_bins, lo, hi)
    idx = ((jnp.log(values) - log_lo) * scale).astype(jnp.int32)
    idx = jnp.clip(idx, 0, n_bins - 1)
    if warm is not None:
        idx = jnp.where(jnp.broadcast_to(warm, values.shape) > 0, idx, -1)
    return idx


def hist_accum(idx: jax.Array, *, n_bins: int = DEFAULT_BINS,
               block_t: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """idx (T, C) int32 in [-1, n_bins) -> per-cell counts (C, n_bins) f32.

    Pads the step axis up to a multiple of ``block_t`` with skip entries
    and dispatches the Pallas kernel (interpret mode off-TPU). ``n_bins``
    not divisible by the 128 lane width falls back to the jnp reference.
    """
    if interpret is None:
        interpret = _interpret_default()
    if n_bins % LANE != 0:
        return hist_accum_ref(idx, n_bins=n_bins)
    t, _ = idx.shape
    bt = min(block_t, t) if t % block_t else block_t
    pad = (-t) % bt
    if pad:
        idx = jnp.concatenate(
            [idx, jnp.full((pad, idx.shape[1]), -1, idx.dtype)], axis=0)
    return hist_accum_tc(idx, n_bins=n_bins, block_t=bt, interpret=interpret)


def hist_sketch(values: jax.Array, warm: jax.Array | None = None, *,
                n_bins: int = DEFAULT_BINS, lo: float = HIST_LO,
                hi: float = HIST_HI, block_t: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """Log-histogram counts (C, n_bins) of a (T, C) block of values."""
    idx = bin_indices(values, warm, n_bins=n_bins, lo=lo, hi=hi)
    return hist_accum(idx, n_bins=n_bins, block_t=block_t,
                      interpret=interpret)


def sketch_quantiles(hist: jax.Array, qs: jax.Array, *, lo: float = HIST_LO,
                     hi: float = HIST_HI) -> jax.Array:
    """Percentiles (Q, ...) read from histogram counts (..., n_bins).

    Returns the geometric midpoint of the first bin at which the cdf
    reaches the target mass — relative error is at most one log-bin width
    (~0.5% at the default 2048 bins over 8 decades).
    """
    n_bins = hist.shape[-1]
    log_lo, scale = _log_scale(n_bins, lo, hi)
    cdf = jnp.cumsum(hist, axis=-1)                       # (..., n_bins)
    count = cdf[..., -1:]                                 # (..., 1)
    qs = jnp.asarray(qs, jnp.float32)
    targets = qs.reshape((-1,) + (1,) * hist.ndim) / 100.0 * count[None]
    bin_idx = jnp.argmax(cdf[None] >= targets, axis=-1)   # (Q, ...)
    return jnp.exp(log_lo + (bin_idx + 0.5) / scale)
