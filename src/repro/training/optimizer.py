"""Optimizers: AdamW (fp32 master + moments) and Adafactor (factored second
moment, no momentum, no master) — both functional, pytree-shaped like params.

AdamW is the default training recipe; Adafactor is used where fp32 Adam
state cannot fit (deepseek-v3-671b on 256 x 16 GB v5e — documented in
DESIGN.md/EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    clip_rms: float = 1.0


def _global_norm(tree: PyTree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params: PyTree) -> PyTree:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
    }


def adamw_update(params: PyTree, grads: PyTree, state: PyTree, step: Array,
                 cfg: OptConfig) -> tuple[PyTree, PyTree]:
    grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = master - cfg.lr * (update + cfg.weight_decay * master)
        return m, v, master

    out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"])
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
    master = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda o: isinstance(o, tuple))
    new_params = jax.tree.map(lambda mast, p: mast.astype(p.dtype), master,
                              params)
    return new_params, {"m": m, "v": v, "master": master}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern), simplified: beta1=0, factored v, no master
# ---------------------------------------------------------------------------


def _factored(shape: tuple[int, ...]) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: PyTree) -> PyTree:
    def vrow(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape)
                else jnp.zeros((1,), jnp.float32))

    def vcol(p):
        return (jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32)
                if _factored(p.shape) else jnp.zeros(p.shape, jnp.float32))

    return {"v_row": jax.tree.map(vrow, params),
            "v_col": jax.tree.map(vcol, params)}


def adafactor_update(params: PyTree, grads: PyTree, state: PyTree,
                     step: Array, cfg: OptConfig) -> tuple[PyTree, PyTree]:
    grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** (-cfg.decay_rate)

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if _factored(g.shape):
            vr = beta2 * vr + (1.0 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1.0 - beta2) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            update = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                          + cfg.eps)
        else:
            vc = beta2 * vc + (1.0 - beta2) * g2
            update = g / (jnp.sqrt(vc) + cfg.eps)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-30)
        update = update / jnp.maximum(1.0, rms / cfg.clip_rms)
        newp = (p.astype(jnp.float32)
                - cfg.lr * (update + cfg.weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), vr, vc

    out = jax.tree.map(upd, params, grads, state["v_row"], state["v_col"])
    is_t = lambda o: isinstance(o, tuple)
    newp = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    vr = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    vc = jax.tree.map(lambda o: o[2], out, is_leaf=is_t)
    return newp, {"v_row": vr, "v_col": vc}


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Optimizer:
    cfg: OptConfig

    def init(self, params: PyTree) -> PyTree:
        return (adamw_init(params) if self.cfg.name == "adamw"
                else adafactor_init(params))

    def update(self, params: PyTree, grads: PyTree, state: PyTree,
               step: Array) -> tuple[PyTree, PyTree]:
        fn = adamw_update if self.cfg.name == "adamw" else adafactor_update
        return fn(params, grads, state, step, self.cfg)


def make_optimizer(name: str, lr: float = 3e-4, **kw: Any) -> Optimizer:
    return Optimizer(OptConfig(name=name, lr=lr, **kw))
