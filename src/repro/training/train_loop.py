"""The Trainer: checkpoint/restart fault tolerance + hedged data loading +
optional straggler-drop gradient aggregation.

Restart contract (tested): `Trainer(...).run(n)` after a crash resumes from
the latest checkpoint and — because the data pipeline is a pure function of
the step — produces bitwise-identical parameters to an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, HedgedPrefetcher, MarkovSource
from repro.distributed.ctx import ShardCtx
from repro.models import lm
from repro.training.optimizer import Optimizer, make_optimizer
from repro.training.step import make_train_step

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    async_ckpt: bool = True
    hedged_loader_k: int = 1       # >1 => redundant loader workers
    log_every: int = 10
    fail_at_step: int | None = None  # fault-injection hook (tests)


class Trainer:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 tcfg: TrainerConfig, opt: Optimizer | None = None,
                 source=None, ctx: ShardCtx | None = None,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.dcfg = dcfg
        self.tcfg = tcfg
        self.opt = opt or make_optimizer(cfg.optimizer, lr=1e-3)
        self.source = source or MarkovSource(cfg, dcfg)
        self.loader = HedgedPrefetcher(self.source,
                                       k=max(1, tcfg.hedged_loader_k))
        self.ctx = ctx
        self.log = log_fn
        self._step_fn = jax.jit(make_train_step(cfg, self.opt, ctx=ctx))
        self._ckpt = ckpt.AsyncCheckpointer(tcfg.ckpt_dir,
                                            keep_last=tcfg.keep_last) \
            if tcfg.async_ckpt else None
        self.metrics_history: list[dict] = []

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0) -> tuple[PyTree, PyTree, int]:
        params = lm.init(jax.random.PRNGKey(seed), self.cfg)
        opt_state = self.opt.init(params)
        return params, opt_state, 0

    def restore_or_init(self, seed: int = 0) -> tuple[PyTree, PyTree, int]:
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return self.init_state(seed)
        params, opt_state, _ = self.init_state(seed)
        state = ckpt.restore(self.tcfg.ckpt_dir, last,
                             {"params": params, "opt": opt_state})
        self.log(f"[trainer] resumed from step {last}")
        return state["params"], state["opt"], last

    # ------------------------------------------------------------------
    def run(self, num_steps: int, seed: int = 0) -> dict:
        params, opt_state, start = self.restore_or_init(seed)
        t0 = time.time()
        for step in range(start, num_steps):
            if self.tcfg.fail_at_step is not None and \
                    step == self.tcfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch = jax.tree.map(jnp.asarray, self.loader.get(step))
            params, opt_state, metrics = self._step_fn(
                params, opt_state, batch, jnp.int32(step))
            if step % self.tcfg.log_every == 0 or step == num_steps - 1:
                loss = float(metrics["loss"])
                self.metrics_history.append({"step": step, "loss": loss})
                self.log(f"[trainer] step {step} loss {loss:.4f} "
                         f"({time.time() - t0:.1f}s)")
            if (step + 1) % self.tcfg.ckpt_every == 0 or \
                    step == num_steps - 1:
                self._save(step + 1, params, opt_state)
        if self._ckpt:
            self._ckpt.wait()
        return {"params": params, "opt": opt_state,
                "history": self.metrics_history,
                "loader_duplicate_wins": self.loader.duplicate_wins}

    def _save(self, step: int, params: PyTree, opt_state: PyTree) -> None:
        tree = {"params": params, "opt": opt_state}
        if self._ckpt:
            self._ckpt.save(step, tree)
        else:
            ckpt.save(self.tcfg.ckpt_dir, step, tree,
                      keep_last=self.tcfg.keep_last)
