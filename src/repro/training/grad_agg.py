"""Straggler-tolerant gradient aggregation (redundancy for training).

Synchronous SPMD cannot take "first of two" inside one XLA program, so the
paper's technique maps onto training as:

  * **backup microbatches** — dispatch n microbatches where only m are
    required; aggregate whichever m finish first (host decides the mask);
  * **drop-straggler aggregation** — a masked mean over microbatch grads:
    contributions with mask=0 (straggling / failed workers) are excluded
    and the mean is renormalized, keeping the update unbiased w.r.t. the
    included data.

Both reduce to ``masked_grad_mean`` below, which is jit-safe (static shapes;
the mask is data). This mirrors backup-task execution in Dolly/MapReduce
(paper §4) on the gradient pathway.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def masked_grad_mean(grad_stack: PyTree, mask: jax.Array) -> PyTree:
    """grad_stack leaves: (n_micro, ...); mask: (n_micro,) in {0,1}.

    Returns the mean over the included microbatches (renormalized).
    """
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)

    def agg(g):
        m = mask.astype(jnp.float32).reshape((-1,) + (1,) * (g.ndim - 1))
        return (jnp.sum(g.astype(jnp.float32) * m, axis=0) / denom
                ).astype(g.dtype)

    return jax.tree.map(agg, grad_stack)


def first_m_mask(arrival_order: jax.Array, m: int) -> jax.Array:
    """Mask selecting the first ``m`` arrivals. arrival_order[i] = rank of
    microbatch i's completion (0 = first)."""
    return (arrival_order < m).astype(jnp.float32)


def accumulate_microbatch_grads(loss_fn, params: PyTree, batches: PyTree,
                                n_micro: int) -> tuple[PyTree, jax.Array]:
    """Stack per-microbatch grads: batches leaves are (n_micro, ...)."""
    def one(mb):
        (_, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                    mb)
        return g, metrics["loss"]

    grads, losses = jax.lax.map(
        lambda i: one(jax.tree.map(lambda b: b[i], batches)),
        jnp.arange(n_micro))
    return grads, losses
