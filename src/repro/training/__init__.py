"""Training: optimizers, train step/loop, straggler-tolerant grad agg."""
