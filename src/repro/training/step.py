"""The production train step: loss -> grads -> optimizer update.

This is exactly what the multi-pod dry-run lowers (train shapes), and what
``launch/train.py`` executes.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

from repro.configs.base import ModelConfig
from repro.distributed.ctx import ShardCtx
from repro.models import lm
from repro.training.optimizer import Optimizer

PyTree = Any


def make_train_step(cfg: ModelConfig, opt: Optimizer,
                    ctx: ShardCtx | None = None,
                    impl: str = "ref") -> Callable:
    def train_step(params: PyTree, opt_state: PyTree, batch: PyTree,
                   step: jax.Array):
        (_, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, cfg, batch, ctx=ctx, impl=impl)
        new_params, new_opt = opt.update(params, grads, opt_state, step)
        return new_params, new_opt, metrics

    return train_step
