"""Streaming serving telemetry on the engine's histogram geometry.

The serving layer needs tail percentiles over millions of requests
without keeping millions of floats sorted. ``TailSketch`` is a pure-
NUMPY mirror of the engine's Pallas ``hist_sketch`` geometry — the SAME
``HIST_LO`` / ``HIST_HI`` bounds, the same ``DEFAULT_BINS`` log-spaced
buckets, the same geometric-midpoint quantile read-out — so a latency
recorded by the live service and a response time summarized by
``queueing.run`` land in the same bucket grid and are directly
comparable (relative error <= half a log-bin width, ~0.5% at the
default 2048 bins over 8 decades). Nothing here dispatches JAX: the
request hot path folds latencies with ``np.bincount``.

``Telemetry`` is the per-request record store the batched service
feeds: arrival / dispatch / first-completion / cancel timestamps plus
hedge and shed counts per request, folded as they complete into
windowed ``TailSketch``es (one sketch per ``window_s`` of arrival
time). ``json_rows()`` exports the windowed p50/p99/p999 trajectory as
JSON-ready provenance rows — the benchmark artifact's raw material.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable

import numpy as np

# One geometry for engine sweeps and serving telemetry: these constants
# are owned by the hist_sketch kernel package.
from repro.kernels.hist_sketch.ops import DEFAULT_BINS, HIST_HI, HIST_LO


class TailSketch:
    """Log-histogram percentile sketch (numpy twin of
    ``repro.kernels.hist_sketch``).

    ``fold`` accepts scalars or arrays; values outside [lo, hi] clamp to
    the edge bins exactly as the kernel's ``bin_indices`` does.
    """

    def __init__(self, n_bins: int = DEFAULT_BINS, lo: float = HIST_LO,
                 hi: float = HIST_HI):
        if n_bins < 2 or not 0.0 < lo < hi:
            raise ValueError(f"bad sketch geometry ({n_bins=}, {lo=}, {hi=})")
        self.n_bins = int(n_bins)
        self.lo, self.hi = float(lo), float(hi)
        self._log_lo = np.log(self.lo)
        self._scale = (self.n_bins - 1) / (np.log(self.hi) - self._log_lo)
        self.counts = np.zeros(self.n_bins, dtype=np.int64)

    def fold(self, values) -> None:
        v = np.atleast_1d(np.asarray(values, dtype=np.float64))
        if v.size == 0:
            return
        if np.any(v <= 0.0) or not np.all(np.isfinite(v)):
            raise ValueError("TailSketch folds positive finite latencies")
        idx = ((np.log(v) - self._log_lo) * self._scale).astype(np.int64)
        np.clip(idx, 0, self.n_bins - 1, out=idx)
        self.counts += np.bincount(idx, minlength=self.n_bins)

    @property
    def count(self) -> int:
        return int(self.counts.sum())

    def merge(self, other: "TailSketch") -> "TailSketch":
        if (other.n_bins, other.lo, other.hi) != (self.n_bins, self.lo,
                                                  self.hi):
            raise ValueError("cannot merge sketches of different geometry")
        self.counts += other.counts
        return self

    def quantile(self, q: float) -> float:
        return float(self.quantiles((q,))[0])

    def quantiles(self, qs: Iterable[float]) -> np.ndarray:
        """Geometric bin midpoints, the same read-out as the engine's
        ``sketch_quantiles`` (first bin where the cdf reaches q% of the
        mass). NaN when the sketch is empty."""
        qs = np.asarray(list(qs), dtype=np.float64)
        cdf = np.cumsum(self.counts)
        total = cdf[-1]
        if total == 0:
            return np.full(qs.shape, np.nan)
        targets = qs / 100.0 * total
        idx = np.searchsorted(cdf, targets, side="left")
        idx = np.minimum(idx, self.n_bins - 1)
        return np.exp(self._log_lo + (idx + 0.5) / self._scale)


@dataclasses.dataclass
class RequestRecord:
    """Per-request telemetry row. Timestamps are whatever clock the
    owner feeds (wall seconds for the live service, virtual seconds in
    trace replay); NaN marks events that have not happened."""

    rid: int
    t_arrival: float
    t_dispatch: float = float("nan")
    t_first_done: float = float("nan")
    t_cancel: float = float("nan")
    k_planned: int = 1
    hedged: bool = False
    shed: bool = False
    copies_started: int = 0
    copies_cancelled: int = 0
    completed_by: str = ""

    @property
    def latency(self) -> float:
        return self.t_first_done - self.t_arrival


_PCTS = (50.0, 99.0, 99.9)
_PCT_KEYS = ("p50", "p99", "p999")


class Telemetry:
    """Streaming per-request metrics for a serving run.

    Thread-safe. Completed latencies fold into one overall ``TailSketch``
    plus one sketch per ``window_s`` of ARRIVAL time (windowing by
    arrival keeps a window's population independent of how long its
    requests took — the open-loop view). ``json_rows()`` emits the
    windowed p50/p99/p999 trajectory; ``provenance()`` the run-level
    summary dict benchmarks attach to their JSON rows.
    """

    def __init__(self, window_s: float = 10.0, n_bins: int = DEFAULT_BINS,
                 lo: float = HIST_LO, hi: float = HIST_HI):
        self.window_s = float(window_s)
        self._geometry = (int(n_bins), float(lo), float(hi))
        self._lock = threading.Lock()
        self._records: dict[int, RequestRecord] = {}
        self._done: list[RequestRecord] = []
        self.overall = TailSketch(n_bins, lo, hi)
        self._windows: dict[int, TailSketch] = {}
        self._t0: float | None = None
        self.counters = {"arrivals": 0, "completions": 0, "hedged": 0,
                         "shed": 0, "cancelled_copies": 0, "timeouts": 0,
                         "failures": 0}

    # ------------------------------------------------------------------
    def note_arrival(self, rid: int, t: float, k_planned: int = 1) -> None:
        with self._lock:
            if self._t0 is None:
                self._t0 = t
            self._records[rid] = RequestRecord(rid=rid, t_arrival=t,
                                               k_planned=k_planned)
            self.counters["arrivals"] += 1

    def note_dispatch(self, rid: int, t: float, k_planned: int,
                      shed: bool = False) -> None:
        with self._lock:
            r = self._records.get(rid)
            if r is None:
                return
            r.t_dispatch = t
            r.k_planned = int(k_planned)
            r.copies_started += 1
            if shed and not r.shed:
                r.shed = True
                self.counters["shed"] += 1

    def note_hedge(self, rid: int, n_copies: int = 1) -> None:
        with self._lock:
            r = self._records.get(rid)
            if r is None:
                return
            r.copies_started += int(n_copies)
            if not r.hedged:
                r.hedged = True
                self.counters["hedged"] += 1

    def note_completion(self, rid: int, t: float,
                        completed_by: str = "") -> None:
        with self._lock:
            r = self._records.pop(rid, None)
            if r is None:
                return
            r.t_first_done = t
            r.completed_by = completed_by
            self._done.append(r)
            self.counters["completions"] += 1
            lat = r.latency
            if lat > 0.0 and np.isfinite(lat):
                self.overall.fold(lat)
                w = int((r.t_arrival - self._t0) // self.window_s)
                sk = self._windows.get(w)
                if sk is None:
                    sk = self._windows[w] = TailSketch(*self._geometry)
                sk.fold(lat)

    def note_cancel(self, rid: int, t: float, n_copies: int = 1,
                    timeout: bool = False) -> None:
        """Record loser cancellations. O(1): only LIVE records are
        annotated, so for a completing request this must be called
        BEFORE ``note_completion`` (the service does) — once a record
        is folded into the sketches it is immutable, and scanning the
        done list for it would serialize the completion path behind an
        O(n) walk."""
        with self._lock:
            r = self._records.get(rid)
            if r is not None:
                r.t_cancel = t
                r.copies_cancelled += int(n_copies)
            self.counters["cancelled_copies"] += int(n_copies)
            if timeout:
                self.counters["timeouts"] += 1

    def note_failure(self, rid: int, t: float) -> None:
        """Every copy of ``rid`` errored: there is no completion to
        fold, so drop the live record and count the failure."""
        with self._lock:
            self._records.pop(rid, None)
            self.counters["failures"] += 1

    # ------------------------------------------------------------------
    def records(self) -> list[RequestRecord]:
        with self._lock:
            return list(self._done)

    def latencies(self) -> np.ndarray:
        with self._lock:
            return np.asarray([r.latency for r in self._done])

    def tail(self, q: float) -> float:
        with self._lock:
            return self.overall.quantile(q)

    def json_rows(self) -> list[dict]:
        """One JSON-ready row per arrival window: count + p50/p99/p999
        from that window's sketch — the streaming latency trajectory."""
        with self._lock:
            rows = []
            for w in sorted(self._windows):
                sk = self._windows[w]
                qs = sk.quantiles(_PCTS)
                rows.append({"window": w,
                             "t_start": (self._t0 or 0.0)
                             + w * self.window_s,
                             "count": sk.count,
                             **{k: float(v)
                                for k, v in zip(_PCT_KEYS, qs)}})
            return rows

    def provenance(self) -> dict:
        with self._lock:
            qs = self.overall.quantiles(_PCTS)
            return {**self.counters,
                    "windows": len(self._windows),
                    "window_s": self.window_s,
                    **{k: float(v) for k, v in zip(_PCT_KEYS, qs)}}
