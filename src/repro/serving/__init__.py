"""Serving: replica engines + the hedged (redundant-dispatch) scheduler."""
