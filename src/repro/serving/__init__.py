"""Serving: replica engines, the hedged (redundant-dispatch) scheduler,
and the adaptive batched service (controller + trace replay + telemetry)."""
