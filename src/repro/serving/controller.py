"""Online replication control from precomputed engine sweeps.

Closed-loop serving — design note
---------------------------------

The paper's help/hurt boundary moves with load (§2.1: the threshold
load) and with the service distribution, so a FIXED replication factor
is wrong somewhere on any diurnal load curve. Shah et al. and Joshi et
al. (PAPERS.md) sharpen this: the right policy must be chosen from the
measured operating point. This module closes that loop: the sweep
engine *precomputes* the whole operating surface offline, and a pure-
numpy controller *interpolates* it online.

Policy-table contract
    ``threshold.policy_table`` runs ONE mixed-grid ``queueing.run``
    sweep over a (rho x k x hedge-delay) grid: variant 0 is the bare
    k=1 baseline, the rest are ``HEDGE_AFTER_DELAY`` at each candidate
    delay (delay 0 degenerates bit-identically to the paper's immediate
    replicate-all). Every column shares the engine's CRN draws, so the
    per-rho ranking is a paired comparison. ``PolicyTable`` wraps the
    resulting numpy arrays; ``predict_tail(rho)`` linearly interpolates
    each variant's tail column between grid loads (clamped at the grid
    edges), and ``best(rho)`` is the argmin variant. Everything at
    serve time is numpy on ~(B x V) arrays — there is NO JAX dispatch
    on the request hot path; JAX ran once, offline, in the sweep.

    Units: the engine's clock is mean service times. The controller
    converts with the replicas' measured/known mean service seconds:
    offered load rho = arrival_rate * mean_service_s / n_replicas, and
    a table delay d becomes ``d * mean_service_s`` seconds of hedge
    timer.

Window semantics
    The load estimate is FEED-FORWARD: offered load comes from a
    sliding window of arrival timestamps (``LoadTracker.arrival_rate``,
    amortized O(1)), which the controller's own hedging cannot inflate
    — duplicating requests changes utilization, not the arrival
    process. Utilization still matters as a capacity guard: a stalled
    or lost replica shrinks effective capacity without changing
    arrivals, so the estimate is

        rho_hat = max(offered_load, busy_fraction / k_eff)

    where ``k_eff`` is the windowed copies-per-request actually
    dispatched (``LoadTracker.copies_per_request``) and the busy
    fraction is SAMPLED AT ARRIVALS and averaged over the decision
    stride — by PASTA an unbiased time average, where a single
    instantaneous snapshot of a small pool (say 6 of 8 replicas busy
    in a Poisson burst at light load) is noisy enough to flip the
    policy on its own. Dividing by k_eff
    removes the controller's own replication from the busy signal —
    without it, hedging at mid load would read as high load, step k
    down, read low again, and flap. With it, the busy term only
    dominates when capacity is genuinely impaired (the chaos segment in
    ``examples/serve_hedged.py``: a stalled replica pins a worker, busy
    rises, k steps down).

Hysteresis semantics
    ``decide`` switches from the current variant to the table argmin
    only when the predicted tail improves by at least ``hysteresis``
    (relative): near-ties — where sweep noise, sketch resolution and
    estimator jitter live — never cause flapping, while a genuine
    regime change (the diurnal peak) clears the margin in one decision.
    Decisions are taken every ``decision_stride`` arrivals, so decision
    cost amortizes to a deque append per request.

CRN seeding of the replay
    The trace replay (``repro.serving.replay``) that exercises this
    controller is deterministic end to end: arrival traces, per-request
    service draws and replica picks are all pre-drawn from
    ``np.random.default_rng`` children of one seed, and a request's
    draws are indexed by (request id, copy index) — NOT by dispatch
    order. Adaptive and static runs over the same trace therefore see
    identical service times for the same (request, copy), the serving
    twin of the engine's common-random-numbers contract, which makes
    adaptive-vs-static tail comparisons paired and the same-seed replay
    bit-reproducible.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Mapping

import numpy as np

from repro.core.hedging import LoadTracker


class PolicyTable:
    """Pure-numpy view of a ``threshold.policy_table`` sweep."""

    def __init__(self, rhos, k, delay, tail, mean=None,
                 percentile: float = 99.0):
        self.rhos = np.asarray(rhos, dtype=np.float64)
        self.k = np.asarray(k, dtype=np.int64)
        self.delay = np.asarray(delay, dtype=np.float64)
        self.tail = np.asarray(tail, dtype=np.float64)
        self.mean = (np.asarray(mean, dtype=np.float64)
                     if mean is not None else None)
        self.percentile = float(percentile)
        b, v = self.tail.shape
        if (self.rhos.shape != (b,) or self.k.shape != (v,)
                or self.delay.shape != (v,)):
            raise ValueError(
                f"inconsistent table shapes: rhos {self.rhos.shape}, "
                f"k {self.k.shape}, delay {self.delay.shape}, "
                f"tail {self.tail.shape}")
        if b < 1 or np.any(np.diff(self.rhos) <= 0):
            raise ValueError("policy-table rhos must be increasing")

    @classmethod
    def from_sweep(cls, table: Mapping) -> "PolicyTable":
        """Wrap the dict returned by ``threshold.policy_table``."""
        return cls(table["rhos"], table["k"], table["delay"],
                   table["tail"], table.get("mean"),
                   table.get("percentile", 99.0))

    @property
    def n_variants(self) -> int:
        return self.tail.shape[1]

    def entry(self, v: int) -> tuple[int, float]:
        """(k, delay-in-service-units) of variant ``v``."""
        return int(self.k[v]), float(self.delay[v])

    def predict_tail(self, rho: float) -> np.ndarray:
        """(V,) predicted tail at ``rho``: per-variant linear
        interpolation between grid loads, clamped at the edges."""
        rho = float(np.clip(rho, self.rhos[0], self.rhos[-1]))
        i = int(np.searchsorted(self.rhos, rho, side="right")) - 1
        i = min(max(i, 0), len(self.rhos) - 2) if len(self.rhos) > 1 else 0
        if len(self.rhos) == 1:
            return self.tail[0].copy()
        x0, x1 = self.rhos[i], self.rhos[i + 1]
        w = (rho - x0) / (x1 - x0)
        return (1.0 - w) * self.tail[i] + w * self.tail[i + 1]

    def best(self, rho: float) -> int:
        return int(np.argmin(self.predict_tail(rho)))

    def to_json(self) -> dict:
        return {"rhos": self.rhos.tolist(), "k": self.k.tolist(),
                "delay": self.delay.tolist(), "tail": self.tail.tolist(),
                "percentile": self.percentile}


@dataclasses.dataclass
class Decision:
    t: float
    rho_hat: float
    variant: int
    k: int
    delay: float          # engine units (mean service times)
    switched: bool


class AdaptiveController:
    """Set (k, hedge delay) live from a ``PolicyTable`` and a measured
    operating point. Thread-safe; serve-time cost is a deque append per
    arrival plus one small numpy interpolation per ``decision_stride``
    arrivals. See the module design note for the load-estimate and
    hysteresis semantics."""

    def __init__(self, table: PolicyTable, n_replicas: int,
                 mean_service_s: float = 1.0, *,
                 tracker: LoadTracker | None = None,
                 window_s: float | None = None,
                 hysteresis: float = 0.15,
                 decision_stride: int = 32,
                 initial_rho: float = 0.0):
        if not 0.0 <= float(hysteresis) < 1.0:
            raise ValueError(f"hysteresis must be in [0, 1), "
                             f"got {hysteresis}")
        self.table = table
        self.n_replicas = int(n_replicas)
        self.mean_service_s = float(mean_service_s)
        if window_s is None:
            # ~100 mean service times: long enough to average Poisson
            # noise, short enough to track a diurnal segment change
            # within a few hundred arrivals.
            window_s = 100.0 * self.mean_service_s
        self.tracker = tracker or LoadTracker(n_replicas,
                                              window_s=float(window_s))
        self.hysteresis = float(hysteresis)
        self.decision_stride = max(int(decision_stride), 1)
        self._lock = threading.Lock()
        self._since_decision = 0
        self._busy_sum = 0.0
        self._busy_n = 0
        self._variant = table.best(float(initial_rho))
        self.history: list[Decision] = []
        self.switches = 0
        self.decisions = 0

    # ------------------------------------------------------------------
    @property
    def current_variant(self) -> int:
        with self._lock:
            return self._variant

    def current(self) -> tuple[int, float]:
        """(k, hedge_delay_SECONDS) of the current operating point."""
        k, d = self.table.entry(self.current_variant)
        return min(k, self.n_replicas), d * self.mean_service_s

    def load_estimate(self, t: float | None = None,
                      busy_fraction: float | None = None) -> float:
        """rho_hat = max(offered load, busy / k_eff) — see design note.
        ``busy_fraction`` defaults to the stride-averaged arrival
        samples (unbiased time average by PASTA); a single snapshot of
        an 8-replica pool is far too noisy to switch policies on."""
        offered = (self.tracker.arrival_rate(t) * self.mean_service_s
                   / max(self.n_replicas, 1))
        if busy_fraction is None:
            with self._lock:
                if self._busy_n:
                    busy_fraction = self._busy_sum / self._busy_n
                    self._busy_sum = 0.0
                    self._busy_n = 0
            if busy_fraction is None:
                busy_fraction = self.tracker.utilization()
        k_eff = self.tracker.copies_per_request(t)
        return max(offered, float(busy_fraction) / k_eff)

    def on_arrival(self, t: float | None = None,
                   busy_fraction: float | None = None) -> tuple[int, float]:
        """Hot-path entry: note the arrival, sample the busy fraction,
        re-decide every ``decision_stride`` arrivals, return
        (k, hedge_delay_s)."""
        self.tracker.note_arrival(t)
        if busy_fraction is None:
            busy_fraction = self.tracker.utilization()
        with self._lock:
            self._busy_sum += float(busy_fraction)
            self._busy_n += 1
            self._since_decision += 1
            due = self._since_decision >= self.decision_stride
            if due:
                self._since_decision = 0
        if due:
            self.decide(t)
        return self.current()

    def note_dispatch(self, n_copies: int, t: float | None = None) -> None:
        self.tracker.note_copies(n_copies, t)

    def decide(self, t: float | None = None,
               busy_fraction: float | None = None) -> tuple[int, float]:
        """Force a decision now (normally driven by ``on_arrival``)."""
        rho_hat = self.load_estimate(t, busy_fraction)
        pred = self.table.predict_tail(rho_hat)
        with self._lock:
            cur = self._variant
            cand = int(np.argmin(pred))
            switched = (cand != cur and
                        pred[cand] < (1.0 - self.hysteresis) * pred[cur])
            if switched:
                self._variant = cand
                self.switches += 1
            self.decisions += 1
            k, d = self.table.entry(self._variant)
            self.history.append(Decision(
                t=float(t) if t is not None else float("nan"),
                rho_hat=float(rho_hat), variant=self._variant,
                k=k, delay=d, switched=switched))
        return self.current()

    def provenance(self) -> dict:
        with self._lock:
            ks = [h.k for h in self.history]
            return {"decisions": self.decisions,
                    "switches": self.switches,
                    "variant": self._variant,
                    "k_min": min(ks) if ks else None,
                    "k_max": max(ks) if ks else None,
                    "hysteresis": self.hysteresis,
                    "window_s": self.tracker.window_s,
                    "percentile": self.table.percentile}
