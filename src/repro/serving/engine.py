"""Single-replica inference engine: jitted prefill + greedy decode.

One engine = one replica (one model copy on its device set); request-level
parallelism comes from the scheduler dispatching across replicas — which is
exactly the granularity the paper's redundancy operates at. Cancellation is
checked between decode steps (a duplicate whose sibling finished stops
burning compute). On this CPU container engines run real (smoke-sized)
models; the hedged-serving benchmarks additionally use ``SimulatedEngine``
with paper-calibrated service-time distributions.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode as dec
from repro.models import lm

PyTree = Any


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray              # prompt (S,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    priority: int = 0               # 0 = primary, 1 = duplicate (paper §2.4)
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done_event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    cancelled: bool = False
    completed_by: str = ""
    failed: bool = False            # every issued copy errored


class InferenceEngine:
    """One model replica: batched prefill + greedy decode (single-slot
    batching; the scheduler parallelizes across replicas)."""

    def __init__(self, cfg: ModelConfig, params: PyTree, max_len: int = 128,
                 name: str = "replica0"):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.name = name
        self._prefill = jax.jit(
            lambda p, b: dec.prefill(p, cfg, b, max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: dec.decode_step(p, cfg, c, t, pos))

    def generate(self, prompt: np.ndarray, max_new_tokens: int = 16,
                 check_cancel: Callable[[], bool] | None = None
                 ) -> np.ndarray | None:
        toks = jnp.asarray(prompt, dtype=jnp.int32)[None]
        logits, cache = self._prefill(self.params, {"tokens": toks})
        out = []
        pos = toks.shape[1]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (1,)
        out.append(int(tok[0]))
        for _ in range(max_new_tokens - 1):
            if check_cancel is not None and check_cancel():
                return None
            logits, cache = self._decode(self.params, cache, tok[None],
                                         jnp.int32(pos))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(int(tok[0]))
            pos += 1
        return np.asarray(out, dtype=np.int32)


class SimulatedEngine:
    """Replica with a service-time model instead of real compute — the
    serving-layer analogue of the paper's queueing-model servers. Service
    times are drawn per request from ``sampler()`` (seconds)."""

    def __init__(self, sampler: Callable[[], float], name: str = "sim0"):
        self.sampler = sampler
        self.name = name

    def generate(self, prompt: np.ndarray, max_new_tokens: int = 16,
                 check_cancel: Callable[[], bool] | None = None):
        t_service = float(self.sampler())
        deadline = time.monotonic() + t_service
        while time.monotonic() < deadline:
            if check_cancel is not None and check_cancel():
                return None
            time.sleep(min(0.0005, max(deadline - time.monotonic(), 0.0)))
        return np.asarray([0] * max_new_tokens, dtype=np.int32)
