"""Fault injection for the serving layer.

``FaultInjector`` wraps any engine (real ``InferenceEngine``,
``SimulatedEngine``, anything with ``generate``) in a proxy whose
behaviour the injector can change at runtime — the serving-layer
analogue of the engine's degradation model (``core.scenario``):

  ``crash``  every ``generate`` raises ``ReplicaCrashed`` — the
             blackhole: the copy never responds and the scheduler's
             redundancy must mask it.
  ``stall``  ``generate`` blocks (checking cancellation) until the
             replica is healed — a hung replica rather than a dead one;
             distinguishable from crash because it pins a worker.
  ``slow``   service time is inflated by a factor — the straggler
             (the proxy times the inner call and pads the difference,
             so it works for real engines, not just simulated ones).

Faults are keyed by replica name, can be scheduled in the future
(``after=`` seconds, a daemon timer), and are reversible (``heal``).
The proxies stay valid across fault changes, so a chaos test can flip
one replica between healthy/slow/crashed mid-trace without touching
the scheduler.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable

STATE_OK = "ok"
STATE_CRASH = "crash"
STATE_STALL = "stall"
STATE_SLOW = "slow"


class ReplicaCrashed(RuntimeError):
    """Raised by a crashed replica's ``generate`` — the scheduler's
    workers treat any exception as a masked replica failure."""


class FaultyEngine:
    """Proxy engine: delegates to ``inner`` subject to the injector's
    current fault state for this replica name."""

    def __init__(self, inner: Any, injector: "FaultInjector"):
        self.inner = inner
        self.injector = injector
        self.name = getattr(inner, "name", repr(inner))

    def generate(self, tokens, max_new_tokens: int = 16,
                 check_cancel: Callable[[], bool] | None = None):
        state, factor = self.injector.state(self.name)
        if state == STATE_CRASH:
            raise ReplicaCrashed(self.name)
        if state == STATE_STALL:
            # hang until healed (or the copy is cancelled); re-dispatch
            # to the inner engine once healthy again
            while True:
                if check_cancel is not None and check_cancel():
                    return None
                state, factor = self.injector.state(self.name)
                if state == STATE_CRASH:
                    raise ReplicaCrashed(self.name)
                if state != STATE_STALL:
                    break
                time.sleep(0.001)
        t0 = time.monotonic()
        out = self.inner.generate(tokens, max_new_tokens,
                                  check_cancel=check_cancel)
        if state == STATE_SLOW and out is not None:
            # pad to factor x the measured service time, cancellable
            extra = (time.monotonic() - t0) * (factor - 1.0)
            deadline = time.monotonic() + extra
            while time.monotonic() < deadline:
                if check_cancel is not None and check_cancel():
                    return None
                time.sleep(min(0.0005,
                               max(deadline - time.monotonic(), 0.0)))
        return out


class FaultInjector:
    """Runtime fault switchboard for a set of wrapped replicas."""

    def __init__(self):
        self._lock = threading.Lock()
        self._state: dict[str, tuple[str, float]] = {}

    def wrap(self, engine: Any) -> FaultyEngine:
        return FaultyEngine(engine, self)

    def state(self, name: str) -> tuple[str, float]:
        with self._lock:
            return self._state.get(name, (STATE_OK, 1.0))

    def _set(self, name: str, state: str, factor: float,
             after: float) -> None:
        def apply():
            with self._lock:
                self._state[name] = (state, factor)
        if after > 0:
            t = threading.Timer(after, apply)
            t.daemon = True
            t.start()
        else:
            apply()

    def crash(self, name: str, after: float = 0.0) -> None:
        self._set(name, STATE_CRASH, 1.0, after)

    def stall(self, name: str, after: float = 0.0) -> None:
        self._set(name, STATE_STALL, 1.0, after)

    def slow(self, name: str, factor: float, after: float = 0.0) -> None:
        if factor < 1.0:
            raise ValueError(f"slow factor must be >= 1, got {factor}")
        self._set(name, STATE_SLOW, float(factor), after)

    def heal(self, name: str, after: float = 0.0) -> None:
        self._set(name, STATE_OK, 1.0, after)
