"""Batched hedged serving — fixed-shape entry points, pooled buffers.

``HedgedScheduler.submit`` is a BLOCKING call: every in-flight request
pins a submitter thread for its whole lifetime, and every hedge delay
is a thread parked in ``Event.wait``. Fine for tens of concurrent
requests; hopeless for an open-loop trace at thousands in flight. This
module rebuilds the entry path in the batch-service idiom (fixed batch
sizes registered up front, reusable pinned transfer buffers, one
dispatch thread per replica group):

  * ``submit`` / ``submit_batch`` are NON-blocking and O(1): they stamp
    telemetry, copy prompts into a pooled ``TransferBuffer`` (batch
    path), and append to a group inbox. No thread is created per
    request — the paper's k-fold duplication happens on the dispatcher,
    not on k caller threads.
  * one dispatcher thread per REPLICA GROUP drains its inbox, asks the
    ``AdaptiveController`` (or the static knobs) for (k, hedge_delay),
    applies the shed watermark from the shared ``LoadTracker``, and
    enqueues copies on the group's ``ReplicaWorker``s — the same
    two-level priority workers the blocking scheduler uses, reused via
    their owner protocol (``tied_cancel`` / ``tracker`` /
    ``_on_copy_done``).
  * delayed hedges park in ONE timer heap serviced by one timer thread
    for the whole service, not one waiting thread per request; first
    completion finalizes the request from the worker's callback and
    cancels queued losers. Single replica failures are masked by
    surviving copies; a request whose copies ALL error (and with no
    hedge left to fire) is finalized as FAILED — ``result`` raises
    instead of blocking its waiter forever.

Batch sizes are FIXED at construction: ``submit_batch`` picks the
smallest registered size that fits and pads, so buffer shapes (and any
downstream compiled entry points) never vary at serve time — requests
ride pre-allocated memory end to end.
"""
from __future__ import annotations

import collections
import heapq
import itertools
import threading
import time
from typing import Any, Sequence

import numpy as np

from repro.core.hedging import HedgePolicy, LoadTracker
from repro.serving.controller import AdaptiveController
from repro.serving.engine import Request
from repro.serving.metrics import Telemetry
from repro.serving.scheduler import (PRIORITY_HIGH, PRIORITY_LOW, ReplicaWorker,
                                     _Copy)


class TransferBuffer:
    """One reusable fixed-shape staging buffer: ``(batch_size, max_seq)``
    int32 token block plus per-row lengths. Callers write rows, the
    dispatcher reads them back out; the pool recycles the memory."""

    __slots__ = ("tokens", "lengths", "batch_size", "max_seq", "in_use")

    def __init__(self, batch_size: int, max_seq: int):
        self.batch_size = int(batch_size)
        self.max_seq = int(max_seq)
        self.tokens = np.zeros((self.batch_size, self.max_seq),
                               dtype=np.int32)
        self.lengths = np.zeros(self.batch_size, dtype=np.int32)
        self.in_use = False

    def fill(self, prompts: Sequence[np.ndarray]) -> int:
        n = len(prompts)
        if n > self.batch_size:
            raise ValueError(f"{n} prompts > batch size {self.batch_size}")
        self.lengths[:] = 0
        for i, p in enumerate(prompts):
            p = np.asarray(p, dtype=np.int32).ravel()
            if p.size > self.max_seq:
                raise ValueError(f"prompt length {p.size} > max_seq "
                                 f"{self.max_seq}")
            self.tokens[i, :p.size] = p
            self.lengths[i] = p.size
        return n

    def row(self, i: int) -> np.ndarray:
        return self.tokens[i, :int(self.lengths[i])]


class TransferBufferPool:
    """Fixed set of ``TransferBuffer``s per registered batch size.
    ``acquire`` blocks when every buffer of that size is in flight —
    natural backpressure on the BATCH path only (single-request submits
    never touch the pool)."""

    def __init__(self, batch_sizes: Sequence[int], max_seq: int,
                 buffers_per_size: int = 2):
        if not batch_sizes:
            raise ValueError("need at least one batch size")
        self.batch_sizes = tuple(sorted(set(int(b) for b in batch_sizes)))
        self._free: dict[int, list[TransferBuffer]] = {
            bs: [TransferBuffer(bs, max_seq)
                 for _ in range(int(buffers_per_size))]
            for bs in self.batch_sizes}
        self._cv = threading.Condition()

    def fit(self, n: int) -> int:
        """Smallest registered batch size >= n."""
        for bs in self.batch_sizes:
            if bs >= n:
                return bs
        raise ValueError(f"batch of {n} exceeds largest registered size "
                         f"{self.batch_sizes[-1]}")

    def acquire(self, batch_size: int, timeout: float | None = None
                ) -> TransferBuffer:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            free = self._free[batch_size]
            while not free:
                left = (None if deadline is None
                        else deadline - time.monotonic())
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"no free transfer buffer of size {batch_size}")
                self._cv.wait(timeout=left)
            buf = free.pop()
            buf.in_use = True
            return buf

    def release(self, buf: TransferBuffer) -> None:
        with self._cv:
            buf.in_use = False
            self._free[buf.batch_size].append(buf)
            self._cv.notify_all()


class _Pending:
    """Dispatcher-side state of one in-flight request.

    ``outstanding`` counts reserved copies that have neither won nor
    failed; ``hedge_pending`` marks a delayed hedge parked in the timer
    heap. Together they decide when EVERY avenue to a completion is
    exhausted (all copies failed, no hedge left to fire) so the request
    can be finalized as failed instead of leaking a waiter."""

    __slots__ = ("req", "copies", "used", "k", "hedge_delay", "lock",
                 "finalized", "group", "outstanding", "hedge_pending")

    def __init__(self, req: Request, group: int):
        self.req = req
        self.copies: list[tuple[ReplicaWorker, _Copy]] = []
        self.used: set[str] = set()
        self.k = 1
        self.hedge_delay = 0.0
        self.lock = threading.Lock()
        self.finalized = False
        self.group = group
        self.outstanding = 0
        self.hedge_pending = False


class BatchedHedgedService:
    """Non-blocking hedged service over replica groups.

    ``engines`` is partitioned round-robin into ``n_groups`` groups,
    each owning one dispatch thread and its slice of workers; a
    request's primary and duplicates stay inside one group (the
    paper's "diverse resources" are the group's distinct replicas).
    Replication policy comes from, in precedence order: an
    ``AdaptiveController`` (live (k, delay) from engine sweeps), else
    the static ``k`` / ``hedge_delay_s`` knobs, else a ``HedgePolicy``
    driven by the shared tracker's utilization. ``shed_watermark``
    reads the SAME ``LoadTracker`` the workers update — the O(1)
    signal, identical to what the controller sees.
    """

    def __init__(self, engines: Sequence[Any], *,
                 batch_sizes: Sequence[int] = (1, 4, 8),
                 max_seq: int = 64,
                 buffers_per_size: int = 2,
                 controller: AdaptiveController | None = None,
                 policy: HedgePolicy | None = None,
                 k: int = 2,
                 hedge_delay_s: float = 0.0,
                 n_groups: int = 1,
                 tracker: LoadTracker | None = None,
                 telemetry: Telemetry | None = None,
                 shed_watermark: float = 1.0,
                 tied_cancel: bool = False,
                 seed: int = 0):
        engines = list(engines)
        if not engines:
            raise ValueError("need at least one engine")
        n_groups = max(1, min(int(n_groups), len(engines)))
        self.tied_cancel = bool(tied_cancel)
        self.controller = controller
        self.policy = policy
        self.static_k = int(k)
        self.static_delay = float(hedge_delay_s)
        self.shed_watermark = float(shed_watermark)
        self.tracker = tracker or (controller.tracker if controller
                                   else LoadTracker(len(engines)))
        self.tracker.set_capacity(len(engines))
        if controller is not None and controller.tracker is not self.tracker:
            raise ValueError("controller must share the service's "
                             "LoadTracker (one load signal)")
        self.telemetry = telemetry or Telemetry()
        self.pool = TransferBufferPool(batch_sizes, max_seq,
                                       buffers_per_size)
        self.rng = np.random.default_rng(seed)
        self._rid = itertools.count()
        self._pending: dict[int, _Pending] = {}
        self._plock = threading.Lock()
        # stats are written from submitter, dispatcher, timer and worker
        # threads — mutate only through _bump (under _plock)
        self.stats = {"total": 0, "hedged": 0, "shed": 0,
                      "duplicate_wins": 0, "cancelled_copies": 0,
                      "batches": 0, "failed": 0}

        # replica groups: round-robin partition, one dispatcher each
        self._groups: list[list[ReplicaWorker]] = [[] for _ in
                                                   range(n_groups)]
        for i, e in enumerate(engines):
            w = ReplicaWorker(e, self, getattr(e, "name", f"r{i}"))
            self._groups[i % n_groups].append(w)
        self._inboxes = [collections.deque() for _ in range(n_groups)]
        self._inbox_cvs = [threading.Condition() for _ in range(n_groups)]
        self._stop = False
        self._dispatchers = [
            threading.Thread(target=self._dispatch_loop, args=(g,),
                             daemon=True, name=f"dispatch-{g}")
            for g in range(n_groups)]
        # one timer thread services every delayed hedge in the service
        self._timer_heap: list[tuple[float, int]] = []
        self._timer_cv = threading.Condition()
        self._timer = threading.Thread(target=self._timer_loop, daemon=True,
                                       name="hedge-timer")
        for t in self._dispatchers:
            t.start()
        self._timer.start()

    def _bump(self, key: str, n: int = 1) -> None:
        if n:
            with self._plock:
                self.stats[key] += n

    # ------------------------------------------------------------------
    # submission: non-blocking, O(1)
    def submit(self, tokens: np.ndarray, max_new_tokens: int = 16
               ) -> Request:
        """Enqueue one request; returns immediately. Wait on
        ``request.done_event`` (or use ``result``) for the output."""
        t = time.monotonic()
        req = Request(rid=next(self._rid),
                      tokens=np.asarray(tokens, dtype=np.int32),
                      max_new_tokens=max_new_tokens, submitted_at=t)
        self._enqueue(req, t)
        return req

    def submit_batch(self, prompts: Sequence[np.ndarray],
                     max_new_tokens: int = 16,
                     timeout: float | None = None) -> list[Request]:
        """Batch entry point: stage ``prompts`` through a pooled
        ``TransferBuffer`` of the smallest fitting registered size,
        enqueue one request per row, release the buffer. Blocks only
        when the pool for that size is exhausted (backpressure)."""
        bs = self.pool.fit(len(prompts))
        buf = self.pool.acquire(bs, timeout=timeout)
        try:
            n = buf.fill(prompts)
            t = time.monotonic()
            reqs = []
            for i in range(n):
                req = Request(rid=next(self._rid),
                              tokens=buf.row(i).copy(),
                              max_new_tokens=max_new_tokens,
                              submitted_at=t)
                reqs.append(req)
            self._bump("batches")
        finally:
            self.pool.release(buf)
        for req in reqs:
            self._enqueue(req, t)
        return reqs

    def result(self, req: Request, timeout: float | None = None
               ) -> list[int]:
        if not req.done_event.wait(timeout=timeout):
            self._cancel_request(req)
            raise TimeoutError(f"request {req.rid} timed out")
        if req.failed:
            raise RuntimeError(f"request {req.rid} failed on every "
                               "replica copy")
        return req.out_tokens

    def _enqueue(self, req: Request, t: float) -> None:
        g = req.rid % len(self._groups)
        p = _Pending(req, g)
        with self._plock:
            self.stats["total"] += 1
            self._pending[req.rid] = p
        self.telemetry.note_arrival(req.rid, t)
        if self.controller is not None:
            self.controller.on_arrival(t)
        else:
            self.tracker.note_arrival(t)
        cv = self._inbox_cvs[g]
        with cv:
            self._inboxes[g].append(p)
            cv.notify()

    # ------------------------------------------------------------------
    # dispatch: one thread per replica group
    def _decide(self) -> tuple[int, float]:
        if self.controller is not None:
            k, delay = self.controller.current()
        elif self.policy is not None:
            k, delay = self.policy.k_for(self.tracker.utilization()), \
                self.static_delay
        else:
            k, delay = self.static_k, self.static_delay
        return max(int(k), 1), float(delay)

    def _dispatch_loop(self, g: int) -> None:
        cv, inbox, workers = self._inbox_cvs[g], self._inboxes[g], \
            self._groups[g]
        while True:
            with cv:
                while not inbox and not self._stop:
                    cv.wait(timeout=0.1)
                if self._stop:
                    return
                p = inbox.popleft()
            if p.req.cancelled:
                continue
            k, delay = self._decide()
            k = min(k, len(workers))
            shed = False
            if k > 1 and self.tracker.utilization() >= self.shed_watermark:
                k, shed = 1, True
                self._bump("shed")
            p.k, p.hedge_delay = k, delay
            t = time.monotonic()
            self.telemetry.note_dispatch(p.req.rid, t, k, shed=shed)
            if self.controller is not None:
                # planned copies: the hedge may be cancelled by an early
                # completion, but capacity is provisioned for k
                self.controller.note_dispatch(k, t)
            else:
                self.tracker.note_copies(k, t)
            if k > 1 and delay <= 0.0:
                self._bump("hedged")
                self.telemetry.note_hedge(p.req.rid, k - 1)
                # reserve primary + duplicates in ONE lock section: a
                # fast-failing primary must never see outstanding==0
                # while its siblings are still on the way
                self._send_copies(
                    p, workers,
                    [PRIORITY_HIGH] + [PRIORITY_LOW] * (k - 1))
            elif k > 1:
                with p.lock:
                    p.hedge_pending = True
                self._send_copies(p, workers, [PRIORITY_HIGH])
                with self._timer_cv:
                    heapq.heappush(self._timer_heap,
                                   (t + delay, p.req.rid))
                    self._timer_cv.notify()
            else:
                self._send_copies(p, workers, [PRIORITY_HIGH])

    def _reserve_copy(self, p: _Pending, workers: list[ReplicaWorker],
                      priority: int) -> tuple[ReplicaWorker, _Copy]:
        """Pick a replica and register one copy. Caller holds ``p.lock``
        and submits the returned pair after releasing it."""
        cand = [w for w in workers if w.name not in p.used] or workers
        w = cand[int(self.rng.integers(len(cand)))]
        copy = _Copy(p.req, priority)
        p.copies.append((w, copy))
        p.used.add(w.name)
        p.outstanding += 1
        return w, copy

    def _send_copies(self, p: _Pending, workers: list[ReplicaWorker],
                     priorities: Sequence[int]) -> None:
        with p.lock:
            if p.finalized:
                return
            sends = [self._reserve_copy(p, workers, pr)
                     for pr in priorities]
        for w, copy in sends:
            w.submit(copy)

    def _timer_loop(self) -> None:
        while True:
            with self._timer_cv:
                while not self._timer_heap and not self._stop:
                    self._timer_cv.wait(timeout=0.1)
                if self._stop:
                    return
                due, rid = self._timer_heap[0]
                now = time.monotonic()
                if due > now:
                    self._timer_cv.wait(timeout=due - now)
                    continue
                heapq.heappop(self._timer_heap)
            with self._plock:
                p = self._pending.get(rid)
            if p is None:
                continue  # finalized before the hedge fired
            workers = self._groups[p.group]
            sends = []
            with p.lock:
                # clearing the flag and reserving the copies must be one
                # atomic step: with the flag down and nothing reserved, a
                # concurrently failing primary would finalize the request
                p.hedge_pending = False
                fire = not p.finalized and not p.req.done_event.is_set()
                if fire:
                    sends = [self._reserve_copy(p, workers, PRIORITY_LOW)
                             for _ in range(p.k - 1)]
            if not fire:
                continue  # completed before the hedge fired: saved work
            self._bump("hedged")
            self.telemetry.note_hedge(rid, p.k - 1)
            for w, copy in sends:
                w.submit(copy)

    # ------------------------------------------------------------------
    # completion: ReplicaWorker owner protocol
    def _on_copy_done(self, worker: ReplicaWorker, copy: _Copy,
                      won: bool) -> None:
        """Only two callers may finalize a request: its WINNING copy
        (so the latency stamp is the first completion, never a loser
        that drained later), and its LAST failing copy once no sibling
        or parked hedge can still win (so a request whose copies all
        error is surfaced as failed instead of blocking its waiter
        forever)."""
        rid = copy.req.rid
        with self._plock:
            p = self._pending.get(rid)
        if p is None:
            return
        failed = False
        with p.lock:
            if p.finalized:
                return
            if not won:
                if copy.req.done_event.is_set():
                    # loser drained after the winner set the event: the
                    # winner's own callback finalizes — pure no-op here
                    return
                p.outstanding -= 1
                if p.outstanding > 0 or p.hedge_pending:
                    return  # a sibling or a parked hedge may still win
                failed = True
            p.finalized = True
            copies = list(p.copies)
        with self._plock:
            self._pending.pop(rid, None)
        t = time.monotonic()
        cancelled = 0
        for w, c in copies:
            if c is not copy and not c.started:
                cancelled += 1
            c.cancelled = True
        self._bump("cancelled_copies", cancelled)
        if cancelled:
            self.telemetry.note_cancel(rid, t, cancelled)
        if failed:
            copy.req.failed = True
            self._bump("failed")
            self.telemetry.note_failure(rid, t)
            copy.req.done_event.set()  # unblock waiters with the failure
            return
        if copy.req.completed_by != copies[0][0].name \
                and copies[0][1].started:
            self._bump("duplicate_wins")
        copy.req.latency = t - copy.req.submitted_at  # type: ignore
        self.telemetry.note_completion(rid, t, copy.req.completed_by)

    def _cancel_request(self, req: Request) -> None:
        req.cancelled = True
        with self._plock:
            p = self._pending.pop(req.rid, None)
        if p is None:
            return
        with p.lock:
            p.finalized = True
            copies = list(p.copies)
        n = 0
        for _, c in copies:
            c.cancelled = True
            n += 1
        self.telemetry.note_cancel(req.rid, time.monotonic(), n,
                                   timeout=True)

    # ------------------------------------------------------------------
    def utilization(self) -> float:
        return self.tracker.utilization()

    def shutdown(self) -> None:
        self._stop = True
        for cv in self._inbox_cvs:
            with cv:
                cv.notify_all()
        with self._timer_cv:
            self._timer_cv.notify_all()
        for t in self._dispatchers:
            t.join(timeout=5)
        self._timer.join(timeout=5)
        for g in self._groups:
            for w in g:
                w.stop()
