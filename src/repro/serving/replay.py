"""Open-loop trace replay for the hedged serving stack.

A closed-loop driver (submit, wait, submit...) can never see the
queueing regime the paper's threshold load is ABOUT: its arrival rate
collapses to match service capacity, so overload shows up as client
slowness instead of queue growth. This module drives the serving policy
open loop — arrivals come from a pregenerated TRACE and never wait for
completions:

  * trace generators: ``poisson_trace`` (stationary), ``mmpp_trace``
    (two-state Markov-modulated bursts), ``diurnal_trace`` (piecewise
    load curve). All seeded and deterministic.
  * ``replay_virtual``: a discrete-event twin of
    ``BatchedHedgedService`` on a VIRTUAL clock — per-replica FIFO
    queues, k-fold dispatch with optional hedge delay, shed
    watermark, first-completion wins. No
    threads and no sleeping, so a million-request diurnal day replays
    in seconds and the run is bit-reproducible: service draws and
    replica picks are pre-drawn indexed by (request, copy) — the CRN
    contract that makes adaptive vs static comparisons paired (see the
    design note in ``repro.serving.controller``).
  * ``replay_live``: paces the same trace onto a real
    ``BatchedHedgedService`` (threads, wall clock) for end-to-end
    smoke runs.

Model gap, documented: by default every issued copy is served to
completion at a single priority level — the engine's (and paper's)
model, so the controller's policy table and the replay agree on the
physics; ``cancel_queued=True`` / ``dup_low_priority=True`` opt into
the live service's loser-cancellation and §2.4 low-priority-duplicate
behaviors instead. The replay does not model token-level work or
transfer buffers; it is the queueing view of the service, one level
above ``queueing.run``'s single-queue view.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import time
from typing import Sequence

import numpy as np

from repro.serving.metrics import TailSketch, _PCT_KEYS, _PCTS


@dataclasses.dataclass
class Trace:
    """An arrival trace: sorted times (seconds), per-request segment id,
    per-segment target offered load."""

    t: np.ndarray             # (N,) arrival times, non-decreasing
    segment: np.ndarray       # (N,) int segment index
    rho: np.ndarray           # (S,) per-segment offered load
    n_replicas: int
    mean_service_s: float
    kind: str = "trace"

    @property
    def n(self) -> int:
        return int(self.t.shape[0])

    @property
    def n_segments(self) -> int:
        return int(self.rho.shape[0])


def poisson_trace(n: int, rho: float, n_replicas: int,
                  mean_service_s: float = 1.0, seed: int = 0) -> Trace:
    """Stationary Poisson arrivals at offered load ``rho``."""
    rng = np.random.default_rng(seed)
    rate = float(rho) * n_replicas / mean_service_s
    t = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return Trace(t=t, segment=np.zeros(n, dtype=np.int64),
                 rho=np.asarray([float(rho)]), n_replicas=int(n_replicas),
                 mean_service_s=float(mean_service_s), kind="poisson")


def mmpp_trace(n: int, rho_lo: float, rho_hi: float, n_replicas: int,
               mean_service_s: float = 1.0, sojourn_s: float = 50.0,
               seed: int = 0) -> Trace:
    """Two-state Markov-modulated Poisson process: the trace alternates
    between a calm state (``rho_lo``) and a burst state (``rho_hi``),
    with exponential sojourns of mean ``sojourn_s`` seconds. Segment id
    is the state (0=calm, 1=burst)."""
    rng = np.random.default_rng(seed)
    rates = (float(rho_lo) * n_replicas / mean_service_s,
             float(rho_hi) * n_replicas / mean_service_s)
    ts, segs = [], []
    t0, state = 0.0, 0
    remaining = n
    while remaining > 0:
        dur = rng.exponential(sojourn_s)
        # arrivals inside this sojourn
        gaps = rng.exponential(1.0 / rates[state],
                               size=max(int(rates[state] * dur * 1.5) + 8,
                                        8))
        tt = t0 + np.cumsum(gaps)
        tt = tt[tt < t0 + dur][:remaining]
        ts.append(tt)
        segs.append(np.full(tt.shape[0], state, dtype=np.int64))
        remaining -= tt.shape[0]
        t0 += dur
        state ^= 1
    t = np.concatenate(ts)
    return Trace(t=t, segment=np.concatenate(segs),
                 rho=np.asarray([float(rho_lo), float(rho_hi)]),
                 n_replicas=int(n_replicas),
                 mean_service_s=float(mean_service_s), kind="mmpp")


def diurnal_trace(n: int, rhos: Sequence[float] = (0.15, 0.45, 0.75, 0.15),
                  n_replicas: int = 8, mean_service_s: float = 1.0,
                  seed: int = 0) -> Trace:
    """Piecewise-stationary load curve — the paper's day: night (deep
    below threshold), morning (near the crossing), peak (well above),
    night again. Requests split evenly across segments; each segment is
    Poisson at its own rho."""
    rng = np.random.default_rng(seed)
    rhos = np.asarray([float(r) for r in rhos])
    per = np.full(len(rhos), n // len(rhos), dtype=np.int64)
    per[:n - int(per.sum())] += 1
    ts, segs = [], []
    t0 = 0.0
    for s, (rho, m) in enumerate(zip(rhos, per)):
        rate = rho * n_replicas / mean_service_s
        tt = t0 + np.cumsum(rng.exponential(1.0 / rate, size=int(m)))
        ts.append(tt)
        segs.append(np.full(int(m), s, dtype=np.int64))
        t0 = tt[-1] if m else t0
    return Trace(t=np.concatenate(ts), segment=np.concatenate(segs),
                 rho=rhos, n_replicas=int(n_replicas),
                 mean_service_s=float(mean_service_s), kind="diurnal")


@dataclasses.dataclass
class ReplayResult:
    """Per-request outcome arrays of one replay (all shape (N,))."""

    trace: Trace
    latency: np.ndarray       # first-completion latency, seconds
    k_planned: np.ndarray     # replication factor chosen at dispatch
    hedged: np.ndarray        # bool: duplicates actually issued
    shed: np.ndarray          # bool: duplicates shed by the watermark
    cancelled_queued: int     # queued loser copies never started
    loser_service: float      # seconds of redundant service burned
    controller: object = None

    def tails(self, segment: int | None = None,
              qs: Sequence[float] = _PCTS) -> np.ndarray:
        lat = (self.latency if segment is None
               else self.latency[self.trace.segment == segment])
        sk = TailSketch()
        sk.fold(lat)
        return sk.quantiles(qs)

    def segment_tails(self) -> list[dict]:
        rows = []
        for s in range(self.trace.n_segments):
            mask = self.trace.segment == s
            if not mask.any():
                continue
            qs = self.tails(segment=s)
            rows.append({"segment": s, "rho": float(self.trace.rho[s]),
                         "count": int(mask.sum()),
                         "hedged_frac": float(self.hedged[mask].mean()),
                         "k_mean": float(self.k_planned[mask].mean()),
                         **{k: float(v) for k, v in zip(_PCT_KEYS, qs)}})
        return rows

    def provenance(self) -> dict:
        qs = self.tails()
        out = {"n": self.trace.n, "kind": self.trace.kind,
               "hedged": int(self.hedged.sum()),
               "shed": int(self.shed.sum()),
               "cancelled_queued": int(self.cancelled_queued),
               "loser_service_s": float(self.loser_service),
               **{k: float(v) for k, v in zip(_PCT_KEYS, qs)}}
        if self.controller is not None:
            out["controller"] = self.controller.provenance()
        return out


_COMPLETE, _HEDGE = 0, 1


def replay_virtual(trace: Trace, *, controller=None, static_k: int = 1,
                   static_delay_s: float = 0.0, shed_watermark: float = 1.0,
                   seed: int = 0, k_max: int = 2,
                   svc_sampler=None,
                   cancel_queued: bool = False,
                   dup_low_priority: bool = False) -> ReplayResult:
    """Discrete-event replay of the hedged service on a virtual clock.

    ``controller`` (an ``AdaptiveController``) is consulted per arrival
    with the virtual time and instantaneous busy fraction; without one,
    the static (k, delay) knobs apply. Service times are drawn up front
    as an (N, k_max) table indexed by (request, copy) — identical
    draws for every policy over the same (trace, seed), so results are
    paired and bit-reproducible. ``svc_sampler(rng, shape)`` overrides
    the service distribution (default: exponential at the trace's mean
    service time); pass the numpy twin of whatever ``ServiceDist`` the
    policy table was swept with so the controller's predictions and
    the replay agree on the service law.

    The DEFAULT queueing model is the engine's (and the paper's): every
    issued copy is served to completion at one priority level — the
    model ``threshold.policy_table`` sweeps, so the controller's table
    predictions and the replay physics agree. The service's two
    mitigations are opt-in knobs: ``cancel_queued`` drops queued losers
    when their request completes, ``dup_low_priority`` queues
    duplicates behind all primaries (§2.4). Turning them on reproduces
    ``BatchedHedgedService``'s behavior and softens the high-load
    penalty of replication — useful for measuring exactly how much
    those mitigations buy.
    """
    n_rep = trace.n_replicas
    N = trace.n
    if controller is not None:
        k_max = max(k_max, int(np.max(controller.table.k)))
    k_max = min(max(int(k_max), int(static_k), 1), n_rep)
    rng = np.random.default_rng(seed)
    if svc_sampler is None:
        svc = rng.exponential(trace.mean_service_s, size=(N, k_max))
    else:
        svc = np.asarray(svc_sampler(rng, (N, k_max)), dtype=np.float64)
    upick = rng.random(size=(N, k_max))

    t_arr = trace.t
    lat = np.full(N, np.nan)
    k_planned = np.ones(N, dtype=np.int64)
    hedged = np.zeros(N, dtype=bool)
    shed = np.zeros(N, dtype=bool)
    done = np.zeros(N, dtype=bool)
    pending_hedge_k = {}          # rid -> k for a parked delayed hedge
    cancelled_queued = 0
    loser_service = 0.0           # duplicate service seconds STARTED

    # per-replica state: two-level FIFO (duplicates never delay
    # primaries), one running copy each
    hi = [collections.deque() for _ in range(n_rep)]
    lo = [collections.deque() for _ in range(n_rep)]
    running = [None] * n_rep      # rid of the running copy, or None
    busy = 0

    events: list = []             # (t, seq, kind, a, b)
    seq = 0

    def start_or_queue(r: int, rid: int, c: int, low: bool) -> None:
        nonlocal busy, seq, loser_service
        if running[r] is None:
            running[r] = rid
            busy += 1
            if c > 0:
                loser_service += svc[rid, c]
            seq += 1
            heapq.heappush(events,
                           (now + svc[rid, c], seq, _COMPLETE, r, rid))
        else:
            (lo if low else hi)[r].append((rid, c))

    def dispatch(rid: int, c: int, used: list, low: bool) -> None:
        cand = [r for r in range(n_rep) if r not in used] or \
            list(range(n_rep))
        r = cand[int(upick[rid, c] * len(cand))]
        used.append(r)
        start_or_queue(r, rid, c, low and dup_low_priority)

    used_by: dict[int, list] = {}
    ai = 0
    now = 0.0
    while ai < N or events:
        ta = t_arr[ai] if ai < N else np.inf
        if events and events[0][0] <= ta:
            now, _, kind, a, b = heapq.heappop(events)
            if kind == _COMPLETE:
                r, rid = a, b
                if not done[rid]:
                    done[rid] = True
                    lat[rid] = now - t_arr[rid]
                    used_by.pop(rid, None)
                    pending_hedge_k.pop(rid, None)
                # else: a loser ran to completion (no tied cancellation)
                # free the server, start the next live copy
                running[r] = None
                busy -= 1
                for q in (hi[r], lo[r]):
                    while q:
                        nrid, nc = q.popleft()
                        if cancel_queued and done[nrid]:
                            cancelled_queued += 1
                            continue
                        running[r] = nrid
                        busy += 1
                        if nc > 0:
                            loser_service += svc[nrid, nc]
                        seq += 1
                        heapq.heappush(events, (now + svc[nrid, nc], seq,
                                                _COMPLETE, r, nrid))
                        break
                    if running[r] is not None:
                        break
            else:  # _HEDGE
                rid = a
                k = pending_hedge_k.pop(rid, None)
                if k is None or done[rid]:
                    continue  # completed first: the delay saved the work
                hedged[rid] = True
                u = used_by.get(rid, [])
                for c in range(1, k):
                    dispatch(rid, c, u, low=True)
        else:
            rid = ai
            ai += 1
            now = ta
            if controller is not None:
                k, delay_s = controller.on_arrival(now,
                                                   busy_fraction=busy
                                                   / n_rep)
            else:
                k, delay_s = int(static_k), float(static_delay_s)
            k = min(max(k, 1), n_rep)
            if k > 1 and busy / n_rep >= shed_watermark:
                k = 1
                shed[rid] = True
            k_planned[rid] = k
            if controller is not None:
                controller.note_dispatch(k, now)
            u = used_by.setdefault(rid, [])
            dispatch(rid, 0, u, low=False)
            if k > 1:
                if delay_s <= 0.0:
                    hedged[rid] = True
                    for c in range(1, k):
                        dispatch(rid, c, u, low=True)
                else:
                    pending_hedge_k[rid] = k
                    seq += 1
                    heapq.heappush(events, (now + delay_s, seq, _HEDGE,
                                            rid, 0))

    return ReplayResult(trace=trace, latency=lat, k_planned=k_planned,
                        hedged=hedged, shed=shed,
                        cancelled_queued=cancelled_queued,
                        loser_service=loser_service, controller=controller)


def replay_live(service, trace: Trace, *, max_new_tokens: int = 2,
                time_scale: float = 1.0, prompt_len: int = 4,
                timeout_s: float = 60.0) -> list:
    """Pace ``trace`` onto a real ``BatchedHedgedService`` in wall time
    (compressed by ``time_scale``): submit each request at its trace
    time, never waiting for completions (open loop), then wait for all
    of them at the end. Returns the completed ``Request`` objects."""
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 1000, size=(trace.n, prompt_len),
                           endpoint=False).astype(np.int32)
    t0 = time.monotonic()
    reqs = []
    for i in range(trace.n):
        due = t0 + trace.t[i] * time_scale
        pause = due - time.monotonic()
        if pause > 0:
            time.sleep(pause)
        reqs.append(service.submit(prompts[i],
                                   max_new_tokens=max_new_tokens))
    deadline = time.monotonic() + timeout_s
    for r in reqs:
        if not r.done_event.wait(timeout=max(deadline - time.monotonic(),
                                             0.01)):
            raise TimeoutError(f"request {r.rid} unfinished in replay")
    return reqs
