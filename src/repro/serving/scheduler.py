"""Hedged request scheduler — the paper's technique as a serving feature.

Each replica runs a worker thread draining a two-level priority queue
(strict: duplicates never delay primaries — the §2.4 mechanism one layer
up). For every incoming request the scheduler:

  1. asks the ``HedgePolicy`` for k given the ``LoadMeter``'s utilization
     (k=1 above the threshold load — "judicious redundancy", §5);
  2. enqueues the primary at HIGH priority on one replica; the k-1
     duplicates go to distinct other replicas at LOW priority — either
     immediately (``hedge_delay=0``, the paper's model) or only after
     ``hedge_delay`` seconds without a completion (Dean & Barroso's
     hedged requests — the serving analogue of the engine's
     ``HEDGE_AFTER_DELAY`` policy, with the delay chosen from engine
     sweeps via ``estimate_hedge_delay``);
  3. returns the first completion; queued (not yet started) losers are
     cancelled, and optionally running ones too (tied requests, off by
     default to match the paper's no-cancellation model).

Robustness knobs (the fault-masking story):

  * ``retry=RetryPolicy(...)`` switches a request to the NON-redundant
    baseline: one copy, resent with exponential backoff when a deadline
    passes — the strawman ``fig_fault_masking`` compares hedging
    against.
  * ``shed_watermark``: above this instantaneous utilization the
    scheduler sheds duplicates (k -> 1) regardless of the hedge policy
    — graceful degradation so redundancy never tips an overloaded
    system over (§2.1's regime change, enforced at runtime).
  * per-request deadlines (``timeout=``) cancel all outstanding copies
    and raise ``TimeoutError``.
  * ``remove_replica`` requeues the departing worker's pending copies
    on the survivors, so elastic shrink (or a chaos kill) loses no
    queued work.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
import time
from typing import Any, Sequence

import numpy as np

from repro.core.hedging import HedgePolicy, LoadMeter, LoadTracker
from repro.serving.engine import Request

PRIORITY_HIGH = 0
PRIORITY_LOW = 1


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Timeout-retry baseline: resend after ``deadline`` seconds,
    multiplying the deadline by ``backoff`` per attempt, at most
    ``max_retries`` resends (the serving twin of the engine's
    ``TIMEOUT_RETRY`` policy code and its capped backoff offsets)."""

    deadline: float
    backoff: float = 2.0
    max_retries: int = 1

    def __post_init__(self):
        if self.deadline <= 0 or self.backoff < 1 or self.max_retries < 0:
            raise ValueError(f"bad RetryPolicy {self}")


class _Copy:
    __slots__ = ("req", "priority", "cancelled", "started")

    def __init__(self, req: Request, priority: int):
        self.req = req
        self.priority = priority
        self.cancelled = False
        self.started = False


class ReplicaWorker:
    """One replica's drain thread. ``scheduler`` is any owner exposing
    ``tied_cancel`` (bool) and ``tracker`` (``LoadTracker`` busy
    accounting, updated as copies start/finish so ``utilization()`` is
    an O(1) read); an owner may additionally define
    ``_on_copy_done(worker, copy, won)`` to observe completions — the
    batched service (``repro.serving.service``) finalizes requests
    there instead of blocking a submitter thread per request."""

    def __init__(self, engine, scheduler: "HedgedScheduler", name: str):
        self.engine = engine
        self.scheduler = scheduler
        self.name = name
        self._heap: list = []
        self._counter = itertools.count()
        self._cv = threading.Condition()
        self._stop = False
        self._busy = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"worker-{name}")
        self._thread.start()

    def submit(self, copy: _Copy) -> None:
        with self._cv:
            heapq.heappush(self._heap, (copy.priority, next(self._counter),
                                        copy))
            self._cv.notify()

    def queue_depth(self) -> int:
        with self._cv:
            return len(self._heap) + (1 if self._busy else 0)

    def is_busy(self) -> bool:
        with self._cv:
            return self._busy

    def stop(self) -> list[_Copy]:
        """Idempotent. Returns the drained, never-started queue entries
        so the scheduler can requeue them on surviving replicas."""
        with self._cv:
            pending = [c for _, _, c in self._heap]
            self._heap.clear()
            already = self._stop
            self._stop = True
            self._cv.notify_all()
        if not already and self._thread.is_alive():
            self._thread.join(timeout=5)
        return pending

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._heap and not self._stop:
                    self._cv.wait(timeout=0.1)
                if self._stop:
                    return
                _, _, copy = heapq.heappop(self._heap)
                if copy.cancelled or copy.req.done_event.is_set():
                    continue  # a sibling already finished: drop silently
                copy.started = True
                self._busy = True
            tracker = getattr(self.scheduler, "tracker", None)
            if tracker is not None:
                tracker.incr_busy()
            try:
                out = self.engine.generate(
                    copy.req.tokens, copy.req.max_new_tokens,
                    check_cancel=lambda c=copy: c.cancelled or
                    (self.scheduler.tied_cancel and
                     c.req.done_event.is_set()))
            except Exception:
                out = None  # replica failure: redundancy masks it
            finally:
                with self._cv:
                    self._busy = False
                if tracker is not None:
                    tracker.decr_busy()
            won = False
            if out is not None and not copy.req.done_event.is_set():
                copy.req.out_tokens = list(map(int, out))
                copy.req.completed_by = self.name
                copy.req.done_event.set()
                won = True
            on_done = getattr(self.scheduler, "_on_copy_done", None)
            if on_done is not None:
                on_done(self, copy, won)


class HedgedScheduler:
    def __init__(self, engines: Sequence[Any],
                 policy: HedgePolicy | None = None,
                 meter: LoadMeter | None = None,
                 tied_cancel: bool = False,
                 seed: int = 0,
                 hedge_delay: float = 0.0,
                 retry: RetryPolicy | None = None,
                 shed_watermark: float = 1.0,
                 tracker: LoadTracker | None = None):
        self.policy = policy or HedgePolicy()
        self.meter = meter or LoadMeter(alpha=0.2)
        self.tied_cancel = tied_cancel
        self.rng = np.random.default_rng(seed)
        self.hedge_delay = float(hedge_delay)
        self.retry = retry
        self.shed_watermark = float(shed_watermark)
        # the ONE load signal: workers update it as copies start/finish,
        # and shed decisions + any adaptive controller read the same
        # object (see LoadTracker — utilization() is O(1), not a
        # per-request traversal of every worker's lock)
        engines = list(engines)
        self.tracker = tracker or LoadTracker(len(engines))
        self.tracker.set_capacity(len(engines))
        self._lock = threading.Lock()   # guards the workers list
        self.workers = [ReplicaWorker(e, self, getattr(e, "name", f"r{i}"))
                        for i, e in enumerate(engines)]
        self._rid = itertools.count()
        self._shutdown = False
        self.stats = {"hedged": 0, "total": 0, "duplicate_wins": 0,
                      "cancelled_copies": 0, "retries": 0, "shed": 0,
                      "requeued": 0}

    # ------------------------------------------------------------------
    # elastic replica management: replicas are independent resources, so
    # adding/removing them at runtime needs no resharding or draining —
    # a removed worker's queued copies are requeued on the survivors.
    def add_replica(self, engine: Any) -> None:
        with self._lock:
            self.workers.append(ReplicaWorker(
                engine, self,
                getattr(engine, "name", f"r{len(self.workers)}")))
            self.tracker.set_capacity(len(self.workers))

    def remove_replica(self, name: str) -> bool:
        with self._lock:
            for i, w in enumerate(self.workers):
                if w.name == name:
                    del self.workers[i]
                    victim = w
                    break
            else:
                return False
            survivors = list(self.workers)
            self.tracker.set_capacity(len(survivors))
        for copy in victim.stop():
            if copy.cancelled or copy.req.done_event.is_set():
                continue
            if survivors:
                tgt = survivors[int(self.rng.integers(len(survivors)))]
                tgt.submit(copy)
                self.stats["requeued"] += 1
        return True

    # ------------------------------------------------------------------
    def utilization(self) -> float:
        """Busy copies / replicas — an O(1) read of the shared
        ``LoadTracker`` (workers incr/decr as copies start/finish), not
        a per-request traversal of every worker's condition variable.
        The shed decision below and an adaptive controller subscribed
        to the same tracker therefore see the SAME load signal."""
        return self.tracker.utilization()

    def _dispatch(self, req: Request, priority: int, dispatched: list,
                  exclude: set[str]) -> ReplicaWorker:
        """Enqueue one copy on a random replica (avoiding ``exclude``
        names when possible) and RECORD the (worker, copy) pair — loser
        accounting must never re-index ``self.workers``, which may have
        shrunk by the time the request completes."""
        with self._lock:
            workers = list(self.workers)
        if not workers:
            raise RuntimeError("no replicas")
        cand = [w for w in workers if w.name not in exclude] or workers
        w = cand[int(self.rng.integers(len(cand)))]
        copy = _Copy(req, priority)
        dispatched.append((w, copy))
        w.submit(copy)
        return w

    def submit(self, tokens: np.ndarray, max_new_tokens: int = 16,
               timeout: float = 30.0, hedge_delay: float | None = None,
               retry: RetryPolicy | None = None) -> Request:
        """Blocking submit: dispatch, wait for the first completion (or
        the per-request deadline ``timeout``), account winners/losers.
        ``hedge_delay``/``retry`` default to the scheduler-level knobs;
        passing ``retry`` runs this request as the non-redundant
        timeout-retry baseline instead of hedging."""
        self.meter.update(self.utilization())
        hedge_delay = (self.hedge_delay if hedge_delay is None
                       else float(hedge_delay))
        retry = self.retry if retry is None else retry
        req = Request(rid=next(self._rid), tokens=tokens,
                      max_new_tokens=max_new_tokens,
                      submitted_at=time.monotonic())
        deadline_t = req.submitted_at + timeout
        dispatched: list[tuple[ReplicaWorker, _Copy]] = []
        used: set[str] = set()
        self.stats["total"] += 1

        def remaining() -> float:
            return max(deadline_t - time.monotonic(), 0.0)

        if retry is not None:
            # non-redundant baseline: one outstanding copy, resent with
            # exponential backoff on its deadline
            w = self._dispatch(req, PRIORITY_HIGH, dispatched, used)
            used.add(w.name)
            d = retry.deadline
            for _ in range(retry.max_retries):
                if req.done_event.wait(timeout=min(d, remaining())):
                    break
                if remaining() == 0.0:
                    break
                self.stats["retries"] += 1
                w = self._dispatch(req, PRIORITY_HIGH, dispatched, used)
                used.add(w.name)
                d *= retry.backoff
        else:
            k = self.policy.k_for(self.meter.utilization)
            with self._lock:
                n = len(self.workers)
            k = min(k, n)
            if k > 1 and self.utilization() >= self.shed_watermark:
                k = 1   # graceful degradation: shed duplicates
                self.stats["shed"] += 1
            w = self._dispatch(req, PRIORITY_HIGH, dispatched, used)
            used.add(w.name)
            if k > 1:
                fire = (hedge_delay <= 0.0 or
                        not req.done_event.wait(
                            timeout=min(hedge_delay, remaining())))
                if fire:
                    self.stats["hedged"] += 1
                    for _ in range(k - 1):
                        w = self._dispatch(req, PRIORITY_LOW, dispatched,
                                           used)
                        used.add(w.name)

        if not req.done_event.wait(timeout=remaining()):
            for _, c in dispatched:
                c.cancelled = True
            raise TimeoutError(f"request {req.rid} timed out")
        # cancel the losers; copies never started count as saved work
        for _, c in dispatched:
            if not c.started:
                self.stats["cancelled_copies"] += 1
            c.cancelled = True
        primary_worker, primary_copy = dispatched[0]
        if req.completed_by and primary_copy.started and \
                req.completed_by != primary_worker.name:
            self.stats["duplicate_wins"] += 1
        req.latency = time.monotonic() - req.submitted_at  # type: ignore
        return req

    def shutdown(self) -> None:
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
            workers = list(self.workers)
        for w in workers:
            w.stop()


def estimate_hedge_delay(key, dist, rho: float, cfg,
                         delays: Sequence[float] = (0.0, 0.25, 0.5, 1.0,
                                                    2.0),
                         degradation=None, n_seeds: int = 2,
                         percentile: float = 99.0) -> float:
    """Pick a hedge delay from the ENGINE, ``threshold.scenario_gain``
    style: run one mixed grid of ``HEDGE_AFTER_DELAY`` variants over
    ``delays`` at the measured load and return the delay with the best
    tail — the scheduler's ``hedge_delay`` knob fed by the same sweep
    machinery that calibrates the hedge threshold. Delays are in units
    of mean service time (the engine's clock); the caller scales by the
    replicas' measured mean service seconds.

    Since the adaptive-serving PR this is a one-row view of the SAME
    (rho x k x delay) grid ``threshold.policy_table`` sweeps for the
    online controller — one mixed-grid ``queueing.run`` call either
    way, so a fixed-``hedge_delay`` scheduler and an adaptive
    ``BatchedHedgedService`` calibrate from identical machinery."""
    from repro.core import threshold
    from repro.core.scenario import Scenario

    kw = {} if degradation is None else {"degradation": degradation}
    base = Scenario(dists=dist, ks=(2,), **kw)
    tab = threshold.policy_table(key, base, cfg, rhos=[float(rho)],
                                 ks=(2,), delays=tuple(delays),
                                 percentile=float(percentile),
                                 n_seeds=n_seeds)
    return float(tab["delay"][int(np.argmin(tab["tail"][0]))])
