"""Hedged request scheduler — the paper's technique as a serving feature.

Each replica runs a worker thread draining a two-level priority queue
(strict: duplicates never delay primaries — the §2.4 mechanism one layer
up). For every incoming request the scheduler:

  1. asks the ``HedgePolicy`` for k given the ``LoadMeter``'s utilization
     (k=1 above the threshold load — "judicious redundancy", §5);
  2. enqueues the primary at HIGH priority on one replica and k-1 duplicate
     copies at LOW priority on distinct other replicas;
  3. returns the first completion; queued (not yet started) losers are
     cancelled, and optionally running ones too (tied requests, off by
     default to match the paper's no-cancellation model).
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Sequence

import numpy as np

from repro.core.hedging import HedgePolicy, LoadMeter
from repro.serving.engine import Request

PRIORITY_HIGH = 0
PRIORITY_LOW = 1


class _Copy:
    __slots__ = ("req", "priority", "cancelled", "started")

    def __init__(self, req: Request, priority: int):
        self.req = req
        self.priority = priority
        self.cancelled = False
        self.started = False


class ReplicaWorker:
    def __init__(self, engine, scheduler: "HedgedScheduler", name: str):
        self.engine = engine
        self.scheduler = scheduler
        self.name = name
        self._heap: list = []
        self._counter = itertools.count()
        self._cv = threading.Condition()
        self._stop = False
        self.busy = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"worker-{name}")
        self._thread.start()

    def submit(self, copy: _Copy) -> None:
        with self._cv:
            heapq.heappush(self._heap, (copy.priority, next(self._counter),
                                        copy))
            self._cv.notify()

    def queue_depth(self) -> int:
        with self._cv:
            return len(self._heap) + (1 if self.busy else 0)

    def stop(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify()
        self._thread.join(timeout=5)

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._heap and not self._stop:
                    self._cv.wait(timeout=0.1)
                if self._stop:
                    return
                _, _, copy = heapq.heappop(self._heap)
            if copy.cancelled or copy.req.done_event.is_set():
                continue  # a sibling already finished: drop silently
            copy.started = True
            self.busy = True
            try:
                out = self.engine.generate(
                    copy.req.tokens, copy.req.max_new_tokens,
                    check_cancel=lambda c=copy: c.cancelled or
                    (self.scheduler.tied_cancel and
                     c.req.done_event.is_set()))
            except Exception:
                out = None  # replica failure: redundancy masks it
            finally:
                self.busy = False
            if out is not None and not copy.req.done_event.is_set():
                copy.req.out_tokens = list(map(int, out))
                copy.req.completed_by = self.name
                copy.req.done_event.set()


class HedgedScheduler:
    def __init__(self, engines: Sequence[Any],
                 policy: HedgePolicy | None = None,
                 meter: LoadMeter | None = None,
                 tied_cancel: bool = False,
                 seed: int = 0):
        self.policy = policy or HedgePolicy()
        self.meter = meter or LoadMeter(alpha=0.2)
        self.tied_cancel = tied_cancel
        self.rng = np.random.default_rng(seed)
        self.workers = [ReplicaWorker(e, self, getattr(e, "name", f"r{i}"))
                        for i, e in enumerate(engines)]
        self._rid = itertools.count()
        self.stats = {"hedged": 0, "total": 0, "duplicate_wins": 0,
                      "cancelled_copies": 0}

    # ------------------------------------------------------------------
    # elastic replica management: replicas are independent resources, so
    # adding/removing them at runtime needs no resharding or draining
    # beyond the departing worker's own queue.
    def add_replica(self, engine: Any) -> None:
        self.workers.append(
            ReplicaWorker(engine, self,
                          getattr(engine, "name", f"r{len(self.workers)}")))

    def remove_replica(self, name: str) -> bool:
        for i, w in enumerate(self.workers):
            if w.name == name:
                w.stop()
                del self.workers[i]
                return True
        return False

    # ------------------------------------------------------------------
    def utilization(self) -> float:
        busy = sum(1.0 for w in self.workers if w.busy)
        return busy / max(len(self.workers), 1)

    def submit(self, tokens: np.ndarray, max_new_tokens: int = 16,
               timeout: float = 30.0) -> Request:
        self.meter.update(self.utilization())
        k = self.policy.k_for(self.meter.utilization)
        k = min(k, len(self.workers))
        req = Request(rid=next(self._rid), tokens=tokens,
                      max_new_tokens=max_new_tokens,
                      submitted_at=time.monotonic())
        order = self.rng.permutation(len(self.workers))[:k]
        copies = []
        for j, widx in enumerate(order):
            copy = _Copy(req, PRIORITY_HIGH if j == 0 else PRIORITY_LOW)
            copies.append(copy)
            self.workers[widx].submit(copy)
        self.stats["total"] += 1
        if k > 1:
            self.stats["hedged"] += 1

        if not req.done_event.wait(timeout=timeout):
            for c in copies:
                c.cancelled = True
            raise TimeoutError(f"request {req.rid} timed out")
        # cancel the queued losers (they may never have started)
        for c in copies:
            if not c.req.done_event.is_set() or not c.started:
                if not c.started:
                    self.stats["cancelled_copies"] += 1
            c.cancelled = True
        if req.completed_by and copies[0].started and \
                req.completed_by != self.workers[order[0]].name:
            self.stats["duplicate_wins"] += 1
        req.latency = time.monotonic() - req.submitted_at  # type: ignore
        return req

    def shutdown(self) -> None:
        for w in self.workers:
            w.stop()
