"""Training launcher.

Examples:
  # ~100M-param model for a few hundred steps on CPU:
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --smoke \
      --steps 200 --seq-len 128 --batch 8

  # any assigned arch's smoke config:
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-12b --smoke \
      --steps 50

On a real TPU cluster the same entry point runs the full config against
``make_production_mesh()`` (the dry-run proves those lower + compile).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import base as cfgbase
from repro.data.pipeline import DataConfig
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=cfgbase.list_architectures())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--hedged-loader-k", type=int, default=2,
                    help="redundant data-loader copies (the paper's "
                         "technique on the input pipeline)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (cfgbase.get_smoke_config(args.arch) if args.smoke
           else cfgbase.get_config(args.arch))
    print(f"[train] arch={cfg.name} params~{cfg.param_count/1e6:.1f}M "
          f"devices={jax.device_count()}")
    dcfg = DataConfig(seq_len=args.seq_len, batch_size=args.batch,
                      seed=args.seed)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         hedged_loader_k=args.hedged_loader_k)
    trainer = Trainer(cfg, dcfg, tcfg,
                      opt=make_optimizer(cfg.optimizer, lr=args.lr))
    out = trainer.run(args.steps, seed=args.seed)
    print(f"[train] done; final loss "
          f"{out['history'][-1]['loss']:.4f}; "
          f"loader duplicate wins: {out['loader_duplicate_wins']}")


if __name__ == "__main__":
    main()
