import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
against the production mesh, and extract the roofline inputs.

For each cell this produces a JSON record with:
  * memory_analysis (bytes per device: args / outputs / temps / code),
  * cost_analysis (HLO flops / bytes accessed, per-device),
  * collective_bytes per collective kind, parsed from the optimized HLO
    (while-loop bodies are multiplied by their inferred trip counts),
so the roofline table (EXPERIMENTS.md §Roofline) is derived entirely from
compiled artifacts, not estimates.

Usage:
  python -m repro.launch.dryrun --arch nemotron-4-15b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import re
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.configs.base import SHAPES
from repro.distributed import sharding
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import decode as dec
from repro.models import lm
from repro.training.optimizer import make_optimizer
from repro.training.step import make_train_step

def _with_sharding(shapes, shardings):
    return jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        shapes, shardings)


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfgbase.get_config(arch)
    shape = SHAPES[shape_name]
    layout = sharding.make_layout(cfg, shape.kind, multi_pod,
                                  shape.global_batch)
    ctx = sharding.make_ctx(cfg, mesh, layout)

    params = sp.param_specs(cfg)
    p_sh = sharding.param_shardings(cfg, mesh, params,
                                    inference=layout.inference,
                                    ep_axes=layout.ep_axes)
    params_in = _with_sharding(params, p_sh)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer)
        opt_shapes = sp.opt_state_specs(cfg, params)
        o_sh = sharding.opt_shardings(cfg, mesh, opt_shapes, params)
        batch = sp.batch_specs(cfg, shape)
        b_sh = sharding.batch_shardings(cfg, mesh, layout, batch)
        step_fn = make_train_step(cfg, opt, ctx=ctx)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1),
                         out_shardings=(p_sh, o_sh, None))
        lowered = jitted.lower(params_in, _with_sharding(opt_shapes, o_sh),
                               _with_sharding(batch, b_sh),
                               jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        batch = sp.batch_specs(cfg, shape)
        b_sh = sharding.batch_shardings(cfg, mesh, layout, batch)

        def prefill_fn(params, batch):
            return dec.prefill(params, cfg, batch, shape.seq_len, ctx=ctx)

        jitted = jax.jit(prefill_fn)
        lowered = jitted.lower(params_in, _with_sharding(batch, b_sh))
    else:  # decode
        d = sp.decode_specs(cfg, shape)
        c_sh = sharding.cache_shardings(cfg, mesh, layout, d["cache"])
        t_sh = sharding.batch_shardings(cfg, mesh, layout,
                                        {"tokens": d["tokens"]})["tokens"]

        def serve_step(params, cache, tokens, pos):
            return dec.decode_step(params, cfg, cache, tokens, pos, ctx=ctx)

        jitted = jax.jit(serve_step, donate_argnums=(1,),
                         out_shardings=(None, c_sh))
        lowered = jitted.lower(
            params_in, _with_sharding(d["cache"], c_sh),
            jax.ShapeDtypeStruct(d["tokens"].shape, d["tokens"].dtype,
                                 sharding=t_sh),
            d["pos"])
    return lowered, cfg, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path) -> dict:
    t0 = time.time()
    record: dict = {"arch": arch, "shape": shape_name,
                    "mesh": "multi" if multi_pod else "single"}
    try:
        lowered, cfg, mesh = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        record["ok"] = True
        record["lower_s"] = round(t_lower, 1)
        record["compile_s"] = round(t_compile, 1)
        record["devices"] = mesh.size
        try:
            ma = compiled.memory_analysis()
            for field in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                record[field] = int(getattr(ma, field, 0) or 0)
        except Exception as e:  # pragma: no cover
            record["memory_analysis_error"] = str(e)
        try:
            ca = compiled.cost_analysis()
            if ca:
                record["flops"] = float(ca.get("flops", 0.0))
                record["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
                record["transcendentals"] = float(
                    ca.get("transcendentals", 0.0))
        except Exception as e:  # pragma: no cover
            record["cost_analysis_error"] = str(e)
        try:
            from repro.launch import hlo_analysis
            hlo = compiled.as_text()
            scaled = hlo_analysis.analyze(hlo)
            record["scaled_flops"] = scaled["flops"]
            record["scaled_io_bytes"] = scaled["io_bytes"]
            record["collective_bytes"] = scaled["collective_bytes"]
            record["hlo_bytes"] = len(hlo)
        except Exception as e:  # pragma: no cover
            record["hlo_error"] = str(e)
    except Exception as e:
        record["ok"] = False
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(time.time() - t0, 1)

    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}_{shape_name}_{record['mesh']}"
    (out_dir / f"{tag}.json").write_text(json.dumps(record, indent=1))
    status = "OK" if record.get("ok") else f"FAIL ({record.get('error')})"
    print(f"[dryrun] {tag}: {status} in {record['total_s']}s", flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.all:
        cells = [(a, s.name) for a in cfgbase.list_architectures()
                 for s in cfgbase.cells(a)]
    else:
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch}_{shape_name}_{'multi' if mp else 'single'}"
            if args.skip_existing and (out_dir / f"{tag}.json").exists():
                existing = json.loads((out_dir / f"{tag}.json").read_text())
                if existing.get("ok"):
                    print(f"[dryrun] {tag}: cached OK", flush=True)
                    continue
            rec = run_cell(arch, shape_name, mp, out_dir)
            n_fail += 0 if rec.get("ok") else 1
    if n_fail:
        raise SystemExit(f"{n_fail} dry-run cells failed")


if __name__ == "__main__":
    main()
