"""ShapeDtypeStruct stand-ins for every (arch x input-shape) cell.

``input_specs`` gives the model inputs (token grids, patch embeddings,
decode caches) as ShapeDtypeStructs — weak-type-correct, shardable, no
device allocation. The dry-run lowers against these.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import decode as dec
from repro.models import lm

PyTree = Any


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    """Training / prefill batch. Training batches carry S+1 tokens (shifted
    inside loss_fn); prefill batches carry the raw S-token prompt."""
    b, s = shape.global_batch, shape.seq_len
    extra = 1 if shape.kind == "train" else 0
    if cfg.family == "audio":
        return {"tokens": sds((b, s + extra, cfg.n_codebooks), jnp.int32)}
    if cfg.patch_stub is not None:
        n_p = cfg.patch_stub.n_patches
        text = s - n_p
        assert text > 0, f"{cfg.name}: seq {s} <= n_patches {n_p}"
        return {
            "tokens": sds((b, text + extra), jnp.int32),
            "patches": sds((b, n_p, cfg.patch_stub.embed_dim), jnp.float32),
        }
    return {"tokens": sds((b, s + extra), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    """Decode step inputs: one new token + a cache of seq_len positions."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(partial(dec.init_cache, cfg, b, s))
    if cfg.family == "audio":
        tokens = sds((b, 1, cfg.n_codebooks), jnp.int32)
    else:
        tokens = sds((b, 1), jnp.int32)
    return {"cache": cache, "tokens": tokens,
            "pos": sds((), jnp.int32)}


def param_specs(cfg: ModelConfig) -> PyTree:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(partial(lm.init, cfg=cfg), key)


def opt_state_specs(cfg: ModelConfig, params: PyTree) -> PyTree:
    from repro.training.optimizer import make_optimizer
    opt = make_optimizer(cfg.optimizer)
    return jax.eval_shape(opt.init, params)
