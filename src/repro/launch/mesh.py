"""Mesh construction + named-sharding rules for the sweep engine.

Functions (not module-level constants) so importing never touches jax
device state. Two mesh families live here:

  * ``make_production_mesh`` / ``make_test_mesh`` — the 2-D/3-D
    data x model meshes of the serving/training stack.
  * ``make_sweep_mesh`` — the 1-D ``"cells"`` mesh the sweep engine
    shards its flattened cell plan over. On a multi-process runtime
    (``repro.distributed.multihost.initialize``) the mesh spans EVERY
    process's devices in ``jax.devices()`` order, so shard ``i`` of the
    cell axis lives on global device ``i`` no matter which host owns it.

Mesh resolution — ONE point, every entry point rides it
-------------------------------------------------------

``resolve_mesh`` is where ``queueing.run`` (and therefore
``threshold.*``, the benchmarks, the legacy shims — everything) decides
what mesh a sweep executes on: an explicit ``mesh=`` argument wins, else
the innermost ``use_sweep_mesh`` context, else the process default that
``multihost.initialize`` installs on multi-process runtimes, else no
mesh (the single-device engine). Callers stop hand-threading ``mesh=``
through every layer: entering ``use_sweep_mesh()`` (or initializing the
multi-process runtime) reroutes every subsequent sweep through the
sharded executor.

``SweepShardingRules`` (in the spirit of scalax's ``MeshShardingHelper``)
is the one place cell placement is DECLARED rather than hand-built:
``CellPlan.sharding_rule(mesh)`` returns the rules object, and both the
shard_map specs of the chunk body and the global-array constructors for
the carry / plan-parameter / chunk-input trees read their placement from
it (cells = sharded along the plan axis, scalars = replicated). The
``put_*`` constructors build each global array from per-process local
blocks (``jax.make_array_from_single_device_arrays``), which is what
makes the SAME code path serve single-process meshes and multi-host
meshes where most of the global array is not addressable locally.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4) -> jax.sharding.Mesh:
    """Small mesh for CI tests (requires XLA host-device override)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_sweep_mesh(n_cells_axis: int | None = None, *,
                    devices=None) -> jax.sharding.Mesh:
    """1-D mesh over the sweep engine's flattened cell axis.

    ``repro.distributed.sweep_shard`` shards the (seed x load x variant)
    cell plan over the ``"cells"`` axis; the plan pads the cell count up
    to a multiple of the mesh size, so any device count serves any grid.
    ``n_cells_axis=None`` uses every visible device — including other
    processes' devices on a multi-process runtime (on CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
    importing jax to get N virtual devices per process).

    A requested ``n_cells_axis`` must divide the available device count
    (taking the first ``n`` of ``jax.devices()``): anything else raises
    a ``ValueError`` here, instead of surfacing as an opaque reshape
    error deep inside mesh construction or leaving a multi-process mesh
    that silently excludes some hosts' devices.
    """
    devs = tuple(jax.devices() if devices is None else devices)
    n = len(devs) if n_cells_axis is None else int(n_cells_axis)
    if n < 1 or n > len(devs) or len(devs) % n != 0:
        raise ValueError(
            f"n_cells_axis={n} cannot tile the {len(devs)} available "
            f"device(s): it must be >= 1 and divide the device count "
            f"evenly. On CPU, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={max(n, 1)} before "
            f"importing jax to get that many virtual devices.")
    return Mesh(np.asarray(devs[:n]), ("cells",))


# --- ambient mesh resolution (THE single resolution point) --------------

_MESH_STACK: list[jax.sharding.Mesh] = []
_DEFAULT_MESH: list[jax.sharding.Mesh | None] = [None]


def set_default_sweep_mesh(mesh: jax.sharding.Mesh | None) -> None:
    """Install (or clear) the process-wide default sweep mesh.
    ``repro.distributed.multihost.initialize`` calls this on
    multi-process runtimes so plain ``queueing.run(...)`` calls — no
    ``mesh=`` anywhere — execute sharded across all hosts."""
    _DEFAULT_MESH[0] = mesh


@contextlib.contextmanager
def use_sweep_mesh(mesh: jax.sharding.Mesh | None = None):
    """Scope an ambient sweep mesh: every ``queueing.run`` (and
    everything built on it — ``threshold.*``, the shims, benchmarks)
    inside the block executes on ``mesh`` without threading a ``mesh=``
    argument through. ``None`` builds the all-devices sweep mesh."""
    mesh = make_sweep_mesh() if mesh is None else mesh
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def resolve_mesh(mesh: jax.sharding.Mesh | None = None
                 ) -> jax.sharding.Mesh | None:
    """Resolve the mesh a sweep should execute on: explicit argument >
    innermost ``use_sweep_mesh`` > multi-process default > ``None``
    (single-device engine)."""
    if mesh is not None:
        return mesh
    if _MESH_STACK:
        return _MESH_STACK[-1]
    return _DEFAULT_MESH[0]


# --- named-sharding rules for the sweep engine's trees ------------------

@dataclasses.dataclass(frozen=True)
class SweepShardingRules:
    """Placement rules for a cell plan on a ``"cells"`` mesh.

    Obtained from ``CellPlan.sharding_rule(mesh)``. Everything keyed by
    the cell axis — the chunk-body carry, the per-cell plan parameters,
    the per-device-blocked chunk inputs — shards ``P("cells")`` along
    axis 0; chunk scalars (start / n_valid / warmup_start) replicate.
    The ``put_*`` constructors realize those rules as global arrays
    built from per-process local shards, valid on single- and
    multi-process meshes alike (shard ``i`` of the cell axis lives on
    ``mesh.devices.flat[i]``, the mesh's device order).
    """

    mesh: jax.sharding.Mesh

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def cell_spec(self) -> P:
        return P("cells")

    @property
    def scalar_spec(self) -> P:
        return P()

    @property
    def cells(self) -> NamedSharding:
        return NamedSharding(self.mesh, P("cells"))

    @property
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def local_positions(self) -> list[int]:
        """Positions along the mesh's device axis owned by THIS process
        (== the cell-axis shard indices this process materializes)."""
        pid = jax.process_index()
        return [i for i, d in enumerate(self.mesh.devices.flat)
                if d.process_index == pid]

    def put_cells(self, x) -> jax.Array:
        """Host value with axis 0 divisible by the mesh size -> global
        array sharded ``P("cells")``; this process supplies only its
        local devices' blocks."""
        x = np.asarray(x)
        per = x.shape[0] // self.n_devices
        pid = jax.process_index()
        arrs = [jax.device_put(x[i * per:(i + 1) * per], d)
                for i, d in enumerate(self.mesh.devices.flat)
                if d.process_index == pid]
        return jax.make_array_from_single_device_arrays(
            x.shape, self.cells, arrs)

    def put_blocks(self, blocks, global_shape) -> jax.Array:
        """Per-LOCAL-device blocks (ordered like ``local_positions()``)
        -> global array sharded ``P("cells")`` whose axis 0 concatenates
        every device's block. The multi-host chunk-input constructor:
        each process stages only the rows its own devices gather."""
        pid = jax.process_index()
        local = [d for d in self.mesh.devices.flat
                 if d.process_index == pid]
        arrs = [jax.device_put(b, d) for b, d in zip(blocks, local,
                                                     strict=True)]
        return jax.make_array_from_single_device_arrays(
            tuple(global_shape), self.cells, arrs)

    def put_replicated(self, x) -> jax.Array:
        """Host value -> fully replicated global array (chunk scalars)."""
        x = np.asarray(x)
        arrs = [jax.device_put(x, d) for d in self.mesh.local_devices]
        return jax.make_array_from_single_device_arrays(
            x.shape, self.replicated, arrs)
