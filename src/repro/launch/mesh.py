"""Production mesh definition.

A function (not a module-level constant) so importing never touches jax
device state. Single pod = 16 x 16 = 256 chips (v5e pod); multi-pod adds a
leading "pod" axis (2 x 16 x 16 = 512 chips) — the pod axis is the
data-center-network tier (gradient reduction across pods is hierarchical).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4) -> jax.sharding.Mesh:
    """Small mesh for CI tests (requires XLA host-device override)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_sweep_mesh(n_cells_axis: int | None = None) -> jax.sharding.Mesh:
    """1-D mesh over the sweep engine's flattened cell axis.

    ``repro.distributed.sweep_shard`` shards the (seed x load x k) cell
    plan over the ``"cells"`` axis; the plan pads the cell count up to a
    multiple of the mesh size, so any device count serves any grid.
    ``n_cells_axis=None`` uses every visible device (on CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
    importing jax to get N virtual devices).
    """
    n = len(jax.devices()) if n_cells_axis is None else int(n_cells_axis)
    return jax.make_mesh((n,), ("cells",))
