"""Serving launcher: replica group + hedged scheduler (the paper's system).

Example (CPU, smoke model, 4 replicas, redundancy on):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --replicas 4 --requests 64 --max-k 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cfgbase
from repro.core.hedging import HedgePolicy
from repro.models import lm
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import HedgedScheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=cfgbase.list_architectures())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-k", type=int, default=2)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="utilization threshold for hedging (paper: the "
                         "threshold load is in (0.26, 0.5))")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (cfgbase.get_smoke_config(args.arch) if args.smoke
           else cfgbase.get_config(args.arch))
    params = lm.init(jax.random.PRNGKey(args.seed), cfg)
    engines = [InferenceEngine(cfg, params, max_len=128, name=f"replica{i}")
               for i in range(args.replicas)]
    sched = HedgedScheduler(
        engines, policy=HedgePolicy(max_k=args.max_k,
                                    threshold=args.threshold),
        seed=args.seed)
    rng = np.random.default_rng(args.seed)
    lat = []
    try:
        for i in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
            req = sched.submit(prompt, max_new_tokens=args.max_new_tokens)
            lat.append(req.latency)
    finally:
        sched.shutdown()
    lat = np.asarray(lat)
    print(f"[serve] n={len(lat)} mean={lat.mean()*1e3:.1f}ms "
          f"p50={np.percentile(lat, 50)*1e3:.1f}ms "
          f"p99={np.percentile(lat, 99)*1e3:.1f}ms")
    print(f"[serve] stats={sched.stats}")


if __name__ == "__main__":
    main()
