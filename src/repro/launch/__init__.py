"""Launchers: production mesh, multi-pod dry-run + HLO analysis, train, serve."""
