"""Static analysis of optimized HLO text: FLOPs, HBM traffic, collective
bytes — with while-loop bodies scaled by their known trip counts.

XLA's ``compiled.cost_analysis()`` counts a while body ONCE regardless of
trip count (verified empirically), which under-reports a scanned-layer model
by ~n_layers x. This module re-derives the roofline terms from the HLO text:

  * flops: 2 * |result| * |contracting dims| for every ``dot`` (including
    dots wrapped inside fusion computations), scaled by loop trip counts
    (read from ``backend_config={"known_trip_count":{"n":...}}``).
  * io_bytes: sum over top-level materializing ops (fusion, dot, copy,
    reduce, scatter/gather, dynamic-slice/update, collectives, convert...)
    of result + operand buffer sizes — post-fusion buffers approximate HBM
    traffic. An approximation (aliasing/fusion internals ignored), stated as
    such in EXPERIMENTS.md.
  * collective_bytes: result-shape bytes per collective kind.

All values are PER DEVICE for an SPMD executable (the HLO is the per-device
partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# NOTE: tuple result types contain `/*index=N*/` comments, so the type part
# must be matched lazily up to the op name's opening paren (not `[^=]*`).
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[a-z0-9]+\[.*?)\s*"
    r"([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_PARAM_RE = re.compile(
    r"%?([\w\.\-]+):\s*([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_TRIP_RE = re.compile(r"known_trip_count[^}]*?\"n\":\"(\d+)\"")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

IO_OPS = {
    "fusion", "dot", "convolution", "custom-call", "copy", "reduce",
    "sort", "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
    "transpose", "convert", "reduce-window", "select-and-scatter", "pad",
    "concatenate", "slice", "reverse", "cbrt", "rsqrt", "exponential",
    "iota", "broadcast", "compare", "add", "multiply", "subtract", "divide",
    "tanh", "select",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_dims(text: str) -> list[list[int]]:
    return [[int(d) for d in dims.split(",") if d]
            for _, dims in _SHAPE_RE.findall(text)]


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    params: dict[str, str]
    instrs: list[Instr]


def parse_hlo(hlo: str) -> dict[str, Computation]:
    """Computation headers are non-indented lines `%name (params) -> T {`
    (optionally prefixed with ENTRY); params may contain nested tuple types,
    so the name is taken from the first token and scalar-typed params are
    regex-scanned (tuple-typed loop-carry params are resolved through their
    get-tuple-element result types instead)."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        is_header = (line and not line[0].isspace() and ") -> " in line
                     and line.rstrip().endswith("{"))
        if is_header:
            head = line.split("(", 1)[0].strip()
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            name = head.lstrip("%").strip()
            params = {p: t for p, t in _PARAM_RE.findall(
                line.rsplit(") -> ", 1)[0])}
            cur = Computation(name, params, [])
            comps[name] = cur
            continue
        if cur is None:
            continue
        mi = _DEF_RE.match(line)
        if mi:
            cur.instrs.append(Instr(mi.group(1), mi.group(2), mi.group(3),
                                    mi.group(4)))
    return comps


def _symbols(comp: Computation) -> dict[str, str]:
    syms = dict(comp.params)
    for ins in comp.instrs:
        syms[ins.name] = ins.result_type
    return syms


def _dot_flops(ins: Instr, syms: dict[str, str]) -> float:
    result_elems = 1
    dims_list = _shape_dims(ins.result_type)
    if dims_list:
        for d in dims_list[0]:
            result_elems *= d
    # lhs operand = first argument in the parens. Depending on the XLA
    # HLO printer version that is either "%name" (resolve its type via
    # the symbol table) or "type %name" (type inline, e.g.
    # "dot(f32[128,128]{1,0} %gte.3, ...)") — newer printers inline the
    # operand types, which used to collapse the contracting factor to 1.
    contract = 1
    mt = re.match(r"\(?([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s", ins.rest)
    if mt:
        lhs_type = mt.group(1)
    else:
        m = re.match(r"%?([\w\.\-]+)", ins.rest)
        lhs_type = syms.get(m.group(1), "") if m else ""
    if lhs_type:
        lhs_dims_list = _shape_dims(lhs_type)
        mcd = _CDIMS_RE.search(ins.rest)
        if lhs_dims_list and mcd:
            lhs_dims = lhs_dims_list[0]
            for ds in mcd.group(1).split(","):
                if ds:
                    idx = int(ds)
                    if idx < len(lhs_dims):
                        contract *= lhs_dims[idx]
    return 2.0 * result_elems * contract


def _operand_bytes(ins: Instr, syms: dict[str, str]) -> int:
    total = 0
    for name in re.findall(r"%([\w\.\-]+)", ins.rest.split(")", 1)[0]):
        t = syms.get(name)
        if t:
            total += _shape_bytes(t)
    return total


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    io_bytes: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def scaled(self, k: float) -> "Analysis":
        out = Analysis(self.flops * k, self.io_bytes * k)
        for key, v in self.collective_bytes.items():
            out.collective_bytes[key] = v * k
        return out

    def add(self, other: "Analysis") -> None:
        self.flops += other.flops
        self.io_bytes += other.io_bytes
        for key, v in other.collective_bytes.items():
            self.collective_bytes[key] += v


def _fusion_dot_flops(comp_name: str, comps: dict[str, Computation],
                      seen: set[str]) -> float:
    """dots nested inside fusion computations (flops only, no io)."""
    if comp_name not in comps or comp_name in seen:
        return 0.0
    seen.add(comp_name)
    comp = comps[comp_name]
    syms = _symbols(comp)
    total = 0.0
    for ins in comp.instrs:
        if ins.op == "dot":
            total += _dot_flops(ins, syms)
        mcall = _CALLS_RE.search(ins.rest)
        if mcall:
            total += _fusion_dot_flops(mcall.group(1), comps, seen)
    return total


def analyze_computation(comp: Computation, comps: dict[str, Computation],
                        memo: dict[str, Analysis]) -> Analysis:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Analysis()  # cycle guard
    syms = _symbols(comp)
    total = Analysis()
    for ins in comp.instrs:
        if ins.op == "while":
            trip = 1
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            mb = _BODY_RE.search(ins.rest)
            if mb and mb.group(1) in comps:
                body = analyze_computation(comps[mb.group(1)], comps, memo)
                total.add(body.scaled(trip))
            mcnd = _COND_RE.search(ins.rest)
            if mcnd and mcnd.group(1) in comps:
                cond = analyze_computation(comps[mcnd.group(1)], comps, memo)
                total.add(cond.scaled(trip))
            continue
        if ins.op in ("call", "conditional"):
            for cname in re.findall(r"(?:to_apply|calls)=%?([\w\.\-]+)",
                                    ins.rest):
                if cname in comps:
                    total.add(analyze_computation(comps[cname], comps, memo))
            continue
        if ins.op == "dot":
            total.flops += _dot_flops(ins, syms)
        elif ins.op == "fusion":
            mcall = _CALLS_RE.search(ins.rest)
            if mcall:
                total.flops += _fusion_dot_flops(mcall.group(1), comps, set())
        base_op = ins.op.replace("-done", "-start")
        for kind in COLLECTIVES:
            if base_op in (kind, kind + "-start"):
                if ins.op.endswith("-done"):
                    break
                total.collective_bytes[kind] += _shape_bytes(ins.result_type)
                break
        if ins.op in IO_OPS:
            if "dynamic-update-slice" in ins.name or \
                    ins.op == "dynamic-update-slice":
                # in-place aliased update (scan accumulators, cache writes):
                # real traffic is the updated slice, approximated by the
                # smallest operand, not the full buffer.
                ops = [_shape_bytes(t) for t in
                       (syms.get(nm) for nm in re.findall(
                           r"%([\w\.\-]+)", ins.rest.split(")", 1)[0]))
                       if t]
                total.io_bytes += min(ops) if ops else 0
                continue
            total.io_bytes += _shape_bytes(ins.result_type)
            total.io_bytes += _operand_bytes(ins, syms)
    memo[comp.name] = total
    return total


def analyze(hlo: str) -> dict:
    comps = parse_hlo(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m2 = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m2:
                entry = m2.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation with the most instructions
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    res = analyze_computation(comps[entry], comps, {})
    return {
        "flops": res.flops,
        "io_bytes": res.io_bytes,
        "collective_bytes": {k: int(v)
                             for k, v in res.collective_bytes.items()},
    }
