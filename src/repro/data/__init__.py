"""Deterministic data pipeline + hedged (first-of-k) prefetcher."""
