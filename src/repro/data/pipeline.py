"""Deterministic synthetic data pipeline with hedged prefetch.

Determinism contract: ``batch_at(step)`` is a pure function of
(seed, step, shard) — a restarted job consumes byte-identical data, which is
what makes checkpoint/resume bitwise-reproducible (tested).

Sources:
  * ``UniformSource`` — i.i.d. tokens (shape/perf testing).
  * ``MarkovSource`` — a fixed random bigram chain, so small models have
    learnable structure and examples show a falling loss.

Redundancy hook (the paper, applied to the input pipeline): the
``HedgedPrefetcher`` races k identical loader workers for the next batch and
takes the first to finish — masking slow/hung loader threads exactly the
way §2 masks slow servers. Batches are deterministic, so duplicates are
interchangeable by construction.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import numpy as np

from repro.configs.base import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 64
    batch_size: int = 8          # per-shard batch
    seed: int = 0
    shard: int = 0
    num_shards: int = 1


class UniformSource:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        d = self.dcfg
        rng = np.random.Generator(np.random.Philox(
            key=d.seed, counter=[step, d.shard, 0, 0]))
        shape: tuple[int, ...] = (d.batch_size, d.seq_len + 1)
        if self.cfg.family == "audio":
            shape = (*shape, self.cfg.n_codebooks)
        batch = {"tokens": rng.integers(0, self.cfg.vocab_size, shape,
                                        dtype=np.int32)}
        if self.cfg.patch_stub is not None:
            batch["patches"] = rng.standard_normal(
                (d.batch_size, self.cfg.patch_stub.n_patches,
                 self.cfg.patch_stub.embed_dim)).astype(np.float32)
        return batch


class MarkovSource:
    """Tokens from a fixed random bigram chain (learnable structure)."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig,
                 branching: int = 4):
        self.cfg = cfg
        self.dcfg = dcfg
        v = cfg.vocab_size
        rng = np.random.Generator(np.random.Philox(key=dcfg.seed + 17))
        # each token can be followed by `branching` successors
        self.successors = rng.integers(0, v, (v, branching), dtype=np.int32)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        d = self.dcfg
        rng = np.random.Generator(np.random.Philox(
            key=d.seed, counter=[step, d.shard, 0, 0]))
        b, s = d.batch_size, d.seq_len + 1
        k = self.successors.shape[1]
        toks = np.empty((b, s), dtype=np.int32)
        toks[:, 0] = rng.integers(0, self.cfg.vocab_size, b)
        choices = rng.integers(0, k, (b, s))
        for t in range(1, s):
            toks[:, t] = self.successors[toks[:, t - 1], choices[:, t]]
        return {"tokens": toks}


class HedgedPrefetcher:
    """Race ``k`` loader workers for each next batch; first result wins.

    Loader work is deterministic, so duplicates return identical batches —
    redundancy costs CPU but can only reduce the latency of a slow loader
    (the paper's trade, applied to input pipelines at k copies).
    """

    def __init__(self, source, k: int = 2, depth: int = 2,
                 start_step: int = 0):
        self.source = source
        self.k = max(1, k)
        self._results: dict[int, queue.Queue] = {}
        self._lock = threading.Lock()
        self._next_step = start_step
        self.depth = depth
        self._issued: set[int] = set()
        self.duplicate_wins = 0

    def _result_q(self, step: int) -> queue.Queue:
        with self._lock:
            if step not in self._results:
                self._results[step] = queue.Queue()
            return self._results[step]

    def _issue(self, step: int) -> None:
        if step in self._issued:
            return
        self._issued.add(step)
        q = self._result_q(step)

        def work(copy_idx: int) -> None:
            batch = self.source.batch_at(step)
            q.put((copy_idx, batch))

        for c in range(self.k):
            threading.Thread(target=work, args=(c,), daemon=True).start()

    def get(self, step: int, timeout: float = 60.0) -> PyTree:
        for s in range(step, step + self.depth + 1):
            self._issue(s)
        copy_idx, batch = self._result_q(step).get(timeout=timeout)
        if copy_idx != 0:
            self.duplicate_wins += 1
        with self._lock:
            self._results.pop(step, None)
        return batch
