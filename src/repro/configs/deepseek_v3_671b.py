"""deepseek-v3-671b [moe]: 61L d7168 128H d_ff(expert)=2048 vocab=129280.
MLA attention, 3 dense + 58 MoE layers (1 shared + 256 routed, top-8), MTP.
[arXiv:2412.19437]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
        n_heads=128, n_kv_heads=128, head_dim=128, d_ff=18432,
        vocab_size=129_280,
        prefix=("mla", "mla", "mla"), pattern=("mla_moe",),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                      d_shared=2048, first_dense=3),
        mtp=True, mlp_act="silu", gated_mlp=True, recipe="tp",
        optimizer="adafactor",  # 671B x fp32 Adam does not fit 256x16GB v5e
        long_context_ok=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="moe", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        prefix=("mla",), pattern=("mla_moe",),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                      d_shared=64, first_dense=1, capacity_factor=8.0),
        mtp=True, mlp_act="silu", gated_mlp=True, recipe="tp",
        optimizer="adafactor", long_context_ok=False)


register("deepseek-v3-671b", full, smoke)
