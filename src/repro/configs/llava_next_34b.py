"""llava-next-34b [vlm]: 60L d7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
AnyRes tiling; the vision tower is a stub — input_specs() provides
precomputed patch embeddings, the backbone owns the multimodal projector.
[hf:llava-hf/llava-v1.6-*]"""
from repro.configs.base import ModelConfig, PatchStub, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480,
        vocab_size=64_000, pattern=("global",),
        patch_stub=PatchStub(n_patches=2880, embed_dim=1024),  # anyres 5x576
        mlp_act="silu", gated_mlp=True,
        recipe="fsdp",  # 56 heads do not divide the 16-way model axis
        long_context_ok=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256, vocab_size=512,
        pattern=("global",), patch_stub=PatchStub(n_patches=8, embed_dim=32),
        mlp_act="silu", gated_mlp=True, recipe="fsdp",
        long_context_ok=False)


register("llava-next-34b", full, smoke)
