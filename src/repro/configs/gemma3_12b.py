"""gemma3-12b [dense]: 48L d3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
5:1 local:global attention, 128k context, qk-norm, dual rope bases.
[hf:google/gemma-3-*]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense", n_layers=48, d_model=3840,
        n_heads=16, n_kv_heads=8, head_dim=256, d_ff=15360,
        vocab_size=262_144,
        pattern=("local", "local", "local", "local", "local", "global"),
        window=1024, qk_norm=True, mlp_act="gelu", gated_mlp=True,
        embed_scale=True, post_norm=True, tie_embeddings=True,
        rope_theta=1_000_000.0, rope_local_theta=10_000.0, recipe="tp",
        long_context_ok=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-smoke", family="dense", n_layers=6, d_model=64,
        n_heads=2, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
        pattern=("local", "local", "local", "local", "local", "global"),
        window=16, qk_norm=True, mlp_act="gelu", gated_mlp=True,
        embed_scale=True, post_norm=True, tie_embeddings=True,
        rope_theta=1_000_000.0, rope_local_theta=10_000.0, recipe="tp",
        long_context_ok=True)


register("gemma3-12b", full, smoke)
