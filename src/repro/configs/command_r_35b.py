"""command-r-35b [dense]: 40L d8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
GQA, no biases. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense", n_layers=40, d_model=8192,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=22528,
        vocab_size=256_000, pattern=("global",), mlp_act="silu",
        gated_mlp=True, use_bias=False, rope_theta=8_000_000.0, recipe="tp",
        long_context_ok=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=2, head_dim=8, d_ff=192, vocab_size=512,
        pattern=("global",), mlp_act="silu", gated_mlp=True, recipe="tp",
        long_context_ok=False)


register("command-r-35b", full, smoke)
