"""recurrentgemma-9b [hybrid]: 38L d4096 16H (MQA kv=1) d_ff=12288
vocab=256000. Griffin pattern: (RG-LRU, RG-LRU, local attention), window
2048. [arXiv:2402.19427]"""
from repro.configs.base import ModelConfig, RGLRUConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
        n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288,
        vocab_size=256_000,
        pattern=("rec", "rec", "local"), suffix=("rec", "rec"),
        window=2048, rglru=RGLRUConfig(lru_width=4096, conv_width=4),
        mlp_act="gelu", gated_mlp=True, embed_scale=True,
        tie_embeddings=True, recipe="tp", long_context_ok=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid", n_layers=8,
        d_model=64, n_heads=2, n_kv_heads=1, head_dim=32, d_ff=256,
        vocab_size=512, pattern=("rec", "rec", "local"), suffix=("rec", "rec"),
        window=16, rglru=RGLRUConfig(lru_width=64, conv_width=4),
        mlp_act="gelu", gated_mlp=True, embed_scale=True,
        tie_embeddings=True, recipe="tp", long_context_ok=True)


register("recurrentgemma-9b", full, smoke)
