"""Architecture registry: importing this package registers all configs."""
from repro.configs import (  # noqa: F401
    command_r_35b,
    deepseek_v3_671b,
    gemma2_2b,
    gemma3_12b,
    granite_moe_3b_a800m,
    llava_next_34b,
    mamba2_370m,
    musicgen_large,
    nemotron_4_15b,
    recurrentgemma_9b,
)
from repro.configs.base import (  # noqa: F401
    SHAPES,
    InputShape,
    ModelConfig,
    cells,
    get_config,
    get_smoke_config,
    list_architectures,
)
