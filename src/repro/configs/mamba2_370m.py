"""mamba2-370m [ssm]: 48L d1024, attention-free, vocab=50280, ssm_state=128.
SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
        n_heads=32, n_kv_heads=32, head_dim=64, d_ff=0, vocab_size=50_280,
        pattern=("ssd",),
        # chunk=256 (reference). §Perf iteration m2-3 tried 64 — HBM traffic
        # ROSE 33% because the inter-chunk state tensor scales as 1/Q; the
        # fitted io(Q) = aQ + b/Q has its optimum near Q=164 with only ~9%
        # headroom, so the structural fix is the Pallas ssd_scan kernel
        # (intra-chunk tensors stay in VMEM), not chunk tuning.
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      chunk=256),
        tie_embeddings=True, recipe="tp", long_context_ok=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke", family="ssm", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=0, vocab_size=512,
        pattern=("ssd",),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=8),
        tie_embeddings=True, recipe="tp", long_context_ok=True)


register("mamba2-370m", full, smoke)
