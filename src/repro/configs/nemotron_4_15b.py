"""nemotron-4-15b [dense]: 32L d6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
GQA + squared-ReLU MLP (non-gated). [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=24576,
        vocab_size=256_000, pattern=("global",), mlp_act="relu2",
        gated_mlp=False, use_bias=False, rope_theta=10_000.0, recipe="tp",
        long_context_ok=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256, vocab_size=512,
        pattern=("global",), mlp_act="relu2", gated_mlp=False, recipe="tp",
        long_context_ok=False)


register("nemotron-4-15b", full, smoke)
