"""musicgen-large [audio]: 48L d2048 32H (MHA kv=32) d_ff=8192 vocab=2048.
Decoder-only over EnCodec tokens, 4 codebooks (delay pattern); the EnCodec
frontend is a stub — input_specs() provides the (B, S, 4) token grid.
[arXiv:2306.05284]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=2048,
        pattern=("global",), n_codebooks=4, mlp_act="gelu", gated_mlp=False,
        use_bias=True, recipe="tp", long_context_ok=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=256, vocab_size=128,
        pattern=("global",), n_codebooks=4, mlp_act="gelu", gated_mlp=False,
        use_bias=True, recipe="tp", long_context_ok=False)


register("musicgen-large", full, smoke)
