"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) vocab=49155.
40 experts top-8, d_expert=512; experts padded 40 -> 48 for 16-way EP.
[hf:ibm-granite/granite-3.0-*]"""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
        n_heads=24, n_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49_155,
        pattern=("global_moe",),
        moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
        mlp_act="silu", gated_mlp=True, tie_embeddings=True,
        recipe="fsdp",  # 24 heads do not divide the 16-way model axis
        long_context_ok=False)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke", family="moe", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
        vocab_size=512, pattern=("global_moe",),
        moe=MoEConfig(n_experts=10, top_k=2, d_expert=64,   # pads 10 -> 16
                      capacity_factor=8.0),
        mlp_act="silu", gated_mlp=True, tie_embeddings=True, recipe="fsdp",
        long_context_ok=False)


register("granite-moe-3b-a800m", full, smoke)
