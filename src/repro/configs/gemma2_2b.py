"""gemma2-2b [dense]: 26L d2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Local+global alternating attention, logit softcaps, sandwich norms.
[arXiv:2408.00118]"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family="dense", n_layers=26, d_model=2304,
        n_heads=8, n_kv_heads=4, head_dim=256, d_ff=9216, vocab_size=256_000,
        pattern=("local", "global"), window=4096, attn_softcap=50.0,
        final_softcap=30.0, mlp_act="gelu", gated_mlp=True,
        embed_scale=True, post_norm=True, tie_embeddings=True,
        recipe="fsdp",  # 8 heads do not divide the 16-way model axis
        long_context_ok=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-smoke", family="dense", n_layers=4, d_model=64,
        n_heads=2, n_kv_heads=1, head_dim=32, d_ff=256, vocab_size=512,
        pattern=("local", "global"), window=16, attn_softcap=50.0,
        final_softcap=30.0, mlp_act="gelu", gated_mlp=True, embed_scale=True,
        post_norm=True, tie_embeddings=True, recipe="fsdp",
        long_context_ok=True)


register("gemma2-2b", full, smoke)
