"""Config system: frozen dataclasses + an architecture registry.

Every assigned architecture registers a full-size config (used only by the
multi-pod dry-run, via ShapeDtypeStructs) and a ``smoke()`` reduction of the
same family (used by CPU tests: one real forward/train step).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0           # shared (always-on) experts, deepseek-style
    d_shared: int = 0           # width of the shared expert(s)
    first_dense: int = 0        # leading dense layers (deepseek: 3)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    @property
    def padded_experts(self) -> int:
        """Experts padded up so EP over a 16-way model axis divides evenly
        (granite: 40 -> 48); padded experts are masked in the router."""
        ep = 16
        return ((self.n_experts + ep - 1) // ep) * ep


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin recurrent block."""
    lru_width: int = 0          # 0 = d_model
    conv_width: int = 4
    c_exponent: float = 8.0     # the a = a_base^(c * r) temperature


@dataclasses.dataclass(frozen=True)
class PatchStub:
    """Modality frontend stub: input_specs() provides precomputed
    frame/patch embeddings of this shape; the backbone owns the projector."""
    n_patches: int
    embed_dim: int


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer pattern: prefix + pattern * repeats + suffix must cover n_layers.
    # entries: "global" | "local" | "mla" | "moe" | "mla_moe" | "ssd" | "rec"
    prefix: tuple[str, ...] = ()
    pattern: tuple[str, ...] = ("global",)
    suffix: tuple[str, ...] = ()

    window: int = 4096               # local-attention window
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    mlp_act: str = "silu"            # silu | gelu | relu2 (squared relu)
    gated_mlp: bool = True           # False => plain 2-matrix MLP
    use_bias: bool = False
    rope_theta: float = 10_000.0
    rope_local_theta: float | None = None
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d)
    post_norm: bool = False          # gemma2/3 sandwich norms
    norm_eps: float = 1e-6

    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    mtp: bool = False                # deepseek multi-token prediction head

    n_codebooks: int = 1             # musicgen: 4 parallel codebooks
    patch_stub: PatchStub | None = None

    # distribution recipe: "tp" (megatron heads/ffn over model axis) or
    # "fsdp" (batch over data x model, ZeRO params; for archs whose head
    # count does not divide the 16-way model axis)
    recipe: str = "tp"
    # training memory recipe
    optimizer: str = "adamw"         # adamw | adafactor
    remat: bool = True
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    scan_layers: bool = True

    # serving
    long_context_ok: bool = True     # False => long_500k shape is skipped

    def __post_init__(self) -> None:
        n_pat = len(self.prefix) + len(self.suffix)
        rem = self.n_layers - n_pat
        if self.pattern:
            if rem % len(self.pattern) != 0:
                raise ValueError(
                    f"{self.name}: {self.n_layers} layers cannot be tiled by "
                    f"pattern {self.pattern} + prefix/suffix {n_pat}")

    @property
    def repeats(self) -> int:
        rem = self.n_layers - len(self.prefix) - len(self.suffix)
        return rem // len(self.pattern)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 when not 16-divisible, so
        the embedding/logits can always be vocab-parallel (mamba2's 50280,
        granite's 49155). Padded logit columns are masked in the loss."""
        if self.vocab_size % 16 == 0:
            return self.vocab_size
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        return self.prefix + self.pattern * self.repeats + self.suffix

    @property
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (self.n_codebooks if self.family == "audio" else 1)
        if not self.tie_embeddings:
            total += v * d * (self.n_codebooks if self.family == "audio" else 1)
        if self.patch_stub:
            total += self.patch_stub.embed_dim * d
        for kind in self.layer_kinds:
            total += self._block_params(kind)
        return total

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds:
            total += self._block_params(kind, active=True)
        return total

    def _block_params(self, kind: str, active: bool = False) -> int:
        d = self.d_model
        n = 0
        # mixer
        if kind in ("global", "local", "global_moe"):
            n += d * self.n_heads * self.head_dim * 2  # wq, wo
            n += d * self.n_kv_heads * self.head_dim * 2  # wk, wv
        elif kind in ("mla", "mla_moe"):
            m = self.mla
            assert m is not None
            n += d * m.q_lora_rank
            n += m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            n += d * (m.kv_lora_rank + m.qk_rope_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d
        elif kind == "ssd":
            s = self.ssm
            assert s is not None
            di = s.expand * d
            n += d * (2 * di + 2 * s.d_state + di // s.head_dim)
            n += di * d
        elif kind == "rec":
            r = self.rglru
            assert r is not None
            w = r.lru_width or d
            n += d * w * 2 + w * d + w * (r.conv_width + 3)
        # mlp
        if kind in ("moe", "mla_moe", "global_moe"):
            mo = self.moe
            assert mo is not None
            per = d * mo.d_expert * (3 if self.gated_mlp else 2)
            routed = mo.top_k if active else mo.n_experts
            n += per * routed
            n += mo.n_shared * d * (mo.d_shared or mo.d_expert) * 3
            n += d * mo.n_experts  # router
        elif kind in ("global", "local", "mla", "dense", "rec"):
            n += d * self.d_ff * (3 if self.gated_mlp else 2)
        return n


# ---------------------------------------------------------------------------
# Shapes (assigned) + registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        from repro import configs  # noqa: F401 - triggers registration
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _SMOKE:
        from repro import configs  # noqa: F401
    return _SMOKE[name]()


def list_architectures() -> list[str]:
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)


def cells(name: str) -> list[InputShape]:
    """The (arch x shape) cells that are RUN for this arch; long_500k is
    skipped for pure full-attention archs (documented in DESIGN.md)."""
    cfg = get_config(name)
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.long_context_ok:
        out.append(SHAPES["long_500k"])
    return out
