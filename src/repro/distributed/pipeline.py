"""Experimental 2-stage pipeline parallelism over the ``pod`` axis.

GPipe-style: the layer stack is split into one stage per pod; microbatches
flow stage-to-stage via ``collective_permute`` under ``shard_map``. With S
stages and M microbatches the bubble fraction is (S-1)/(M+S-1) — at S=2,
M=8 that is 11%.

This exists as the scale-out alternative to pod-as-DP when the per-pod
batch would otherwise shrink below efficiency (DESIGN.md §5). The dry-run's
default multi-pod layouts use pod-as-DP; this module is exercised by its
own unit test on fake devices.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def pipeline_apply(stage_fn: Callable[[PyTree, jax.Array], jax.Array],
                   stage_params: PyTree, x: jax.Array, mesh: Mesh,
                   axis: str = "pod") -> jax.Array:
    """Run ``n_stages`` sequential stages over microbatches of ``x``.

    stage_params: pytree whose leaves have a leading n_stages dim (stage s
    uses slice s). x: (n_micro, mb, ...) microbatched input, sharded over
    ``axis`` on dim 0 is NOT required — x is passed replicated; outputs are
    returned replicated from the last stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    p_specs = jax.tree.map(lambda _: P(axis), stage_params)

    def run(p_local, x_all):
        stage = jax.lax.axis_index(axis)
        p_stage = jax.tree.map(lambda a: a[0], p_local)
        buf = jnp.zeros_like(x_all[0])          # current activation
        outs = jnp.zeros_like(x_all)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(t < n_micro, x_all[mb_idx], jnp.zeros_like(buf))
            cur_in = jnp.where(stage == 0, inject, buf)
            y = stage_fn(p_stage, cur_in)
            # pass to the next stage
            nxt = jax.lax.ppermute(y, axis, perm) if n_stages > 1 else y
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = t - (n_stages - 1)
            valid = jnp.logical_and(out_idx >= 0, stage == n_stages - 1)
            outs = jnp.where(
                valid,
                jax.lax.dynamic_update_index_in_dim(
                    outs, y, jnp.clip(out_idx, 0, n_micro - 1), 0),
                outs)
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # broadcast the last stage's outputs to every pod
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    return jax.shard_map(
        run, mesh=mesh, in_specs=(p_specs, P()), out_specs=P(),
        check_vma=False)(stage_params, x)
