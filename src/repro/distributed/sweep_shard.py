"""Sharded cell-plan execution layer for the chunked sweep engine.

``run_sharded`` (the mesh path of ``repro.core.queueing.run``) and the
legacy shims ``sweep_sharded`` / ``sweep_dists_sharded`` are drop-in,
BIT-IDENTICAL replacements for the unsharded engine that run its
per-chunk scan body under ``shard_map`` over a 1-D ``"cells"`` device
mesh (``repro.launch.mesh.make_sweep_mesh``). The
(seed x load x variant) grid — dist-stacked along the seed axis, with
each variant's scenario policy/model codes riding the plan as per-cell
coordinates, so MIXED-policy grids shard like any other — is flattened
by ``repro.core.cellplan`` into one cell axis padded to a multiple of
the mesh size, and every device owns ``n_padded / n_devices`` cells end
to end:

  * Per-cell state is DEVICE-LOCAL for the whole stream: server
    free-time grids, Kahan mean state, and hist_sketch rows live in the
    local shard of the scan carry, and the Pallas histogram kernel runs
    per shard on its local (block, C/D) response blocks — the kernel's
    per-cell grid maps 1:1 onto the sharded axis. Nothing is
    communicated between chunks.
  * Cell randomness derives from cell COORDINATES, never device
    placement: chunk ``c``, seed ``s`` draws from
    ``split(fold_in(key, c), n_seeds)[s]`` through the exact unsharded
    samplers, executed on the host (chunk inputs are O(rows x
    chunk_size) — small by construction, that is the point of chunking).
  * The ONLY gather of results is at summary finalization, after the
    last chunk: pad cells are sliced away there, so they never reach a
    mean or a histogram summary.

Why host-side sampling and not per-cell sampling inside the shard: XLA's
codegen for the transcendental sampling transforms (log / pow) is only
approximately rounded, and the chosen expansion varies with tensor shape
and fusion context — a ``(C/D, T)``-shaped in-shard sampler produces
1-ULP-different draws for different device counts D, silently breaking
the CRN contract's sharding-invariance guarantee (observed on CPU at
~17% of draws for T=1700). Sampling once per seed on the host keeps the
op shapes — and therefore the bits — literally identical to the
unsharded engine. For the same reason the chunk BODY is its own XLA
program, mirroring the unsharded driver's sampler/body split, rather
than being fused with anything else.

Probe batches from ``threshold_bisect(mesh=...)`` ride the load axis of
the plan, so one sharded engine call still serves all brackets (and the
estimators no longer pass ``mesh=`` explicitly at all — ``queueing.run``
resolves the ambient mesh through ``repro.launch.mesh.resolve_mesh``).

Multi-host execution & sharding rules — design note
---------------------------------------------------

The same executor serves a SINGLE process with D devices and a
multi-process runtime (``repro.distributed.multihost.initialize``) where
the ``"cells"`` mesh spans every process's devices. Four pieces make the
multi-host path both correct and cheap:

**Sharding rules, declared once.** ``CellPlan.sharding_rule(mesh)``
returns the plan's ``repro.launch.mesh.SweepShardingRules``: everything
keyed by the cell axis (carry, per-cell plan parameters, per-device
input blocks) shards ``P("cells")``, chunk scalars replicate, and the
``put_*`` constructors build each global array from the blocks THIS
process owns (``jax.make_array_from_single_device_arrays``). Callers
never hand-build a ``NamedSharding``; the shard_map in_specs below and
the array constructors read the same rules object.

**Per-host sampling reduction.** Host-side sampling is per-seed
deterministic: row ``r`` of a chunk's input block is a pure function of
``split(fold_in(key, c), n_seeds)[r % n_seeds]`` (and, for service
tables, the row's distribution), NOT of which other rows are sampled
alongside it — so each process draws ONLY the sorted union of input
rows its local cells gather (``queueing.ChunkSampler.rows``) instead of
every process sampling the full O(all-rows x chunk) block. Locality
cannot change bits. ``cellplan.device_row_maps`` turns the plan's
global row indices into per-device row lists plus DEVICE-LOCAL gather
indices satisfying ``x[rows[d]][local[c]] == x[idx[c]]``; since the
chunk body reads inputs only through per-cell row gathers, remapping to
local positions is exact, and the shard_map input specs become
``P("cells")`` blocks (each device receives just its rows) rather than
full replicated blocks.

**Sampling/compute pipeline.** With ``pipeline="on"`` the chunk loop
runs through ``repro.core.chunkflow.iter_staged``: a producer thread
samples chunk ``c+1`` — eagerly, per row: the row-reduced sampler is
deliberately NOT jitted, because jit-fusing the stacked per-row draws
re-introduces exactly the shape-dependent ULP wobble described above
(observed flipping ~0.1% of one row's service draws when the requested
subset changed) — and stages its per-device blocks while the main
thread dispatches chunk ``c``'s shard_mapped body, double-buffered
with a bounded slot pool
(TransferBufferPool idiom) so peak staging memory is O(depth x chunk
inputs). The pipeline moves WHEN sampling happens, never what is
sampled: on/off are bit-identical.

**The single gather.** Per-cell state never crosses processes during
the stream. After the last chunk, finalization — and ONLY finalization
— gathers: on a mesh that spans processes, the cell-sharded ``ssum`` /
``cnt`` / ``hist`` buffers pass through a jitted identity with
replicated out_shardings (``multihost.fetch_replicated``), the one
collective of the whole engine, and every process computes the full
summary from its replica. Single-process meshes skip even that (eager
finalize reads the addressable shards directly).
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import cellplan, chunkflow, queueing
from repro.core import scenario as scenario_mod
from repro.core.distributions import ServiceDist
from repro.distributed import multihost
from repro.launch.mesh import make_sweep_mesh

try:  # public API (jax >= 0.6); the experimental module was removed
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep -> check_vma
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")

Array = jax.Array


def _shard_map_unchecked(f, mesh, in_specs, out_specs):
    """shard_map with replication checking off: pallas_call (the
    hist_sketch kernel) has no replication rule, and every spec we pass
    is explicit — nothing is inferred."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: False})


@functools.lru_cache(maxsize=None)
def _body_fn(mesh: jax.sharding.Mesh, n_servers: int, n_bins: int,
             block: int, use_kernel: str = "off",
             has_shared: bool = False, has_timed: bool = False,
             has_dists: bool = False):
    """Build (and cache) the jitted, shard_mapped chunk-body executor.

    The carry and the per-cell parameters — including the scenario
    policy/model codes, service-model mixes and the degradation /
    timed-policy parameters — are sharded over ``"cells"``, and so are
    the chunk INPUT blocks: each device receives only the input rows
    its own cells gather, with ``seed_idx`` / ``svc_idx`` already
    remapped to device-LOCAL row positions (``cellplan.device_row_maps``
    — an exact remap, see the module design note). Only the three chunk
    scalars replicate. Cached per mesh so repeated engine calls
    (threshold bisection!) reuse the wrapper and its jit cache.
    ``has_shared`` / ``has_timed`` are the static services-layout /
    timed-block flags of ``cell_update_ref`` (part of the cache key,
    like the kernel mode).

    ``use_kernel`` is a RESOLVED cell-update kernel mode (see
    ``queueing.run``): the Pallas kernel runs per shard on its local
    cells — its per-cell grid maps 1:1 onto the sharded axis, like the
    hist_sketch kernel — so every mode preserves the bit-identity
    contract.
    """
    def chunk_body(free, ssum, comp, cnt, hist, seed_idx, rates, k_mask,
                   ovh, policy_code, model_code, mix, p_slow, slow_factor,
                   p_fail, delay, svc_idx,
                   unit_gaps, servers, services, start, n_valid,
                   warmup_start):
        return queueing._sweep_chunk_cells(
            free, ssum, comp, cnt, hist, unit_gaps, servers, services,
            start, n_valid, warmup_start, seed_idx, rates, k_mask, ovh,
            policy_code, model_code, mix, p_slow, slow_factor, p_fail,
            delay, svc_idx if has_dists else None,
            n_servers=n_servers, n_bins=n_bins, block=block,
            use_kernel=use_kernel, has_shared=has_shared,
            has_timed=has_timed, has_dists=has_dists)

    cells = P("cells")
    return jax.jit(_shard_map_unchecked(
        chunk_body, mesh,
        in_specs=(cells,) * 20 + (P(),) * 3,
        out_specs=(cells,) * 5))


def _sweep_cells_sharded(sampler, n_seeds_total: int,
                         rhos: Array, cfg: queueing.SimConfig, *,
                         variants, warmup_frac: float,
                         percentiles: tuple[float, ...], n_bins: int,
                         chunk_size: int | None,
                         mesh: jax.sharding.Mesh | None,
                         use_kernel: str = "off",
                         pipeline: str = "off") -> dict[str, Array]:
    """Drive the shard_mapped chunk body over the whole arrival stream.

    ``sampler`` is the SAME ``queueing.ChunkSampler`` the unsharded
    ``_run_engine`` consumes — identical randomness by construction;
    here its ``rows`` entry point draws only this process's input rows
    (the per-host sampling reduction, see the module design note).
    ``variants`` are the scenario's per-variant coordinates; their
    policy/model codes shard over the mesh with the rest of the plan, so
    MIXED-policy grids ride the same device-local body. ``pipeline`` is
    resolved (``"on"``/``"off"``): ``"on"`` overlaps next-chunk sampling
    + staging with the current chunk's compute via
    ``chunkflow.iter_staged`` — bit-identical either way.
    """
    mesh = make_sweep_mesh() if mesh is None else mesh
    if tuple(mesh.axis_names) != ("cells",):
        raise ValueError(f"expected a 1-D ('cells',) mesh "
                         f"(make_sweep_mesh), got axes {mesh.axis_names}")
    spec = getattr(sampler, "spec", None)
    if spec is None or not hasattr(sampler, "rows"):
        raise TypeError(
            "the sharded executor needs a queueing.ChunkSampler "
            "(its .spec/.rows drive the per-host sampling reduction); "
            "got a bare sampler callable")
    m = cfg.n_arrivals
    variants = tuple(variants)
    policies, models = scenario_mod.variant_codes(variants)
    plan = cellplan.make_cell_plan(
        n_seeds_total, rhos.shape[0], len(variants),
        pad_to=mesh.devices.size, policies=policies, models=models,
        dist_ids=scenario_mod.variant_dist_ids(variants))
    rules = plan.sharding_rule(mesh)
    (rates_c, k_mask_c, ovh_c, mix_c, pslow_c, sfac_c, pfail_c,
     delay_c) = queueing._plan_cell_params(plan, rhos, cfg, variants)
    has_shared = scenario_mod.any_server_dependent(variants)
    has_timed = scenario_mod.any_timed(variants)
    has_dists = scenario_mod.any_dist_ids(variants)

    # global input-row index per cell -> per-device row lists + local
    # gather indices (exact remap; svc rows == seed rows unless the grid
    # is heterogeneous, where services stack one table per union member)
    n_dev = rules.n_devices
    seed_g = np.asarray(plan.seed_idx)
    seed_rows, seed_local = cellplan.device_row_maps(seed_g, n_dev)
    if has_dists:
        svc_rows, svc_local = cellplan.device_row_maps(
            np.asarray(plan.dist_id) * n_seeds_total + seed_g, n_dev)
    else:
        svc_rows, svc_local = seed_rows, seed_local

    # THIS process's sampling set: the sorted union over its devices
    # (shared rows are drawn once per host, not once per device)
    local_pos = rules.local_positions()
    proc_seed = np.unique(seed_rows[local_pos])
    proc_svc = np.unique(svc_rows[local_pos])
    seed_take = {p: np.searchsorted(proc_seed, seed_rows[p])
                 for p in local_pos}
    svc_take = {p: np.searchsorted(proc_svc, svc_rows[p])
                for p in local_pos}

    warmup_start = int(m * warmup_frac)
    need_hist = len(percentiles) > 0
    t_chunk, n_chunks, block, pad = queueing._chunk_layout(
        cfg, chunk_size, need_hist, kernel_on=use_kernel != "off")
    t_pad = t_chunk + pad
    r_seed, r_svc = seed_rows.shape[1], svc_rows.shape[1]

    # carry + per-cell plan params as cell-sharded GLOBAL arrays (this
    # process supplies only its local devices' blocks — required on a
    # multi-process mesh, a no-op-cost re-layout on one process)
    put = lambda x: rules.put_cells(np.asarray(x))  # noqa: E731
    free, ssum, comp, cnt, hist = (
        put(x) for x in queueing._init_cell_state(plan, cfg, n_bins,
                                                  need_hist))
    (seed_local_g, svc_local_g, rates_g, k_mask_g, ovh_g, pol_g, mdl_g,
     mix_g, pslow_g, sfac_g, pfail_g, delay_g) = (
        put(x) for x in (seed_local, svc_local, rates_c, k_mask_c, ovh_c,
                         plan.policy_code, plan.model_code, mix_c,
                         pslow_c, sfac_c, pfail_c, delay_c))
    warm_g = rules.put_replicated(np.int32(warmup_start))
    run_chunk = _body_fn(mesh, cfg.n_servers, n_bins, block, use_kernel,
                         has_shared, has_timed, has_dists)

    def produce(c: int):
        """Sample THIS host's input rows for chunk ``c`` (one fused
        dispatch) and stage them as per-device cell-sharded blocks."""
        g, sv, svc = queueing._pad_chunk_inputs(
            *sampler.rows(c, t_chunk, proc_seed, proc_svc), pad)
        g, sv, svc = np.asarray(g), np.asarray(sv), np.asarray(svc)
        return (
            rules.put_blocks([g[seed_take[p]] for p in local_pos],
                             (n_dev * r_seed,) + g.shape[1:]),
            rules.put_blocks([sv[seed_take[p]] for p in local_pos],
                             (n_dev * r_seed,) + sv.shape[1:]),
            rules.put_blocks([svc[svc_take[p]] for p in local_pos],
                             (n_dev * r_svc,) + svc.shape[1:]))

    use_pipe = pipeline == "on" and n_chunks > 1
    for c, (gaps_g, servers_g, services_g) in enumerate(
            chunkflow.iter_staged(produce, n_chunks, enabled=use_pipe)):
        start = c * t_chunk
        free, ssum, comp, cnt, hist = run_chunk(
            free, ssum, comp, cnt, hist, seed_local_g, rates_g, k_mask_g,
            ovh_g, pol_g, mdl_g, mix_g, pslow_g, sfac_g, pfail_g, delay_g,
            svc_local_g, gaps_g, servers_g, services_g,
            rules.put_replicated(np.int32(start)),
            rules.put_replicated(np.int32(min(t_chunk, m - start))),
            warm_g)

    jax.block_until_ready(ssum)  # drain the producer before stats/gather
    queueing._record_pipeline_stats(
        sampler, enabled=use_pipe, n_chunks=n_chunks, t_pad=t_pad,
        seed_rows=int(proc_seed.size), svc_rows=int(proc_svc.size))

    if multihost.spans_processes(mesh):
        # THE single cross-process gather of the sweep (design note)
        gathered = multihost.fetch_replicated(
            mesh, *((ssum, cnt, hist) if need_hist else (ssum, cnt)))
        ssum, cnt = jnp.asarray(gathered[0]), jnp.asarray(gathered[1])
        hist = (jnp.asarray(gathered[2]) if need_hist
                else jnp.zeros((0, 0)))
    return queueing._finalize_summary(plan, ssum, cnt, hist,
                                      m - warmup_start, percentiles)


def run_sharded(key: Array, scenario, rhos: Array, cfg: queueing.SimConfig,
                *, n_seeds: int = 2,
                percentiles: tuple[float, ...]
                = queueing.DEFAULT_PERCENTILES,
                n_bins: int = queueing.DEFAULT_BINS,
                chunk_size: int | None = None,
                mesh: jax.sharding.Mesh | None = None,
                kernel: str = "auto") -> dict[str, Array]:
    """``queueing.run`` across a device mesh (``mesh=None`` uses every
    visible device): same scenario semantics — including mixed-policy /
    mixed-model grids — same summary shapes, bit-identical results for
    the same ``(key, chunk_size)`` no matter the device count (and no
    matter the ``kernel`` mode). Equivalent to
    ``queueing.run(..., mesh=mesh)``."""
    return queueing.run(key, scenario, rhos, cfg, n_seeds=n_seeds,
                        percentiles=percentiles, n_bins=n_bins,
                        chunk_size=chunk_size,
                        mesh=make_sweep_mesh() if mesh is None else mesh,
                        kernel=kernel)


def sweep_sharded(key: Array, dist: ServiceDist, rhos: Array,
                  cfg: queueing.SimConfig, *, ks: tuple[int, ...] = (1, 2),
                  n_seeds: int = 2,
                  percentiles: tuple[float, ...]
                  = queueing.DEFAULT_PERCENTILES,
                  n_bins: int = queueing.DEFAULT_BINS,
                  chunk_size: int | None = None,
                  mesh: jax.sharding.Mesh | None = None) -> dict[str, Array]:
    """``queueing.sweep`` across a device mesh: same signature plus
    ``mesh`` (default: all visible devices), same summary shapes
    ``(n_seeds, len(rhos), len(ks))``, and — per the CRN contract —
    bit-identical results for the same ``(key, chunk_size)`` no matter
    the device count.

    .. deprecated:: Thin shim over ``run_sharded`` (paper-default
       scenario); prefer ``queueing.run(..., mesh=...)``."""
    scn = queueing.Scenario.paper_default(
        dist, ks=tuple(int(k) for k in ks),
        client_overhead=cfg.client_overhead, warmup_frac=cfg.warmup_frac)
    return run_sharded(key, scn, rhos, cfg, n_seeds=n_seeds,
                       percentiles=percentiles, n_bins=n_bins,
                       chunk_size=chunk_size, mesh=mesh)


def sweep_dists_sharded(key: Array, dist_list, rhos: Array,
                        cfg: queueing.SimConfig, *,
                        ks: tuple[int, ...] = (1, 2), n_seeds: int = 2,
                        percentiles: tuple[float, ...]
                        = queueing.DEFAULT_PERCENTILES,
                        n_bins: int = queueing.DEFAULT_BINS,
                        chunk_size: int | None = None,
                        mesh: jax.sharding.Mesh | None = None
                        ) -> dict[str, Array]:
    """``queueing.sweep_dists`` across a device mesh: distributions stack
    along the plan's seed axis (every dist shares per-seed keys and the
    same arrival process — CRN across dists), summaries come back
    ``(len(dist_list), n_seeds, len(rhos), len(ks))``, bit-identical to
    the unsharded engine.

    .. deprecated:: Thin shim over ``run_sharded`` (multi-``dists``
       paper-default scenario); prefer ``queueing.run(..., mesh=...)``."""
    dist_list = tuple(dist_list)
    scn = queueing.Scenario.paper_default(
        dist_list, ks=tuple(int(k) for k in ks),
        client_overhead=cfg.client_overhead, warmup_frac=cfg.warmup_frac)
    out = run_sharded(key, scn, rhos, cfg, n_seeds=n_seeds,
                      percentiles=percentiles, n_bins=n_bins,
                      chunk_size=chunk_size, mesh=mesh)
    if len(dist_list) == 1:  # run() adds the dist axis only for d > 1
        out = {k: (v[None] if isinstance(v, jax.Array) else v)
               for k, v in out.items()}
    return out
