"""Sharded cell-plan execution layer for the chunked sweep engine.

``run_sharded`` (the ``mesh=`` path of ``repro.core.queueing.run``) and
the legacy shims ``sweep_sharded`` / ``sweep_dists_sharded`` are
drop-in, BIT-IDENTICAL replacements for the unsharded engine that run
its per-chunk scan body under ``shard_map`` over a 1-D ``"cells"``
device mesh (``repro.launch.mesh.make_sweep_mesh``). The
(seed x load x variant) grid — dist-stacked along the seed axis, with
each variant's scenario policy/model codes riding the plan as per-cell
coordinates, so MIXED-policy grids shard like any other — is flattened
by ``repro.core.cellplan`` into one cell axis padded to a multiple of
the mesh size, and every device owns ``n_padded / n_devices`` cells end
to end:

  * Per-cell state is DEVICE-LOCAL for the whole stream: server
    free-time grids, Kahan mean state, and hist_sketch rows live in the
    local shard of the scan carry, and the Pallas histogram kernel runs
    per shard on its local (block, C/D) response blocks — the kernel's
    per-cell grid maps 1:1 onto the sharded axis. Nothing is
    communicated between chunks.
  * Cell randomness derives from cell COORDINATES, never device
    placement: chunk ``c``, seed ``s`` draws from
    ``split(fold_in(key, c), n_seeds)[s]`` through the exact unsharded
    samplers, executed per seed on the host and broadcast into the mesh
    (chunk inputs are O(S x chunk_size) — small by construction, that
    is the point of chunking). Each device then gathers its own cells'
    seed rows step-by-step inside the scan via the sharded
    ``seed_idx`` map.
  * The ONLY gather of results is at summary finalization
    (``queueing._finalize_summary``), after the last chunk: pad cells
    are sliced away there, so they never reach a mean or a histogram
    summary.

Why host-side sampling and not per-cell sampling inside the shard: XLA's
codegen for the transcendental sampling transforms (log / pow) is only
approximately rounded, and the chosen expansion varies with tensor shape
and fusion context — a ``(C/D, T)``-shaped in-shard sampler produces
1-ULP-different draws for different device counts D, silently breaking
the CRN contract's sharding-invariance guarantee (observed on CPU at
~17% of draws for T=1700). Sampling once per seed on the host keeps the
op shapes — and therefore the bits — literally identical to the
unsharded engine. For the same reason the chunk BODY is its own XLA
program, mirroring the unsharded driver's sampler/body split, rather
than being fused with anything else.

Probe batches from ``threshold_bisect(mesh=...)`` ride the load axis of
the plan, so one sharded engine call still serves all brackets.
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cellplan, queueing
from repro.core import scenario as scenario_mod
from repro.core.distributions import ServiceDist
from repro.launch.mesh import make_sweep_mesh

try:  # public API (jax >= 0.6); the experimental module was removed
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep -> check_vma
_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")

Array = jax.Array


def _shard_map_unchecked(f, mesh, in_specs, out_specs):
    """shard_map with replication checking off: pallas_call (the
    hist_sketch kernel) has no replication rule, and every spec we pass
    is explicit — nothing is inferred."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: False})


@functools.lru_cache(maxsize=None)
def _body_fn(mesh: jax.sharding.Mesh, n_servers: int, n_bins: int,
             block: int, use_kernel: str = "off",
             has_shared: bool = False, has_timed: bool = False,
             has_dists: bool = False):
    """Build (and cache) the jitted, shard_mapped chunk-body executor.

    The carry and the per-cell parameters — including the scenario
    policy/model codes, service-model mixes and the degradation /
    timed-policy parameters — are sharded over ``"cells"``; the
    seed-level sampled inputs are replicated (each device reads only
    its cells' rows via the sharded ``seed_idx``). Cached per mesh so
    repeated engine calls (threshold bisection!) reuse the wrapper and
    its jit cache. ``has_shared`` / ``has_timed`` are the static
    services-layout / timed-block flags of ``cell_update_ref`` (part of
    the cache key, like the kernel mode).

    ``use_kernel`` is a RESOLVED cell-update kernel mode (see
    ``queueing.run``): the Pallas kernel runs per shard on its local
    cells — its per-cell grid maps 1:1 onto the sharded axis, like the
    hist_sketch kernel — so every mode preserves the bit-identity
    contract.
    """
    def chunk_body(free, ssum, comp, cnt, hist, seed_idx, rates, k_mask,
                   ovh, policy_code, model_code, mix, p_slow, slow_factor,
                   p_fail, delay, svc_idx,
                   unit_gaps, servers, services, start, n_valid,
                   warmup_start):
        return queueing._sweep_chunk_cells(
            free, ssum, comp, cnt, hist, unit_gaps, servers, services,
            start, n_valid, warmup_start, seed_idx, rates, k_mask, ovh,
            policy_code, model_code, mix, p_slow, slow_factor, p_fail,
            delay, svc_idx if has_dists else None,
            n_servers=n_servers, n_bins=n_bins, block=block,
            use_kernel=use_kernel, has_shared=has_shared,
            has_timed=has_timed, has_dists=has_dists)

    cells = P("cells")
    return jax.jit(_shard_map_unchecked(
        chunk_body, mesh,
        in_specs=(cells,) * 17 + (P(),) * 6,
        out_specs=(cells,) * 5))


def _sweep_cells_sharded(sampler, n_seeds_total: int,
                         rhos: Array, cfg: queueing.SimConfig, *,
                         variants, warmup_frac: float,
                         percentiles: tuple[float, ...], n_bins: int,
                         chunk_size: int | None,
                         mesh: jax.sharding.Mesh | None,
                         use_kernel: str = "off") -> dict[str, Array]:
    """Drive the shard_mapped chunk body over the whole arrival stream.

    ``sampler(chunk_idx, chunk_len)`` is the SAME host-side per-seed
    sampler closure the unsharded ``_run_engine`` consumes — identical
    randomness by construction. ``variants`` are the scenario's
    per-variant coordinates (``queueing._plan_cell_params`` also accepts
    a legacy ``ks`` int tuple); their policy/model codes shard over the
    mesh with the rest of the plan, so MIXED-policy grids ride the same
    device-local body.
    """
    mesh = make_sweep_mesh() if mesh is None else mesh
    if tuple(mesh.axis_names) != ("cells",):
        raise ValueError(f"expected a 1-D ('cells',) mesh "
                         f"(make_sweep_mesh), got axes {mesh.axis_names}")
    m = cfg.n_arrivals
    variants = tuple(variants)
    policies, models = scenario_mod.variant_codes(variants)
    plan = cellplan.make_cell_plan(
        n_seeds_total, rhos.shape[0], len(variants),
        pad_to=mesh.devices.size, policies=policies, models=models,
        dist_ids=scenario_mod.variant_dist_ids(variants))
    (rates_c, k_mask_c, ovh_c, mix_c, pslow_c, sfac_c, pfail_c,
     delay_c) = queueing._plan_cell_params(plan, rhos, cfg, variants)
    has_shared = scenario_mod.any_server_dependent(variants)
    has_timed = scenario_mod.any_timed(variants)
    has_dists = scenario_mod.any_dist_ids(variants)
    # per-cell service-table row (== seed_idx for homogeneous grids,
    # where the body ignores it; see queueing._sweep_chunk_cells)
    svc_idx_c = plan.dist_id * n_seeds_total + plan.seed_idx
    warmup_start = int(m * warmup_frac)
    need_hist = len(percentiles) > 0
    t_chunk, n_chunks, block, pad = queueing._chunk_layout(
        cfg, chunk_size, need_hist, kernel_on=use_kernel != "off")
    free, ssum, comp, cnt, hist = queueing._init_cell_state(
        plan, cfg, n_bins, need_hist)
    run_chunk = _body_fn(mesh, cfg.n_servers, n_bins, block, use_kernel,
                         has_shared, has_timed, has_dists)

    for c in range(n_chunks):
        unit_gaps, servers, services = queueing._pad_chunk_inputs(
            *sampler(c, t_chunk), pad)
        start = c * t_chunk
        free, ssum, comp, cnt, hist = run_chunk(
            free, ssum, comp, cnt, hist, plan.seed_idx, rates_c, k_mask_c,
            ovh_c, plan.policy_code, plan.model_code, mix_c, pslow_c,
            sfac_c, pfail_c, delay_c, svc_idx_c,
            unit_gaps, servers, services, jnp.asarray(start),
            jnp.asarray(min(t_chunk, m - start)),
            jnp.asarray(warmup_start))

    return queueing._finalize_summary(plan, ssum, cnt, hist,
                                      m - warmup_start, percentiles)


def run_sharded(key: Array, scenario, rhos: Array, cfg: queueing.SimConfig,
                *, n_seeds: int = 2,
                percentiles: tuple[float, ...]
                = queueing.DEFAULT_PERCENTILES,
                n_bins: int = queueing.DEFAULT_BINS,
                chunk_size: int | None = None,
                mesh: jax.sharding.Mesh | None = None,
                kernel: str = "auto") -> dict[str, Array]:
    """``queueing.run`` across a device mesh (``mesh=None`` uses every
    visible device): same scenario semantics — including mixed-policy /
    mixed-model grids — same summary shapes, bit-identical results for
    the same ``(key, chunk_size)`` no matter the device count (and no
    matter the ``kernel`` mode). Equivalent to
    ``queueing.run(..., mesh=mesh)``."""
    return queueing.run(key, scenario, rhos, cfg, n_seeds=n_seeds,
                        percentiles=percentiles, n_bins=n_bins,
                        chunk_size=chunk_size,
                        mesh=make_sweep_mesh() if mesh is None else mesh,
                        kernel=kernel)


def sweep_sharded(key: Array, dist: ServiceDist, rhos: Array,
                  cfg: queueing.SimConfig, *, ks: tuple[int, ...] = (1, 2),
                  n_seeds: int = 2,
                  percentiles: tuple[float, ...]
                  = queueing.DEFAULT_PERCENTILES,
                  n_bins: int = queueing.DEFAULT_BINS,
                  chunk_size: int | None = None,
                  mesh: jax.sharding.Mesh | None = None) -> dict[str, Array]:
    """``queueing.sweep`` across a device mesh: same signature plus
    ``mesh`` (default: all visible devices), same summary shapes
    ``(n_seeds, len(rhos), len(ks))``, and — per the CRN contract —
    bit-identical results for the same ``(key, chunk_size)`` no matter
    the device count.

    .. deprecated:: Thin shim over ``run_sharded`` (paper-default
       scenario); prefer ``queueing.run(..., mesh=...)``."""
    scn = queueing.Scenario.paper_default(
        dist, ks=tuple(int(k) for k in ks),
        client_overhead=cfg.client_overhead, warmup_frac=cfg.warmup_frac)
    return run_sharded(key, scn, rhos, cfg, n_seeds=n_seeds,
                       percentiles=percentiles, n_bins=n_bins,
                       chunk_size=chunk_size, mesh=mesh)


def sweep_dists_sharded(key: Array, dist_list, rhos: Array,
                        cfg: queueing.SimConfig, *,
                        ks: tuple[int, ...] = (1, 2), n_seeds: int = 2,
                        percentiles: tuple[float, ...]
                        = queueing.DEFAULT_PERCENTILES,
                        n_bins: int = queueing.DEFAULT_BINS,
                        chunk_size: int | None = None,
                        mesh: jax.sharding.Mesh | None = None
                        ) -> dict[str, Array]:
    """``queueing.sweep_dists`` across a device mesh: distributions stack
    along the plan's seed axis (every dist shares per-seed keys and the
    same arrival process — CRN across dists), summaries come back
    ``(len(dist_list), n_seeds, len(rhos), len(ks))``, bit-identical to
    the unsharded engine.

    .. deprecated:: Thin shim over ``run_sharded`` (multi-``dists``
       paper-default scenario); prefer ``queueing.run(..., mesh=...)``."""
    dist_list = tuple(dist_list)
    scn = queueing.Scenario.paper_default(
        dist_list, ks=tuple(int(k) for k in ks),
        client_overhead=cfg.client_overhead, warmup_frac=cfg.warmup_frac)
    out = run_sharded(key, scn, rhos, cfg, n_seeds=n_seeds,
                      percentiles=percentiles, n_bins=n_bins,
                      chunk_size=chunk_size, mesh=mesh)
    if len(dist_list) == 1:  # run() adds the dist axis only for d > 1
        out = {k: (v[None] if isinstance(v, jax.Array) else v)
               for k, v in out.items()}
    return out
