"""Distribution: sharding rules + layouts, ShardCtx activation constraints,
hierarchical collectives, elastic replanning, experimental pipeline PP."""
