"""Multi-process launch layer for the sweep engine.

``initialize`` wires one process of a multi-host run into the jax
distributed runtime (``jax.distributed.initialize`` with the gloo CPU
collectives backend), then installs the all-processes ``"cells"`` sweep
mesh as the ambient default (``repro.launch.mesh.set_default_sweep_mesh``)
— so a worker's plain ``queueing.run(...)`` call, with no ``mesh=``
anywhere, executes sharded across every host. On CPU each process gets
``local_device_count`` virtual devices via
``XLA_FLAGS=--xla_force_host_platform_device_count`` (set here if the
caller has not, BEFORE jax backends initialize), which is how CI
exercises the real multi-process code path on one machine: 2 spawned
subprocesses x 4 virtual devices against a single-process 8-device
reference, bit-identical (tests/test_multihost.py).

The other half of this module is the single cross-process gather of the
sweep: ``fetch_replicated`` jits an identity function with REPLICATED
output shardings, which makes XLA insert the all-gather that turns the
executor's cell-sharded summaries into arrays every process holds in
full — the one collective of the whole engine (see the design note in
``repro.distributed.sweep_shard``).
"""
from __future__ import annotations

import os
from functools import lru_cache

import numpy as np

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def initialize(coordinator_address: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None,
               local_device_count: int | None = None, *,
               set_default_mesh: bool = True) -> bool:
    """Join a multi-process jax runtime; returns True if one was joined.

    No-op (returns False) when ``num_processes`` is None or <= 1, so a
    launcher script can call this unconditionally and fall through to
    plain single-process execution. Must run before anything touches jax
    device state: ``local_device_count`` is applied through ``XLA_FLAGS``
    (ignored if the flag is already set — e.g. by the test harness) and
    the CPU collectives implementation is switched to gloo, both of
    which only take effect before backend initialization.

    With ``set_default_mesh`` (the default), the all-devices sweep mesh
    becomes the process-wide ambient default — every subsequent
    ``queueing.run`` resolves to it (``launch.mesh.resolve_mesh``) and
    executes sharded across all processes' devices.
    """
    if num_processes is None or int(num_processes) <= 1:
        return False
    if local_device_count is not None:
        if _FORCE_FLAG not in os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "")
                + f" {_FORCE_FLAG}={int(local_device_count)}").strip()
        # Virtual host devices only exist on the CPU backend. Pin the
        # platform too: with jax.distributed active, an installed
        # libtpu otherwise tries to initialize a TPU pod runtime (and
        # hangs >60s on TPU_WORKER_HOSTNAMES before aborting the
        # process) instead of quietly falling back to CPU.
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax

    try:  # CPU collectives: gloo (the only CPU backend with cross-host
        # all-gather support); unavailable names just keep the default
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - older/newer jax config names
        pass
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=int(num_processes), process_id=int(process_id))
    if set_default_mesh:
        from repro.launch import mesh as mesh_mod

        mesh_mod.set_default_sweep_mesh(mesh_mod.make_sweep_mesh())
    return True


def is_initialized() -> bool:
    import jax

    return jax.process_count() > 1


def process_index() -> int:
    import jax

    return jax.process_index()


def process_count() -> int:
    import jax

    return jax.process_count()


def is_coordinator() -> bool:
    return process_index() == 0


def spans_processes(mesh) -> bool:
    """True when the mesh's devices live on more than one process —
    i.e. when finalization needs the cross-process gather and host-side
    ``np.asarray`` on a sharded array would fail (non-addressable
    shards)."""
    return len({d.process_index for d in mesh.devices.flat}) > 1


@lru_cache(maxsize=None)
def _gather_fn(mesh, n: int):
    """Jitted identity with fully REPLICATED out_shardings: running it on
    cell-sharded arrays makes XLA emit the all-gather that assembles the
    global value on every process. Cached per (mesh, arity) — ONE
    compiled collective reused by every chunk-streamed sweep on the
    mesh."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())
    return jax.jit(lambda *xs: tuple(xs), out_shardings=(rep,) * n)


def fetch_replicated(mesh, *xs) -> tuple[np.ndarray, ...]:
    """Gather cell-sharded arrays to full host copies on EVERY process
    (the sweep's single collective). Returns numpy arrays read from the
    first addressable shard — after replication, any shard is the whole
    value."""
    out = _gather_fn(mesh, len(xs))(*xs)
    return tuple(np.asarray(o.addressable_data(0)) for o in out)
