"""Collective helpers for multi-pod training.

``hierarchical_grad_reduce``: the pod axis is the data-center-network tier
(slow links), so gradients reduce in two stages — reduce-scatter over the
in-pod ``data`` axis (fast ICI), all-reduce the shards over ``pod`` (DCN),
then all-gather back over ``data``. DCN traffic per device drops from
full-gradient to gradient/|data| (16x) vs a flat cross-pod all-reduce.

``interleave_overlap`` tags per-layer gradient reductions so XLA's latency
hiding scheduler can overlap them with the backward compute (expressed via
scan-carried partial reductions rather than one fused end-of-step
all-reduce).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def hierarchical_grad_reduce(grads: PyTree, mesh: Mesh,
                             data_axis: str = "data",
                             pod_axis: str = "pod") -> PyTree:
    """Mean-reduce gradients over (data x pod) hierarchically.

    Gradients enter replicated per (data, pod) rank (each rank computed its
    microbatch); leave identical on every rank. Inside shard_map:
      1. reduce-scatter over data  (ICI, 1/|data| traffic each)
      2. all-reduce over pod       (DCN, only the local shard)
      3. all-gather over data      (ICI)
    """
    if pod_axis not in mesh.shape:
        # single-pod: plain psum-mean over data
        def reduce_single(g):
            n = mesh.shape[data_axis]
            return jax.tree.map(lambda x: x / n,
                                jax.lax.psum(g, data_axis))

        return jax.shard_map(reduce_single, mesh=mesh, in_specs=P(),
                             out_specs=P(), check_vma=False)(grads)

    n_total = mesh.shape[data_axis] * mesh.shape[pod_axis]

    def reduce_fn(g):
        def one(x):
            flat = x.reshape(-1)
            pad = (-flat.shape[0]) % mesh.shape[data_axis]
            if pad:
                flat = jax.numpy.pad(flat, (0, pad))
            shard = jax.lax.psum_scatter(flat, data_axis, scatter_dimension=0,
                                         tiled=True)
            shard = jax.lax.psum(shard, pod_axis)
            full = jax.lax.all_gather(shard, data_axis, axis=0, tiled=True)
            if pad:
                full = full[:-pad]
            return (full / n_total).reshape(x.shape).astype(x.dtype)

        return jax.tree.map(one, g)

    return jax.shard_map(reduce_fn, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_vma=False)(grads)
