"""Activation-sharding context threaded through the model code.

``ShardCtx`` names which mesh axes shard each logical activation dimension.
``constrain`` is a no-op when no context is set (single-device tests), so
model code can sprinkle constraints freely.

Axis assignments per (recipe x step kind) are produced by
``repro.distributed.sharding.make_layout``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

AxisSpec = tuple[str, ...] | None  # mesh axes for one logical dim


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    batch: AxisSpec = None        # batch dim of activations
    seq: AxisSpec = None          # sequence dim (SP/CP)
    kv_seq: AxisSpec = None       # KV-cache sequence dim (decode)
    heads: AxisSpec = None        # attention heads / d_inner (TP)
    model_axis: str = "model"     # the TP/EP axis name
    ep_axes: tuple[str, ...] = ("model",)  # expert-parallel axes
    recipe: str = "tp"

    def spec(self, *dims: AxisSpec) -> P:
        return P(*[d if d else None for d in dims])


def _norm(axes: AxisSpec) -> AxisSpec:
    if axes is None:
        return None
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes) or None


def constrain(x: Array, ctx: ShardCtx | None, *dims: AxisSpec) -> Array:
    """with_sharding_constraint if ctx is set; identity otherwise."""
    if ctx is None:
        return x
    spec = P(*[_norm(d) for d in dims])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def constrain_tree(tree: Any, ctx: ShardCtx | None,
                   spec_fn) -> Any:
    """Constrain every leaf; ``spec_fn(path, leaf) -> tuple of AxisSpec``."""
    if ctx is None:
        return tree
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        dims = spec_fn(path, leaf)
        out.append(constrain(leaf, ctx, *dims) if dims is not None else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
