"""Elastic scaling: rebuild the mesh around failed hardware and reshard.

Fail-stop recovery at pod scale is checkpoint/restart shaped (synchronous
SPMD cannot lose a participant mid-step), so elasticity here means:

  * ``best_mesh_shape`` — given the surviving chip count, pick the largest
    (data, model) grid the framework supports (model axis preserved when
    possible: changing TP degree changes per-op shapes; shrinking the data
    axis only changes throughput);
  * ``reshard_state`` — load a checkpoint saved under ANY mesh onto the new
    mesh (checkpoints store gathered arrays — `repro.checkpoint.ckpt`);
  * ``ElasticPlan`` — what the launcher logs/acts on.

The serving side is elastic by construction: ``HedgedScheduler`` treats
replicas as independent resources — `add_replica`/`remove_replica` at
runtime — and the paper's redundancy masks a replica that dies mid-request
(tested in test_serving.py::test_replica_failure_masked).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh

from repro.checkpoint import ckpt
from repro.distributed import sharding
from repro.configs.base import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    healthy_devices: int
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    dropped_devices: int
    global_batch_scale: float  # relative DP throughput vs nominal


def best_mesh_shape(healthy: int, model_degree: int = 16,
                    nominal_data: int = 16) -> tuple[int, int]:
    """Largest (data, model) grid on ``healthy`` chips, preferring to keep
    the model (TP) degree fixed and shrink data parallelism."""
    for m in (model_degree, model_degree // 2, model_degree // 4, 1):
        if m == 0:
            continue
        data = healthy // m
        if data >= 1:
            return (data, m)
    return (1, 1)


def plan_for(healthy: int, model_degree: int = 16,
             nominal: int = 256) -> ElasticPlan:
    data, model = best_mesh_shape(healthy, model_degree)
    used = data * model
    return ElasticPlan(
        healthy_devices=healthy,
        mesh_shape=(data, model),
        axis_names=("data", "model"),
        dropped_devices=healthy - used,
        global_batch_scale=(data * model) / nominal)


def reshard_state(cfg: ModelConfig, ckpt_dir: str, step: int,
                  like: PyTree, new_mesh: Mesh) -> PyTree:
    """Restore a checkpoint onto ``new_mesh`` (any shape) with the arch's
    sharding rules re-derived for that mesh."""
    shardings = sharding.param_shardings(cfg, new_mesh, like)
    return ckpt.restore(ckpt_dir, step, like, shardings=shardings)
