"""Sharding rules: map (param/cache path, shape) -> PartitionSpec, and build
activation layouts per (recipe x step kind).

Two recipes (chosen per arch in its config):
  * ``tp``   — Megatron-style: attention heads / d_ff / experts / vocab over
    the 16-way ``model`` axis; batch over ``data`` (and ``pod``); large
    params additionally ZeRO-sharded over ``data`` on a free dimension.
  * ``fsdp`` — for archs whose head count does not divide 16 (gemma2 8H,
    granite 24H, llava 56H): batch over ``data x model``; every large param
    sharded over ("data","model") on its largest divisible dim and gathered
    at use (ZeRO-3); MoE experts still EP over ``model``.

Decode always shards the KV-cache SEQUENCE over ``model`` (plus ``data`` and
``pod`` for long_500k) — divisibility-free w.r.t. head counts, and the
natural layout for flash-decoding-style distributed attention.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed.ctx import ShardCtx

PyTree = Any

ZERO_MIN_SIZE = 1 << 20  # leaves smaller than 1 MiB-ish stay replicated


# ---------------------------------------------------------------------------
# Path utilities
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path_str(fn, tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [fn(_path_str(path), leaf) for path, leaf in flat])


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------


def _tp_base_spec(path: str, shape: tuple[int, ...],
                  cfg: ModelConfig) -> list:
    """Base spec (no stacking, no ZeRO) for the tp recipe."""
    m = "model"
    heads_ok = cfg.n_heads % 16 == 0
    kv_ok = cfg.n_kv_heads % 16 == 0
    spec: list = [None] * len(shape)
    if "embed/table" in path:
        # vocab-sharded: local logits + tiny logsumexp psum in the CE;
        # fall back to d_model sharding when the vocab is not divisible
        # (mamba2's 50280).
        if shape[-2] % 16 == 0:
            spec[-2] = m
        elif shape[-1] % 16 == 0:
            spec[-1] = m
        return spec
    if "lm_head/w" in path:
        if shape[-1] % 16 == 0:
            spec[-1] = m
        elif shape[-2] % 16 == 0:
            spec[-2] = m
        return spec
    if path.endswith("mixer/wq/w") or path.endswith("mixer/wq/b"):
        if heads_ok:
            spec[-2 if path.endswith("w") else -2] = m
        return spec
    if any(path.endswith(s) for s in ("mixer/wk/w", "mixer/wv/w",
                                      "mixer/wk/b", "mixer/wv/b")):
        if kv_ok:
            spec[-2] = m
        return spec
    if path.endswith("mixer/wo/w"):
        if heads_ok:
            spec[-3] = m
        return spec
    # MLA
    if any(s in path for s in ("wuq/w", "wuk/w", "wuv/w")):
        spec[-2] = m  # head dim (deepseek: 128 heads)
        return spec
    if "mixer/wo/w" in path:
        spec[-3] = m
        return spec
    # dense MLP (gate/up column-parallel, out row-parallel)
    if any(path.endswith(s) for s in ("mlp/gate/w", "mlp/up/w",
                                      "shared/gate/w", "shared/up/w")):
        spec[-1] = m
        return spec
    if any(path.endswith(s) for s in ("mlp/gate/b", "mlp/up/b",
                                      "shared/gate/b", "shared/up/b")):
        spec[-1] = m
        return spec
    if path.endswith("mlp/out/w") or path.endswith("shared/out/w"):
        spec[-2] = m
        return spec
    # MoE experts (E leading dim)
    if any(s in path for s in ("mlp/w_up", "mlp/w_gate", "mlp/w_out")):
        spec[-3] = m
        return spec
    # SSD
    if any(path.endswith(s) for s in ("z_proj/w", "x_proj/w", "dt_proj/w")):
        spec[-1] = m
        return spec
    if path.endswith("conv_x_w"):
        spec[-1] = m
        return spec
    if any(path.endswith(s) for s in ("conv_x_b", "dt_bias", "a_log",
                                      "d_skip")):
        spec[-1] = m
        return spec
    if "mixer/norm/scale" in path:  # SSD gated-norm over d_inner
        spec[-1] = m
        return spec
    if path.endswith("out_proj/w"):
        spec[-2] = m
        return spec
    # RG-LRU
    if any(path.endswith(s) for s in ("in_gate/w", "in_rec/w")):
        spec[-1] = m
        return spec
    if path.endswith("conv_w"):
        spec[-1] = m
        return spec
    if path.endswith("conv_b") or path.endswith("lam"):
        spec[-1] = m
        return spec
    if path.endswith("wa") or path.endswith("wx"):
        spec[-3] = m
        return spec
    if path.endswith("ba") or path.endswith("bx"):
        spec[-2] = m
        return spec
    if path.endswith("mixer/out/w"):
        spec[-2] = m
        return spec
    return spec  # norms, router, biases, small projections: replicated


def _fsdp_base_spec(path: str, shape: tuple[int, ...],
                    cfg: ModelConfig) -> list:
    """fsdp recipe: largest divisible dim over ('data','model')."""
    spec: list = [None] * len(shape)
    if any(s in path for s in ("mlp/w_up", "mlp/w_gate", "mlp/w_out")):
        spec[-3] = "model"  # EP for experts
        if shape[-2] % 16 == 0 and _size(shape) >= ZERO_MIN_SIZE:
            spec[-2] = "data"
        return spec
    if "router" in path or _size(shape) < ZERO_MIN_SIZE:
        return spec
    # pick the largest dim divisible by |data|*|model| = 256, else by 16
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if shape[i] % 256 == 0:
            spec[i] = ("data", "model")
            return spec
    for i in order:
        if shape[i] % 16 == 0:
            spec[i] = "data"
            return spec
    return spec


def _size(shape: tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def _zero_over_data(spec: list, shape: tuple[int, ...],
                    path: str = "") -> list:
    """tp recipe: additionally shard one free dim of large params over
    ``data`` (ZeRO-style; gathered at use). Embedding tables are exempt:
    ZeRO-sharding the gather's embedding dim forces SPMD into an
    "involuntary full rematerialization" of the gathered activations
    (observed in the nemotron dry-run) — far costlier than the memory it
    saves."""
    if _size(shape) < ZERO_MIN_SIZE:
        return spec
    if "embed/table" in path or "lm_head" in path:
        return spec
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and shape[i] % 16 == 0:
            spec[i] = "data"
            return spec
    return spec


def _inference_base_spec(path: str, shape: tuple[int, ...],
                         cfg: ModelConfig,
                         ep_axes: tuple[str, ...]) -> list:
    """Decode-time rule: weights are read once per TOKEN, so ZeRO-style
    gather-at-use is catastrophic (it re-gathers the model every step).
    Instead: experts sharded over all EP axes (tokens move, not weights);
    every other matrix sharded on its largest model-divisible dim (the
    per-layer psum of a (B, 1, D) activation is tiny); no data-axis
    sharding (replicas of the non-expert weights across `data` serve the
    batch in parallel)."""
    spec: list = [None] * len(shape)
    if any(s in path for s in ("mlp/w_up", "mlp/w_gate", "mlp/w_out")):
        # fall back to model-only EP if the expert count doesn't divide
        ep = ep_axes
        size = 1
        for a in ep:
            size *= {"pod": 2, "data": 16, "model": 16}[a]
        if shape[-3] % size != 0:
            ep = ("model",)
        spec[-3] = ep[0] if len(ep) == 1 else ep
        return spec
    if "embed/table" in path:
        if shape[-2] % 16 == 0:
            spec[-2] = "model"
        elif shape[-1] % 16 == 0:
            spec[-1] = "model"
        return spec
    if "lm_head/w" in path:
        if shape[-1] % 16 == 0:
            spec[-1] = "model"
        elif shape[-2] % 16 == 0:
            spec[-2] = "model"
        return spec
    if "router" in path or _size(shape) < (1 << 16):
        return spec
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if shape[i] % 16 == 0:
            spec[i] = "model"
            return spec
    return spec


def param_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig, *,
               inference: bool = False,
               ep_axes: tuple[str, ...] = ("model",)) -> P:
    stacked = path.startswith("blocks/")
    base_shape = shape[1:] if stacked else shape
    if inference:
        spec = _inference_base_spec(path, base_shape, cfg, ep_axes)
    elif cfg.recipe == "fsdp":
        spec = _fsdp_base_spec(path, base_shape, cfg)
    else:
        spec = _tp_base_spec(path, base_shape, cfg)
        spec = _zero_over_data(spec, base_shape, path)
    if stacked:
        spec = [None] + spec
    return P(*spec)


def param_shardings(cfg: ModelConfig, mesh: Mesh,
                    params_shapes: PyTree, *, inference: bool = False,
                    ep_axes: tuple[str, ...] = ("model",)) -> PyTree:
    return tree_map_with_path_str(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf.shape, cfg, inference=inference,
                             ep_axes=ep_axes)),
        params_shapes)


def opt_shardings(cfg: ModelConfig, mesh: Mesh, opt_shapes: PyTree,
                  params_shapes: PyTree) -> PyTree:
    """Optimizer state mirrors params; Adafactor's factored leaves drop the
    corresponding trailing dims of the param spec."""
    param_specs = tree_map_with_path_str(
        lambda path, leaf: param_spec(path, leaf.shape, cfg), params_shapes)

    def spec_for(path: str, leaf) -> NamedSharding:
        # path looks like "m/<param path>" / "v_row/<param path>" etc.
        head, _, rest = path.partition("/")
        sub = _lookup(param_specs, rest)
        if sub is None:
            return NamedSharding(mesh, P())
        base = list(sub)
        nd = len(leaf.shape)
        if head == "v_row" and len(base) == nd + 1:
            spec = base[:-1]            # param shape minus last dim
        elif head == "v_col" and len(base) == nd + 1:
            spec = base[:-2] + base[-1:]  # minus second-to-last dim
        elif len(base) == nd:           # m / v / master / unfactored v_col
            spec = base
        else:                           # unfactored v_row placeholder (1,)
            spec = [None] * nd
        return NamedSharding(mesh, P(*spec))

    return tree_map_with_path_str(spec_for, opt_shapes)


def _lookup(tree: PyTree, path: str):
    cur = tree
    for part in path.split("/"):
        if isinstance(cur, dict):
            if part not in cur:
                return None
            cur = cur[part]
        elif isinstance(cur, (list, tuple)):
            try:
                cur = cur[int(part)]
            except (ValueError, IndexError):
                return None
        else:
            return cur
    return cur


# ---------------------------------------------------------------------------
# Activation layouts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Layout:
    batch: tuple[str, ...] | None
    seq: tuple[str, ...] | None
    kv_seq: tuple[str, ...] | None
    ep_axes: tuple[str, ...] = ("model",)
    inference: bool = False


def _decode_ep_axes(cfg: ModelConfig, multi_pod: bool) -> tuple[str, ...]:
    """Decode EP: spread experts over every axis that divides them —
    deepseek's 256 experts cover the full (model x data) 256 chips."""
    if cfg.moe is None:
        return ("model",)
    e = cfg.moe.padded_experts
    axes: tuple[str, ...] = ("model",)
    if e % 256 == 0:
        axes = ("model", "data")
    if multi_pod and e % 512 == 0:
        axes = ("model", "data", "pod")
    return axes


def make_layout(cfg: ModelConfig, kind: str, multi_pod: bool,
                global_batch: int) -> Layout:
    pod = ("pod",) if multi_pod else ()
    if cfg.recipe == "fsdp":
        if kind == "train":
            return Layout(batch=("data", "model"), seq=pod or None,
                          kv_seq=None)
        if kind == "prefill":
            return Layout(batch=("data",), seq=(*pod, "model"), kv_seq=None)
        # decode
        ep = _decode_ep_axes(cfg, multi_pod)
        if global_batch == 1:
            return Layout(batch=None, seq=None,
                          kv_seq=(*pod, "data", "model"), ep_axes=ep,
                          inference=True)
        return Layout(batch=(*pod, "data"), seq=None, kv_seq=("model",),
                      ep_axes=ep, inference=True)
    # tp
    if kind in ("train", "prefill"):
        batch = (*pod, "data")
        if global_batch % _axes_size_guess(batch) != 0:
            batch = ("data",)
        return Layout(batch=batch, seq=None, kv_seq=None)
    ep = _decode_ep_axes(cfg, multi_pod)
    if global_batch == 1:
        return Layout(batch=None, seq=None, kv_seq=(*pod, "data", "model"),
                      ep_axes=ep, inference=True)
    return Layout(batch=(*pod, "data"), seq=None, kv_seq=("model",),
                  ep_axes=ep, inference=True)


def _axes_size_guess(axes: tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= {"pod": 2, "data": 16, "model": 16}[a]
    return size


def make_ctx(cfg: ModelConfig, mesh: Mesh, layout: Layout) -> ShardCtx:
    return ShardCtx(mesh=mesh, batch=layout.batch, seq=layout.seq,
                    kv_seq=layout.kv_seq, model_axis="model",
                    ep_axes=layout.ep_axes, recipe=cfg.recipe)


# ---------------------------------------------------------------------------
# Cache + batch shardings
# ---------------------------------------------------------------------------


def _divides(axes: tuple[str, ...] | None, mesh: Mesh, dim: int) -> bool:
    if not axes:
        return False
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim % size == 0


def cache_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig,
               mesh: Mesh, layout: Layout) -> P:
    stacked = path.startswith("blocks/")
    base = shape[1:] if stacked else shape
    spec: list = [None] * len(base)
    b_axes = layout.batch if _divides(layout.batch, mesh, base[0]) else None
    kv = layout.kv_seq
    leaf = path.rsplit("/", 1)[-1]
    if leaf in ("k", "v"):
        spec[0] = b_axes
        if _divides(kv, mesh, base[1]):
            spec[1] = kv
        elif kv and base[1] % mesh.shape["model"] == 0:
            spec[1] = ("model",)
    elif leaf in ("c_kv", "k_rope"):
        spec[0] = b_axes
        if _divides(kv, mesh, base[1]):
            spec[1] = kv
        elif kv and base[1] % mesh.shape["model"] == 0:
            spec[1] = ("model",)
    elif leaf == "pos":
        pass  # replicated slot-position vectors
    elif leaf == "h" and len(base) == 4:   # ssd state (B, H, P, N)
        spec[0] = b_axes
        if base[1] % mesh.shape["model"] == 0:
            spec[1] = ("model",)
    elif leaf == "h":                       # rglru state (B, W)
        spec[0] = b_axes
        if base[-1] % mesh.shape["model"] == 0:
            spec[-1] = ("model",)
    elif leaf in ("x", "conv"):             # conv states (B, cw-1, C)
        spec[0] = b_axes
        if base[-1] % mesh.shape["model"] == 0:
            spec[-1] = ("model",)
    elif leaf == "bc":
        spec[0] = b_axes
    if stacked:
        spec = [None] + spec
    return P(*spec)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, layout: Layout,
                    cache_shapes: PyTree) -> PyTree:
    return tree_map_with_path_str(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(path, leaf.shape, cfg, mesh, layout)),
        cache_shapes)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, layout: Layout,
                    batch_shapes: PyTree) -> PyTree:
    def spec(path: str, leaf) -> NamedSharding:
        dims: list = [None] * len(leaf.shape)
        if _divides(layout.batch, mesh, leaf.shape[0]):
            dims[0] = layout.batch
        if "tokens" in path and len(leaf.shape) >= 2 and \
                _divides(layout.seq, mesh, leaf.shape[1]):
            dims[1] = layout.seq
        return NamedSharding(mesh, P(*dims))

    return tree_map_with_path_str(spec, batch_shapes)
