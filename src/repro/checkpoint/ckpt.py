"""Checkpointing: atomic, restart-safe, elastic.

Layout per checkpoint:  <dir>/step_<N>/
    arrays.npz   — flattened leaves keyed by tree path (bf16 stored as a
                   uint16 view; true dtype recorded in meta)
    meta.json    — step, leaf dtypes

Properties:
  * atomic publish (write to ``.tmp`` dir, rename) — a crash mid-save never
    corrupts the latest checkpoint (tested by killing mid-save);
  * elastic restore — arrays are saved unsharded (gathered), so a restart
    can device_put them onto a DIFFERENT mesh/sharding (elastic rescale);
  * ``AsyncCheckpointer`` overlaps serialization+IO with training (double
    buffered, at most one outstanding save).
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "||"


def _key_of(path) -> str:
    return _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)


def _snapshot(tree: PyTree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Gathered numpy arrays (bf16 viewed as uint16) + dtype metadata."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays: dict[str, np.ndarray] = {}
    dtypes: dict[str, str] = {}
    for path, leaf in flat:
        key = _key_of(path)
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[key] = arr
    return arrays, dtypes


def _publish(directory: Path, step: int, arrays: dict[str, np.ndarray],
             dtypes: dict[str, str], keep_last: int) -> Path:
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "meta.json").write_text(json.dumps(
        {"step": step, "dtypes": dtypes, "fmt": 1}))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _cleanup(directory, keep_last)
    return final


def save(directory: str | Path, step: int, tree: PyTree,
         keep_last: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    arrays, dtypes = _snapshot(tree)
    return _publish(directory, step, arrays, dtypes, keep_last)


def _cleanup(directory: Path, keep_last: int) -> None:
    ckpts = sorted(d for d in directory.iterdir()
                   if d.is_dir() and d.name.startswith("step_"))
    for old in ckpts[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)
    for stale in directory.iterdir():
        if stale.name.startswith(".tmp_step_"):
            shutil.rmtree(stale, ignore_errors=True)


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(d.name.split("_")[1]) for d in directory.iterdir()
             if d.is_dir() and d.name.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str | Path, step: int, like: PyTree,
            shardings: PyTree | None = None) -> PyTree:
    """Restore into the structure of ``like`` (ShapeDtypeStructs or arrays).
    With ``shardings`` given, leaves are device_put with those shardings —
    the mesh may differ from the one that saved (elastic restart)."""
    ckpt_dir = Path(directory) / f"step_{step:08d}"
    meta = json.loads((ckpt_dir / "meta.json").read_text())
    data = np.load(ckpt_dir / "arrays.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_flat = None
    if shardings is not None:
        sh_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        key = _key_of(path)
        arr = data[key]
        if meta["dtypes"].get(key) == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        out = jnp.asarray(arr).astype(leaf.dtype)
        if sh_flat is not None:
            out = jax.device_put(out, sh_flat[i])
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Overlap checkpoint IO with training (one outstanding save)."""

    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.directory = Path(directory)
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree: PyTree) -> None:
        self.wait()
        # snapshot on the caller thread (consistent view), IO in background
        arrays, dtypes = _snapshot(tree)
        self.directory.mkdir(parents=True, exist_ok=True)

        def work():
            try:
                _publish(self.directory, step, arrays, dtypes,
                         self.keep_last)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
