"""Atomic + async checkpointing with elastic (resharded) restore."""
