"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU + gated output.

Block structure (Griffin recurrent block):
    x -> [linear -> GeLU]                  (gate branch)
      -> [linear -> causal conv1d(w=4) -> RG-LRU]  (recurrent branch)
    y  = gate * recurrent  -> linear out

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    a_t = exp(c * softplus(Lambda) * (-r_t))   in (0, 1)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

This is an elementwise linear recurrence h_t = a_t h_{t-1} + b_t — the
full-sequence path uses an associative scan (O(log L) depth), with a Pallas
chunked-scan kernel as the TPU-target implementation. Decode carries
(conv_state, h) — O(1) per token, which is what makes long_500k decoding
trivial for this family.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.models import layers
from repro.models.layers import init_linear, linear

Array = jax.Array
PyTree = Any


N_GATE_BLOCKS = 16  # block-diagonal gates (as in the official recurrentgemma
                    # implementation) — shardable over a 16-way model axis


def init_rglru_block(key: Array, d_model: int, cfg: RGLRUConfig,
                     dtype=layers.DEFAULT_PARAM_DTYPE) -> PyTree:
    w = cfg.lru_width or d_model
    nb = N_GATE_BLOCKS
    assert w % nb == 0, f"lru_width {w} % {nb} != 0"
    ks = jax.random.split(key, 6)
    return {
        "in_gate": init_linear(ks[0], d_model, w, dtype=dtype),
        "in_rec": init_linear(ks[1], d_model, w, dtype=dtype),
        "conv_w": layers.truncated_normal(ks[2], (cfg.conv_width, w),
                                          scale=cfg.conv_width**-0.5,
                                          dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype=dtype),
        # block-diagonal RG-LRU gates on the recurrent branch
        "wa": layers.truncated_normal(ks[3], (nb, w // nb, w // nb),
                                      scale=(w // nb)**-0.5, dtype=dtype),
        "ba": jnp.zeros((nb, w // nb), dtype=dtype),
        "wx": layers.truncated_normal(ks[4], (nb, w // nb, w // nb),
                                      scale=(w // nb)**-0.5, dtype=dtype),
        "bx": jnp.zeros((nb, w // nb), dtype=dtype),
        "lam": jnp.full((w,), 2.0, dtype=jnp.float32),  # softplus(2) ~ 2.1
        "out": init_linear(ks[5], w, d_model, dtype=dtype),
    }


def _block_linear(w: Array, b: Array, u: Array) -> Array:
    """Block-diagonal linear: u (..., W) with W = nb * bw."""
    nb, bw, _ = w.shape
    ub = u.reshape(*u.shape[:-1], nb, bw)
    y = jnp.einsum("...nb,nbc->...nc", ub, w.astype(u.dtype))
    y = y + b.astype(u.dtype)
    return y.reshape(*u.shape)


def _gates(p: PyTree, cfg: RGLRUConfig, u: Array):
    """a_t and b_t for the linear recurrence h_t = a h + b, fp32."""
    r = jax.nn.sigmoid(_block_linear(p["wa"], p["ba"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_linear(p["wx"], p["bx"], u).astype(jnp.float32))
    log_a = -cfg.c_exponent * jax.nn.softplus(p["lam"]) * r   # (..., W) < 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, b


def causal_conv1d(w: Array, b: Array, x: Array,
                  state: Array | None = None) -> tuple[Array, Array]:
    """Depthwise causal conv. x (B, L, W); state (B, cw-1, W) carries the
    last cw-1 inputs for decode. Returns (y, new_state)."""
    cw = w.shape[0]
    bsz, length, width = x.shape
    if state is None:
        state = jnp.zeros((bsz, cw - 1, width), dtype=x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, cw-1+L, W)
    y = jnp.zeros_like(x)
    for i in range(cw):
        y = y + xp[:, i:i + length] * w[i][None, None, :].astype(x.dtype)
    y = y + b[None, None, :].astype(x.dtype)
    new_state = xp[:, -(cw - 1):] if cw > 1 else state
    return y, new_state


def linear_scan(a: Array, b: Array, h0: Array | None = None) -> Array:
    """h_t = a_t h_{t-1} + b_t along axis 1, via associative scan."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(p: PyTree, x: Array, cfg: RGLRUConfig, *,
                impl: str = "ref", return_state: bool = False):
    """Full-sequence recurrent block (training / prefill). x (B, L, D)."""
    gate = jax.nn.gelu(linear(p["in_gate"], x), approximate=True)
    u = linear(p["in_rec"], x)
    u, conv_state = causal_conv1d(p["conv_w"], p["conv_b"], u)
    a, b = _gates(p, cfg, u)
    if impl == "pallas":
        from repro.kernels.rglru_scan import ops as scan_ops
        h = scan_ops.chunked_linear_scan(a, b)
    else:
        h = linear_scan(a, b)
    y = h.astype(x.dtype) * gate
    out = linear(p["out"], y)
    if return_state:
        return out, {"conv": conv_state, "h": h[:, -1]}
    return out


def init_rglru_cache(batch: int, d_model: int, cfg: RGLRUConfig,
                     dtype=jnp.float32) -> PyTree:
    w = cfg.lru_width or d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype=jnp.bfloat16),
        "h": jnp.zeros((batch, w), dtype=dtype),
    }


def rglru_decode(p: PyTree, x: Array, cache: PyTree, cfg: RGLRUConfig
                 ) -> tuple[Array, PyTree]:
    """One-token step. x (B, 1, D)."""
    gate = jax.nn.gelu(linear(p["in_gate"], x), approximate=True)
    u = linear(p["in_rec"], x)
    u, conv_state = causal_conv1d(p["conv_w"], p["conv_b"], u, cache["conv"])
    a, b = _gates(p, cfg, u)  # (B, 1, W)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = h[:, None].astype(x.dtype) * gate
    return linear(p["out"], y), {"conv": conv_state, "h": h}
