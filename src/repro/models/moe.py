"""Mixture-of-Experts MLP: top-k routing, static capacity, shared experts.

Layout: expert weights are (E, D, F) sharded over the ``model`` mesh axis
(expert parallelism). ``moe_mlp`` operates on a LOCAL slice of experts
([e_start, e_start + e_local)) with the FULL router, so it can run:

  * single-device (tests): e_start=0, e_local=E — the plain dense path;
  * under ``shard_map`` (production): each model-rank routes the tokens it
    can see, keeps only assignments that hit its local experts, computes the
    (E_local, C, D) buffer, and the caller psums over the model axis
    (`tp` recipe: tokens model-replicated) or all-gathers tokens first and
    psum-scatters after (`fsdp` recipe: tokens model-sharded).

Dispatch is sort-free masked-capacity (position-in-expert via cumsum, tokens
beyond capacity dropped) — static shapes, TPU-friendly. Padded experts
(granite 40 -> 48 so EP16 divides) are masked to -inf in the router.

The Switch-style aux load-balance loss is returned for the trainer;
DeepSeek's bias-based aux-free balancing is approximated by this loss
(noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import layers
from repro.models.layers import activation, init_mlp, mlp

Array = jax.Array
PyTree = Any


def init_moe(key: Array, d_model: int, cfg: MoEConfig, gated: bool,
             dtype=layers.DEFAULT_PARAM_DTYPE) -> PyTree:
    ks = jax.random.split(key, 5)
    e = cfg.padded_experts
    f = cfg.d_expert
    p = {
        "router": {"w": layers.truncated_normal(
            ks[0], (d_model, e), scale=d_model**-0.5, dtype=jnp.float32)},
        "w_up": layers.truncated_normal(ks[1], (e, d_model, f),
                                        scale=d_model**-0.5, dtype=dtype),
        "w_out": layers.truncated_normal(ks[2], (e, f, d_model),
                                         scale=f**-0.5, dtype=dtype),
    }
    if gated:
        p["w_gate"] = layers.truncated_normal(ks[3], (e, d_model, f),
                                              scale=d_model**-0.5, dtype=dtype)
    if cfg.n_shared:
        d_sh = (cfg.d_shared or cfg.d_expert) * cfg.n_shared
        p["shared"] = init_mlp(ks[4], d_model, d_sh, gated, dtype=dtype)
    return p


def route(router_w: Array, xt: Array, cfg: MoEConfig):
    """(T, D) -> top-k indices (T, k), combine weights (T, k), aux loss."""
    e = cfg.padded_experts
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w)
    if e != cfg.n_experts:
        pad = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad[None, :], -1e30, logits)
    gates, idx = jax.lax.top_k(logits, cfg.top_k)
    weights = jax.nn.softmax(gates, axis=-1)
    probs_full = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)          # (T, k, E)
    token_mask = jnp.sum(onehot, axis=1)                         # (T, E)
    aux = cfg.aux_loss_weight * cfg.n_experts * jnp.sum(
        jnp.mean(token_mask, axis=0) * jnp.mean(probs_full, axis=0))
    return idx, weights, token_mask, aux


def moe_mlp(p: PyTree, x: Array, cfg: MoEConfig, act: str, *,
            e_start: int = 0, e_local: int | None = None,
            capacity: int | None = None) -> tuple[Array, Array]:
    """MoE over a local slice of experts. x (..., D) -> (y, aux).

    ``p['w_up']``/``w_gate``/``w_out`` hold only the local experts
    (leading dim e_local); ``p['router']`` is always the full router.
    """
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    e = cfg.padded_experts
    e_local = e_local if e_local is not None else e
    k = cfg.top_k

    idx, weights, token_mask, aux = route(p["router"]["w"], xt, cfg)

    cap = capacity or max(1, int(t * k / cfg.n_experts * cfg.capacity_factor))
    # position of each token within its expert's queue (over ALL experts,
    # so capacity accounting is identical no matter how experts are sharded)
    pos_in_e = (jnp.cumsum(token_mask, axis=0) - 1.0) * token_mask  # (T, E)
    pos = jnp.einsum("tke,te->tk", jax.nn.one_hot(idx, e, dtype=jnp.float32),
                     pos_in_e).astype(jnp.int32)
    keep = pos < cap

    # keep only assignments routed to local experts
    local = (idx >= e_start) & (idx < e_start + e_local) & keep
    local_e = jnp.where(local, idx - e_start, e_local)           # drop row
    flat_e = local_e.reshape(-1)
    flat_pos = jnp.where(local, pos, cap).reshape(-1)

    buf = jnp.zeros((e_local + 1, cap + 1, d), dtype=x.dtype)
    src = jnp.repeat(xt, k, axis=0)
    buf = buf.at[flat_e, flat_pos].add(src)
    buf = buf[:e_local, :cap]                                    # (E_l, C, D)

    if "w_gate" in p:
        h = activation(act, jnp.einsum("ecd,edf->ecf", buf,
                                       p["w_gate"].astype(buf.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
    else:
        h = activation(act, jnp.einsum("ecd,edf->ecf", buf,
                                       p["w_up"].astype(buf.dtype)))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(h.dtype))

    w_flat = (weights * local).reshape(-1).astype(out_buf.dtype)
    gathered = out_buf[jnp.minimum(flat_e, e_local - 1),
                       jnp.minimum(flat_pos, cap - 1)]           # (T*k, D)
    y = jnp.sum((gathered * w_flat[:, None]).reshape(t, k, d), axis=1)

    if "shared" in p:  # shared expert(s): dense, every token
        y = y + mlp(p["shared"], xt, act)
    return y.reshape(shape), aux
