"""Mamba-2 block: state-space duality (SSD), chunked full-sequence path.

Per-head scalar-decay SSM:
    h_t = exp(dt_t * A) h_{t-1} + dt_t * (x_t outer B_t)     h: (P, N)
    y_t = h_t @ C_t + D * x_t

Full-sequence (training/prefill) uses the SSD chunked algorithm: the
sequence is split into chunks of Q tokens; within a chunk the output is an
attention-like quadratic term (the "duality"); across chunks a cheap scan
propagates the (H, P, N) state. The quadratic intra-chunk term is the
compute hot spot and is what the Pallas ``ssd_scan`` kernel implements; the
pure-jnp version here is its oracle and the CPU/dry-run path.

Projections are SPLIT (z / x / BC / dt) rather than fused so tensor
parallelism can shard the d_inner and head dims over the model axis while
keeping the small B/C projections replicated.

Decode carries (conv_state, ssm_state) — O(1) per token (long_500k-ready).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import layers
from repro.models.layers import init_linear, linear

Array = jax.Array
PyTree = Any


def dims(d_model: int, cfg: SSMConfig) -> tuple[int, int]:
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    return d_inner, n_heads


def init_ssd_block(key: Array, d_model: int, cfg: SSMConfig,
                   dtype=layers.DEFAULT_PARAM_DTYPE) -> PyTree:
    d_inner, n_heads = dims(d_model, cfg)
    ks = jax.random.split(key, 7)
    return {
        "z_proj": init_linear(ks[0], d_model, d_inner, dtype=dtype),
        "x_proj": init_linear(ks[1], d_model, d_inner, dtype=dtype),
        "bc_proj": init_linear(ks[2], d_model, 2 * cfg.d_state, dtype=dtype),
        "dt_proj": init_linear(ks[3], d_model, n_heads, dtype=dtype),
        "conv_x_w": layers.truncated_normal(ks[4], (cfg.d_conv, d_inner),
                                            scale=cfg.d_conv**-0.5,
                                            dtype=dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype=dtype),
        "conv_bc_w": layers.truncated_normal(ks[5], (cfg.d_conv,
                                                     2 * cfg.d_state),
                                             scale=cfg.d_conv**-0.5,
                                             dtype=dtype),
        "conv_bc_b": jnp.zeros((2 * cfg.d_state,), dtype=dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype=jnp.float32),
        "a_log": jnp.zeros((n_heads,), dtype=jnp.float32),  # A = -exp(a_log)
        "d_skip": jnp.ones((n_heads,), dtype=jnp.float32),
        "norm": layers.init_rmsnorm(d_inner),
        "out_proj": init_linear(ks[6], d_inner, d_model, dtype=dtype),
    }


def _prep(p: PyTree, x: Array, cfg: SSMConfig,
          conv_state: PyTree | None):
    """Shared front end: projections, convs, activations."""
    from repro.models.rglru import causal_conv1d

    d_model = x.shape[-1]
    d_inner, n_heads = dims(d_model, cfg)
    z = linear(p["z_proj"], x)
    xs = linear(p["x_proj"], x)
    bc = linear(p["bc_proj"], x)
    dt = linear(p["dt_proj"], x)
    cs_x = conv_state["x"] if conv_state else None
    cs_bc = conv_state["bc"] if conv_state else None
    xs, new_cs_x = causal_conv1d(p["conv_x_w"], p["conv_x_b"], xs, cs_x)
    bc, new_cs_bc = causal_conv1d(p["conv_bc_w"], p["conv_bc_b"], bc, cs_bc)
    xs = jax.nn.silu(xs)
    bc = jax.nn.silu(bc)
    b = bc[..., :cfg.d_state]
    c = bc[..., cfg.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, L, H)
    a = -jnp.exp(p["a_log"])                                     # (H,)
    bsz, length = x.shape[:2]
    xh = xs.reshape(bsz, length, n_heads, cfg.head_dim)
    new_conv = {"x": new_cs_x, "bc": new_cs_bc}
    return z, xs, xh, b, c, dt, a, new_conv, d_inner, n_heads


def ssd_reference(xh: Array, b: Array, c: Array, dt: Array, a: Array,
                  h0: Array | None = None) -> tuple[Array, Array]:
    """Exact sequential recurrence (the oracle). xh (B,L,H,P), b/c (B,L,N),
    dt (B,L,H), a (H,). Returns (y (B,L,H,P), final state (B,H,P,N))."""
    bsz, length, n_heads, hd = xh.shape
    n = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, n_heads, hd, n), dtype=jnp.float32)

    def step(h, inp):
        xt, bt, ct, dtt = inp  # (B,H,P), (B,N), (B,N), (B,H)
        decay = jnp.exp(dtt * a[None, :])                      # (B,H)
        upd = (dtt[..., None, None] * xt[..., None]
               * bt[:, None, None, :])                         # (B,H,P,N)
        h = decay[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (xh.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32), dt.swapaxes(0, 1))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h_final


def ssd_chunked(xh: Array, b: Array, c: Array, dt: Array, a: Array,
                chunk: int, h0: Array | None = None,
                impl: str = "ref") -> tuple[Array, Array]:
    """SSD chunked algorithm. Same contract as ``ssd_reference``."""
    bsz, length, n_heads, hd = xh.shape
    n = b.shape[-1]
    q = chunk
    orig_len = length
    if length % q:
        # pad to a chunk multiple: dt=0 => decay=1 and no state update, so
        # padded steps are identity on the state and sliced off the output.
        pad = q - length % q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        length += pad
    nc = length // q

    xc = xh.reshape(bsz, nc, q, n_heads, hd).astype(jnp.float32)
    bc = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, n_heads)

    log_decay = dtc * a[None, None, None, :]                   # (B,NC,Q,H) <0
    cum = jnp.cumsum(log_decay, axis=2)                        # inclusive
    total = cum[:, :, -1:]                                     # (B,NC,1,H)

    if impl == "pallas":
        from repro.kernels.ssd_scan import ops as ssd_ops
        y_intra, states = ssd_ops.ssd_intra_chunk(xc, bc, cc, dtc, cum)
    else:
        # intra-chunk "attention": L[q,s] = exp(cum_q - cum_s) for s <= q
        rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,NC,Q,Q,H)
        mask = jnp.tril(jnp.ones((q, q), dtype=bool))
        gate = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("bcqn,bcsn->bcqs", cc, bc)
        w = scores[..., None] * gate * dtc[:, :, None, :, :]   # (B,NC,Q,S,H)
        y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", w, xc)
        # per-chunk contributed state: sum_s exp(total - cum_s) dt_s x_s B_s
        sgate = jnp.exp(total - cum) * dtc                     # (B,NC,Q,H)
        states = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", sgate, xc, bc)

    # inter-chunk scan over the (small) per-chunk states
    if h0 is None:
        h0 = jnp.zeros((bsz, n_heads, hd, n), dtype=jnp.float32)
    chunk_decay = jnp.exp(total[:, :, 0]).swapaxes(0, 1)       # (NC,B,H)

    def step(h, inp):
        dec, st = inp
        h_out = h                                              # state BEFORE
        h = dec[..., None, None] * h + st
        return h, h_out

    h_final, h_prev = jax.lax.scan(
        step, h0, (chunk_decay, states.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                             # (B,NC,H,P,N)

    # inter-chunk contribution: y += exp(cum_q) * C_q . h_prev
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp", jnp.exp(cum), cc, h_prev)
    y = (y_intra + y_inter).reshape(bsz, length, n_heads, hd)
    return y[:, :orig_len], h_final


def _finish(p: PyTree, x_shape, z: Array, xs: Array, y_flat: Array,
            cfg: SSMConfig) -> Array:
    """Skip connection, gating, norm, out projection."""
    y = y_flat + xs * jnp.repeat(p["d_skip"], cfg.head_dim
                                 )[None, None, :].astype(xs.dtype)
    y = layers.rmsnorm(p["norm"],
                       (y.astype(jnp.float32)
                        * jax.nn.silu(z.astype(jnp.float32))
                        ).astype(z.dtype))
    return linear(p["out_proj"], y)


def ssd_block(p: PyTree, x: Array, cfg: SSMConfig, *,
              impl: str = "ref", return_state: bool = False):
    """Full-sequence Mamba-2 mixer. x (B, L, D)."""
    z, xs, xh, b, c, dt, a, new_conv, d_inner, _ = _prep(p, x, cfg, None)
    y, h_final = ssd_chunked(xh, b, c, dt, a, cfg.chunk, impl=impl)
    y_flat = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    out = _finish(p, x.shape, z, xs, y_flat, cfg)
    if return_state:
        return out, {"conv": new_conv, "h": h_final}
    return out


def init_ssd_cache(batch: int, d_model: int, cfg: SSMConfig) -> PyTree:
    d_inner, n_heads = dims(d_model, cfg)
    return {
        "conv": {
            "x": jnp.zeros((batch, cfg.d_conv - 1, d_inner),
                           dtype=jnp.bfloat16),
            "bc": jnp.zeros((batch, cfg.d_conv - 1, 2 * cfg.d_state),
                            dtype=jnp.bfloat16),
        },
        "h": jnp.zeros((batch, n_heads, cfg.head_dim, cfg.d_state),
                       dtype=jnp.float32),
    }


def ssd_decode(p: PyTree, x: Array, cache: PyTree, cfg: SSMConfig
               ) -> tuple[Array, PyTree]:
    """One-token step. x (B, 1, D)."""
    z, xs, xh, b, c, dt, a, new_conv, d_inner, _ = _prep(
        p, x, cfg, cache["conv"])
    decay = jnp.exp(dt[:, 0] * a[None, :])                     # (B,H)
    upd = (dt[:, 0][..., None, None]
           * xh[:, 0][..., None].astype(jnp.float32)
           * b[:, 0][:, None, None, :].astype(jnp.float32))
    h = decay[..., None, None] * cache["h"] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, c[:, 0].astype(jnp.float32))
    y_flat = y.reshape(x.shape[0], 1, d_inner).astype(x.dtype)
    out = _finish(p, x.shape, z, xs, y_flat, cfg)
    return out, {"conv": new_conv, "h": h}
