"""Model zoo: blocks (attention/MLA/MoE/RG-LRU/SSD) + the LM assembler
(`lm` for training, `decode` for serving)."""
