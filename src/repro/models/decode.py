"""Serving paths: cache init, prefill (cache building), single-token decode.

Cache layouts (per block kind):
  global / global_moe : dense KV cache (B, max_len, KV, hd) + slot positions
  local               : ring-buffer KV cache (B, window, KV, hd) + slots
  mla / mla_moe       : compressed latent cache (B, max_len, kv_lora + rope)
  rec                 : {conv (B, cw-1, W), h (B, W)}
  ssd                 : {conv {x, bc}, h (B, H, P, N)}

Decode shapes are what the dry-run lowers for ``decode_32k``/``long_500k``:
``decode_step`` with a cache of ShapeDtypeStructs at max_len = seq_len.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import ShardCtx, constrain
from repro.models import attention as attn
from repro.models import layers, lm, mla, rglru, ssd
from repro.models.layers import linear, mlp, rmsnorm

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------


def init_block_cache(cfg: ModelConfig, kind: str, batch: int,
                     max_len: int) -> PyTree:
    mixer, _ = lm._mixer_mlp(kind)
    if mixer in ("global", "local"):
        return attn.init_cache(batch, mixer, max_len, cfg.window,
                               cfg.n_kv_heads, cfg.head_dim)
    if mixer == "mla":
        return mla.init_mla_cache(batch, max_len, cfg.mla)
    if mixer == "rec":
        return rglru.init_rglru_cache(batch, cfg.d_model, cfg.rglru)
    if mixer == "ssd":
        return ssd.init_ssd_cache(batch, cfg.d_model, cfg.ssm)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    cache: dict[str, Any] = {
        "prefix": [init_block_cache(cfg, k, batch, max_len)
                   for k in cfg.prefix],
        "suffix": [init_block_cache(cfg, k, batch, max_len)
                   for k in cfg.suffix],
        "blocks": {},
    }
    for i, kind in enumerate(cfg.pattern):
        one = init_block_cache(cfg, kind, batch, max_len)
        cache["blocks"][f"pos{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.repeats, *a.shape)
                                       ).copy(), one)
    return cache


# ---------------------------------------------------------------------------
# Per-block decode
# ---------------------------------------------------------------------------


def _theta(cfg: ModelConfig, mixer: str) -> float:
    if mixer == "local" and cfg.rope_local_theta:
        return cfg.rope_local_theta
    return cfg.rope_theta


def block_decode(p: PyTree, cache: PyTree, x: Array, cfg: ModelConfig,
                 kind: str, pos: Array, ctx: ShardCtx | None,
                 impl: str) -> tuple[Array, PyTree]:
    mixer, mlp_kind = lm._mixer_mlp(kind)
    h = rmsnorm(p["pre_norm"], x, cfg.norm_eps)
    if mixer in ("global", "local"):
        h, new_cache = attn.decode_attention(
            p["mixer"], h, cache, pos, kind=mixer, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim, window=cfg.window,
            rope_theta=_theta(cfg, mixer), attn_softcap=cfg.attn_softcap,
            qk_norm=cfg.qk_norm, eps=cfg.norm_eps, impl=impl)
    elif mixer == "mla":
        h, new_cache = mla.mla_decode(p["mixer"], h, cache, pos,
                                      n_heads=cfg.n_heads, cfg=cfg.mla,
                                      rope_theta=cfg.rope_theta,
                                      eps=cfg.norm_eps)
    elif mixer == "rec":
        h, new_cache = rglru.rglru_decode(p["mixer"], h, cache, cfg.rglru)
    elif mixer == "ssd":
        h, new_cache = ssd.ssd_decode(p["mixer"], h, cache, cfg.ssm)
    else:  # pragma: no cover
        raise ValueError(kind)
    if cfg.post_norm:
        h = rmsnorm(p["post_mixer_norm"], h, cfg.norm_eps)
    x = x + h
    if mlp_kind != "none":
        h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        if mlp_kind == "moe":
            h, _ = lm._run_moe(p["mlp"], h, cfg, ctx,
                               capacity=x.shape[0])  # no decode drops
        else:
            h = mlp(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norm:
            h = rmsnorm(p["post_mlp_norm"], h, cfg.norm_eps)
        x = x + h
    return x, new_cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def _embed_step(params: PyTree, cfg: ModelConfig, tokens: Array) -> Array:
    compute = jnp.bfloat16
    if cfg.family == "audio":
        tables = params["embed"]["table"]        # (K, V, D)
        x = jnp.zeros((tokens.shape[0], 1, cfg.d_model), dtype=compute)
        for k in range(cfg.n_codebooks):
            x = x + tables[k][tokens[..., k]].astype(compute)
    else:
        x = params["embed"]["table"][tokens].astype(compute)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dtype=compute)
    return x


def decode_step(params: PyTree, cfg: ModelConfig, cache: PyTree,
                tokens: Array, pos: Array, *, ctx: ShardCtx | None = None,
                impl: str = "ref") -> tuple[Array, PyTree]:
    """One token for every sequence in the batch.

    tokens: (B, 1) int32 (audio: (B, 1, K)); pos: scalar int32.
    Returns (logits (B, V) — audio (B, K, V) — , new cache).
    """
    x = _embed_step(params, cfg, tokens)
    new_cache: dict[str, Any] = {"prefix": [], "suffix": [], "blocks": {}}
    for p_blk, kind, c_blk in zip(params["prefix"], cfg.prefix,
                                  cache["prefix"]):
        x, nc = block_decode(p_blk, c_blk, x, cfg, kind, pos, ctx, impl)
        new_cache["prefix"].append(nc)

    pattern = cfg.pattern

    def body(carry, blk_and_cache):
        h = carry
        blk, c = blk_and_cache
        ncs = {}
        for i, kind in enumerate(pattern):
            h, nc = block_decode(blk[f"pos{i}"], c[f"pos{i}"], h, cfg, kind,
                                 pos, ctx, impl)
            ncs[f"pos{i}"] = nc
        return h, ncs

    if cfg.scan_layers and cfg.repeats > 1:
        x, new_blocks = jax.lax.scan(body, x,
                                     (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = new_blocks
    else:
        new_blocks = {}
        for r in range(cfg.repeats):
            blk = jax.tree.map(lambda a, r=r: a[r], params["blocks"])
            c = jax.tree.map(lambda a, r=r: a[r], cache["blocks"])
            x, ncs = body(x, (blk, c))
            for k, v in ncs.items():
                new_blocks.setdefault(k, []).append(v)
        new_cache["blocks"] = {
            k: jax.tree.map(lambda *xs: jnp.stack(xs), *v)
            for k, v in new_blocks.items()}

    for p_blk, kind, c_blk in zip(params["suffix"], cfg.suffix,
                                  cache["suffix"]):
        x, nc = block_decode(p_blk, c_blk, x, cfg, kind, pos, ctx, impl)
        new_cache["suffix"].append(nc)

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)  # (B, 1, D)
    table = lm._head_table(params, cfg)
    if cfg.family == "audio":
        logits = jnp.stack(
            [layers.logits_from_hidden(table[k], h[:, 0], cfg.final_softcap)
             for k in range(cfg.n_codebooks)], axis=1)  # (B, K, V)
    else:
        logits = layers.logits_from_hidden(table, h[:, 0], cfg.final_softcap)
    logits = logits[..., :cfg.vocab_size]  # drop sharding-pad columns
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: full-sequence pass that also builds the cache
# ---------------------------------------------------------------------------


def _attn_cache_from_kv(k: Array, v: Array, mixer: str, window: int,
                        max_len: int) -> PyTree:
    b, s = k.shape[:2]
    if mixer == "local":
        w = window
        n = min(s, w)
        slots = (jnp.arange(s - n, s)) % w
        ck = jnp.zeros((b, w, *k.shape[2:]), dtype=k.dtype)
        cv = jnp.zeros((b, w, *v.shape[2:]), dtype=v.dtype)
        ck = ck.at[:, slots].set(k[:, s - n:])
        cv = cv.at[:, slots].set(v[:, s - n:])
        pos = jnp.full((w,), -1, jnp.int32).at[slots].set(
            jnp.arange(s - n, s, dtype=jnp.int32))
        return {"k": ck, "v": cv, "pos": pos}
    ck = jnp.zeros((b, max_len, *k.shape[2:]), dtype=k.dtype)
    cv = jnp.zeros((b, max_len, *v.shape[2:]), dtype=v.dtype)
    ck = ck.at[:, :s].set(k)
    cv = cv.at[:, :s].set(v)
    pos = jnp.full((max_len,), -1, jnp.int32).at[:s].set(
        jnp.arange(s, dtype=jnp.int32))
    return {"k": ck, "v": cv, "pos": pos}


def block_prefill(p: PyTree, x: Array, cfg: ModelConfig, kind: str,
                  positions: Array, max_len: int, ctx: ShardCtx | None,
                  impl: str) -> tuple[Array, PyTree]:
    mixer, mlp_kind = lm._mixer_mlp(kind)
    h = rmsnorm(p["pre_norm"], x, cfg.norm_eps)
    if mixer in ("global", "local"):
        h, (k, v) = attn.attention(
            p["mixer"], h, positions, kind=mixer, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim, window=cfg.window,
            rope_theta=_theta(cfg, mixer), attn_softcap=cfg.attn_softcap,
            qk_norm=cfg.qk_norm, eps=cfg.norm_eps, impl=impl, return_kv=True)
        new_cache = _attn_cache_from_kv(k, v, mixer, cfg.window, max_len)
    elif mixer == "mla":
        h, (c_kv, k_rope) = mla.mla_attention(
            p["mixer"], h, positions, n_heads=cfg.n_heads, cfg=cfg.mla,
            rope_theta=cfg.rope_theta, eps=cfg.norm_eps, impl=impl,
            return_kv=True)
        b, s = c_kv.shape[:2]
        cc = jnp.zeros((b, max_len, c_kv.shape[-1]), jnp.bfloat16
                       ).at[:, :s].set(c_kv.astype(jnp.bfloat16))
        cr = jnp.zeros((b, max_len, k_rope.shape[-1]), jnp.bfloat16
                       ).at[:, :s].set(k_rope.astype(jnp.bfloat16))
        new_cache = {"c_kv": cc, "k_rope": cr}
    elif mixer == "rec":
        h, new_cache = rglru.rglru_block(p["mixer"], h, cfg.rglru, impl=impl,
                                         return_state=True)
    elif mixer == "ssd":
        h, new_cache = ssd.ssd_block(p["mixer"], h, cfg.ssm, impl=impl,
                                     return_state=True)
    else:  # pragma: no cover
        raise ValueError(kind)
    if cfg.post_norm:
        h = rmsnorm(p["post_mixer_norm"], h, cfg.norm_eps)
    x = x + h
    if mlp_kind != "none":
        h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        if mlp_kind == "moe":
            h, _ = lm._run_moe(p["mlp"], h, cfg, ctx)
        else:
            h = mlp(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norm:
            h = rmsnorm(p["post_mlp_norm"], h, cfg.norm_eps)
        x = x + h
    return x, new_cache


def prefill(params: PyTree, cfg: ModelConfig, batch: dict[str, Array],
            max_len: int, *, ctx: ShardCtx | None = None,
            impl: str = "ref") -> tuple[Array, PyTree]:
    """Run the prompt, build the cache. Returns (last-position logits, cache).

    For prefill, batch["tokens"] is the raw prompt (B, S) — NOT shifted.
    """
    compute = jnp.bfloat16
    if cfg.family == "audio":
        toks = batch["tokens"]
        tables = params["embed"]["table"]
        x = jnp.zeros((*toks.shape[:2], cfg.d_model), dtype=compute)
        for k in range(cfg.n_codebooks):
            x = x + tables[k][toks[..., k]].astype(compute)
    elif cfg.patch_stub is not None:
        x_text = params["embed"]["table"][batch["tokens"]].astype(compute)
        x_patch = linear(params["patch_proj"],
                         batch["patches"].astype(compute))
        x = jnp.concatenate([x_patch, x_text], axis=1)
    else:
        x = params["embed"]["table"][batch["tokens"]].astype(compute)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dtype=compute)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(x, ctx, ctx.batch if ctx else None,
                  ctx.seq if ctx else None, None)

    cache: dict[str, Any] = {"prefix": [], "suffix": [], "blocks": {}}
    for p_blk, kind in zip(params["prefix"], cfg.prefix):
        x, nc = block_prefill(p_blk, x, cfg, kind, positions, max_len, ctx,
                              impl)
        cache["prefix"].append(nc)

    pattern = cfg.pattern

    def body(carry, blk):
        h = carry
        ncs = {}
        for i, kind in enumerate(pattern):
            h, nc = block_prefill(blk[f"pos{i}"], h, cfg, kind, positions,
                                  max_len, ctx, impl)
            ncs[f"pos{i}"] = nc
        return h, ncs

    if cfg.scan_layers and cfg.repeats > 1:
        x, new_blocks = jax.lax.scan(body, x, params["blocks"])
        cache["blocks"] = new_blocks
    else:
        acc: dict[str, list] = {}
        for r in range(cfg.repeats):
            blk = jax.tree.map(lambda a, r=r: a[r], params["blocks"])
            x, ncs = body(x, blk)
            for kk, vv in ncs.items():
                acc.setdefault(kk, []).append(vv)
        cache["blocks"] = {kk: jax.tree.map(lambda *xs: jnp.stack(xs), *vv)
                           for kk, vv in acc.items()}

    for p_blk, kind in zip(params["suffix"], cfg.suffix):
        x, nc = block_prefill(p_blk, x, cfg, kind, positions, max_len, ctx,
                              impl)
        cache["suffix"].append(nc)

    h = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    table = lm._head_table(params, cfg)
    if cfg.family == "audio":
        logits = jnp.stack(
            [layers.logits_from_hidden(table[k], h[:, 0], cfg.final_softcap)
             for k in range(cfg.n_codebooks)], axis=1)
    else:
        logits = layers.logits_from_hidden(table, h[:, 0], cfg.final_softcap)
    logits = logits[..., :cfg.vocab_size]  # drop sharding-pad columns
    return logits, cache
