"""DeepSeek-V3 Multi-head Latent Attention (MLA) + the MTP head.

Prefill/training: the factorized projections are expanded to per-head K/V
(mathematically the reference MHA). Decode: the ABSORBED form — the cache
stores only the compressed latent (c_kv, k_rope) per position, and the
up-projections are folded into the query/output sides so per-step work is
O(S * kv_lora_rank) instead of O(S * H * head_dim). This is the memory win
that makes deepseek decode_32k fit: cache is (B, S, kv_lora + rope) instead
of (B, S, H, 2*hd) — a 128 * 256 / 576 ~= 57x reduction.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models import layers
from repro.models.layers import apply_rope, init_linear, linear, rmsnorm

Array = jax.Array
PyTree = Any

NEG_INF = -2.0e38


def init_mla(key: Array, d_model: int, n_heads: int, cfg: MLAConfig,
             dtype=layers.DEFAULT_PARAM_DTYPE) -> PyTree:
    ks = jax.random.split(key, 6)
    qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wdq": init_linear(ks[0], d_model, cfg.q_lora_rank, dtype=dtype),
        "q_norm": layers.init_rmsnorm(cfg.q_lora_rank),
        "wuq": init_linear(ks[1], cfg.q_lora_rank, (n_heads, qk_head),
                           dtype=dtype),
        # joint down-projection: [c_kv | k_rope]
        "wdkv": init_linear(ks[2], d_model, cfg.kv_lora_rank + cfg.qk_rope_dim,
                            dtype=dtype),
        "kv_norm": layers.init_rmsnorm(cfg.kv_lora_rank),
        "wuk": init_linear(ks[3], cfg.kv_lora_rank, (n_heads, cfg.qk_nope_dim),
                           dtype=dtype),
        "wuv": init_linear(ks[4], cfg.kv_lora_rank, (n_heads, cfg.v_head_dim),
                           dtype=dtype),
        "wo": {"w": layers.truncated_normal(
            ks[5], (n_heads, cfg.v_head_dim, d_model),
            scale=(n_heads * cfg.v_head_dim) ** -0.5, dtype=dtype)},
    }


def _queries(p: PyTree, x: Array, positions: Array, cfg: MLAConfig,
             rope_theta: float, eps: float):
    cq = rmsnorm(p["q_norm"], linear(p["wdq"], x), eps)
    q = linear(p["wuq"], cq)  # (B, S, H, nope+rope)
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, rope_theta)
    return q_nope, q_rope


def _latents(p: PyTree, x: Array, positions: Array, cfg: MLAConfig,
             rope_theta: float, eps: float):
    dkv = linear(p["wdkv"], x)  # (B, S, kv_lora + rope)
    c_kv = rmsnorm(p["kv_norm"], dkv[..., :cfg.kv_lora_rank], eps)
    k_rope = dkv[..., cfg.kv_lora_rank:][..., None, :]  # (B, S, 1, rope)
    k_rope = apply_rope(k_rope, positions, rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_attention(p: PyTree, x: Array, positions: Array, *, n_heads: int,
                  cfg: MLAConfig, rope_theta: float, eps: float = 1e-6,
                  impl: str = "ref", return_kv: bool = False, ctx=None):
    """Full-sequence causal MLA (training / prefill) — expanded form."""
    b, s, _ = x.shape
    q_nope, q_rope = _queries(p, x, positions, cfg, rope_theta, eps)
    c_kv, k_rope = _latents(p, x, positions, cfg, rope_theta, eps)
    if ctx is not None and ctx.seq:
        from repro.distributed.ctx import constrain
        c_kv = constrain(c_kv, ctx, ctx.batch, None, None)
        k_rope = constrain(k_rope, ctx, ctx.batch, None, None)
    k_nope = linear(p["wuk"], c_kv)   # (B, S, H, nope)
    v = linear(p["wuv"], c_kv)        # (B, S, H, v_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (b, s, n_heads, cfg.qk_rope_dim))],
                        axis=-1)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, window=None, softcap=None)
    else:
        scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                            preferred_element_type=jnp.float32) * scale
        i = jnp.arange(s)[:, None]
        j = jnp.arange(s)[None, :]
        scores = jnp.where((j <= i)[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    y = jnp.einsum("bqhd,hdm->bqm", out, p["wo"]["w"].astype(out.dtype))
    if return_kv:
        return y, (c_kv, k_rope)
    return y


# ---------------------------------------------------------------------------
# Absorbed decode
# ---------------------------------------------------------------------------


def init_mla_cache(batch: int, max_len: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> PyTree:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype=dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype=dtype),
    }


def mla_decode(p: PyTree, x: Array, cache: PyTree, pos: Array, *,
               n_heads: int, cfg: MLAConfig, rope_theta: float,
               eps: float = 1e-6) -> tuple[Array, PyTree]:
    """One decode step in absorbed form. x (B, 1, D)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _queries(p, x, positions, cfg, rope_theta, eps)
    c_new, kr_new = _latents(p, x, positions, cfg, rope_theta, eps)

    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    new_cache = {"c_kv": c_kv, "k_rope": k_rope}

    # absorb W_uk into the query: q_c (B, 1, H, kv_lora). wuk w is (c, h, d).
    q_c = jnp.einsum("bqhd,chd->bqhc", q_nope,
                     p["wuk"]["w"].astype(q_nope.dtype))
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    scores = (jnp.einsum("bqhc,bsc->bhqs", q_c, c_kv.astype(q_c.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhr,bsr->bhqs", q_rope,
                           k_rope.astype(q_rope.dtype),
                           preferred_element_type=jnp.float32)) * scale
    s_idx = jnp.arange(scores.shape[-1])
    scores = jnp.where((s_idx <= pos)[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    # attend in latent space then apply W_uv and W_o
    out_c = jnp.einsum("bhqs,bsc->bqhc", probs, c_kv.astype(probs.dtype))
    out = jnp.einsum("bqhc,chd->bqhd", out_c,  # wuv w is (c, h, v_dim)
                     p["wuv"]["w"].astype(out_c.dtype))
    y = jnp.einsum("bqhd,hdm->bqm", out, p["wo"]["w"].astype(out.dtype))
    return y, new_cache
