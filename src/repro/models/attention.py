"""GQA attention: global and sliding-window variants, softcap, qk-norm.

Two implementations:
  * ``impl="ref"``   — pure jnp (used by CPU tests and the dry-run; the
    dry-run targets the XLA TPU attention fusion path).
  * ``impl="pallas"`` — the Pallas flash kernel in ``repro.kernels`` (TPU
    target; validated on CPU in interpret mode by the kernel tests).

Decode uses either a dense cache (global layers: (B, S_max, KV, hd), masked
by current position) or a ring-buffer cache (local layers: (B, window, KV,
hd) + slot-position vector).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import apply_rope, init_linear, linear, rmsnorm, softcap

Array = jax.Array
PyTree = Any

NEG_INF = -2.0e38


def init_attention(key: Array, d_model: int, n_heads: int, n_kv: int,
                   head_dim: int, use_bias: bool = False, qk_norm: bool = False,
                   dtype=layers.DEFAULT_PARAM_DTYPE) -> PyTree:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": init_linear(k1, d_model, (n_heads, head_dim), use_bias, dtype),
        "wk": init_linear(k2, d_model, (n_kv, head_dim), use_bias, dtype),
        "wv": init_linear(k3, d_model, (n_kv, head_dim), use_bias, dtype),
        "wo": {"w": layers.truncated_normal(
            k4, (n_heads, head_dim, d_model),
            scale=(n_heads * head_dim) ** -0.5, dtype=dtype)},
    }
    if qk_norm:
        p["q_norm"] = layers.init_rmsnorm(head_dim)
        p["k_norm"] = layers.init_rmsnorm(head_dim)
    return p


def _project_qkv(p: PyTree, x: Array, positions: Array, rope_theta: float,
                 qk_norm: bool, eps: float):
    q = linear(p["wq"], x)            # (B, S, H, hd)
    k = linear(p["wk"], x)            # (B, S, KV, hd)
    v = linear(p["wv"], x)
    if qk_norm:
        q = rmsnorm(p["q_norm"], q, eps)
        k = rmsnorm(p["k_norm"], k, eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, mask: Array, cap: float | None) -> Array:
    """Grouped scaled-dot-product attention, fp32 softmax.

    q (B, Sq, H, hd), k/v (B, Sk, KV, hd), mask (B|1, Sq, Sk) bool.
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = softcap(scores, cap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, hd)


def causal_mask(sq: int, window: int | None = None) -> Array:
    """(1, sq, sq) causal (optionally banded) mask."""
    i = jnp.arange(sq)[:, None]
    j = jnp.arange(sq)[None, :]
    m = j <= i
    if window is not None:
        m &= j > i - window
    return m[None]


def attention(p: PyTree, x: Array, positions: Array, *, kind: str,
              n_heads: int, n_kv: int, head_dim: int, window: int,
              rope_theta: float, attn_softcap: float | None = None,
              qk_norm: bool = False, eps: float = 1e-6,
              impl: str = "ref", return_kv: bool = False, ctx=None):
    """Causal self-attention over a full sequence (training / prefill)."""
    q, k, v = _project_qkv(p, x, positions, rope_theta, qk_norm, eps)
    if ctx is not None and ctx.seq:
        # sequence-parallel prefill/train: Q stays seq-sharded, K/V are
        # all-gathered over the seq axes (expressed as a constraint; XLA
        # emits the all-gather).
        from repro.distributed.ctx import constrain
        k = constrain(k, ctx, ctx.batch, None, None, None)
        v = constrain(v, ctx, ctx.batch, None, None, None)
    w = window if kind == "local" else None
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, window=w, softcap=attn_softcap)
    else:
        mask = causal_mask(x.shape[1], w)
        out = _sdpa(q, k, v, mask, attn_softcap)
    y = jnp.einsum("bqhd,hdm->bqm", out, p["wo"]["w"].astype(out.dtype))
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# Decode (single new token against a cache)
# ---------------------------------------------------------------------------


def init_cache(batch: int, kind: str, max_len: int, window: int, n_kv: int,
               head_dim: int, dtype=jnp.bfloat16) -> PyTree:
    """Dense cache for global layers, ring buffer for local layers."""
    length = window if kind == "local" else max_len
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype=dtype),
        # position stored in each slot; -1 = empty. Ring for local layers.
        "pos": jnp.full((length,), -1, dtype=jnp.int32),
    }


def decode_attention(p: PyTree, x: Array, cache: PyTree, pos: Array, *,
                     kind: str, n_heads: int, n_kv: int, head_dim: int,
                     window: int, rope_theta: float,
                     attn_softcap: float | None = None, qk_norm: bool = False,
                     eps: float = 1e-6, impl: str = "ref"
                     ) -> tuple[Array, PyTree]:
    """One decode step. x (B, 1, D); pos scalar int32 (current position)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, positions, rope_theta, qk_norm, eps)

    length = cache["k"].shape[1]
    # dense caches have length >= pos so the modulo is the identity there;
    # ring buffers (local layers) wrap.
    slot = pos % length
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    slot_pos = cache["pos"].at[slot].set(pos)
    new_cache = {"k": k, "v": v, "pos": slot_pos}

    valid = slot_pos >= 0
    if kind == "local":
        valid &= slot_pos > pos - window
    mask = valid[None, None, :]  # (1, 1, length)

    if impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q, k, v, slot_pos, pos,
                                      window=window if kind == "local" else None,
                                      softcap=attn_softcap)
    else:
        out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype), mask, attn_softcap)
    y = jnp.einsum("bqhd,hdm->bqm", out, p["wo"]["w"].astype(out.dtype))
    return y, new_cache
