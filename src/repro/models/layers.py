"""Shared building blocks: norms, RoPE, MLPs, embeddings, softcap.

Functional style: ``init_*`` returns a param pytree, ``apply`` fns are pure.
Params are stored in ``param_dtype`` (bf16 by default) and compute happens
in ``compute_dtype`` with fp32 accumulation where it matters (norms, softmax,
logits).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

DEFAULT_PARAM_DTYPE = jnp.bfloat16


def truncated_normal(key: Array, shape, scale: float,
                     dtype=DEFAULT_PARAM_DTYPE) -> Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def init_linear(key: Array, d_in: int, d_out: int | tuple[int, ...],
                use_bias: bool = False, dtype=DEFAULT_PARAM_DTYPE) -> PyTree:
    out = d_out if isinstance(d_out, tuple) else (d_out,)
    w = truncated_normal(key, (d_in, *out), scale=d_in**-0.5, dtype=dtype)
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros(out, dtype=dtype)
    return p


def linear(p: PyTree, x: Array) -> Array:
    """x (..., d_in) @ w (d_in, *out) -> (..., *out)."""
    w = p["w"]
    out_rank = w.ndim - 1
    y = jax.lax.dot_general(
        x, w.astype(x.dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    del out_rank
    return y


def init_rmsnorm(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.zeros((d,), dtype=dtype)}  # gemma-style (1+scale)


def rmsnorm(p: PyTree, x: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(dtype)


def softcap(x: Array, cap: float | None) -> Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def activation(name: str, x: Array) -> Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, H, hd) rotated by per-position angles; positions (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key: Array, d_model: int, d_ff: int, gated: bool,
             use_bias: bool = False, dtype=DEFAULT_PARAM_DTYPE) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"out": init_linear(k2, d_ff, d_model, use_bias, dtype)}
    if gated:
        p["gate"] = init_linear(k1, d_model, d_ff, use_bias, dtype)
        p["up"] = init_linear(k3, d_model, d_ff, use_bias, dtype)
    else:
        p["up"] = init_linear(k1, d_model, d_ff, use_bias, dtype)
    return p


def mlp(p: PyTree, x: Array, act: str) -> Array:
    if "gate" in p:
        h = activation(act, linear(p["gate"], x)) * linear(p["up"], x)
    else:
        h = activation(act, linear(p["up"], x))
    return linear(p["out"], h)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def init_embedding(key: Array, vocab: int, d: int,
                   dtype=DEFAULT_PARAM_DTYPE) -> PyTree:
    return {"table": truncated_normal(key, (vocab, d), scale=1.0, dtype=dtype)}


def embed(p: PyTree, tokens: Array, scale: bool, d_model: int,
          compute_dtype=jnp.bfloat16) -> Array:
    x = p["table"][tokens].astype(compute_dtype)
    if scale:
        x = x * jnp.asarray(d_model**0.5, dtype=compute_dtype)
    return x


def logits_from_hidden(table: Array, h: Array,
                       final_cap: float | None = None) -> Array:
    """h (..., D) @ table.T (V, D) -> (..., V), fp32 out."""
    out = jnp.einsum("...d,vd->...v", h, table.astype(h.dtype),
                     preferred_element_type=jnp.float32)
    return softcap(out, final_cap)


def chunked_cross_entropy(table: Array, h: Array, targets: Array,
                          mask: Array | None = None, chunk: int = 512,
                          final_cap: float | None = None,
                          n_valid: int | None = None) -> Array:
    """Next-token CE without materializing full (B, S, V) logits.

    Scans over sequence chunks: each step computes (B, chunk, V) logits,
    logsumexp, and the target log-prob. Memory-bounds the loss layer — with
    256k vocabularies the full logit tensor would dominate activation memory.

    ``n_valid``: real vocabulary size when the table is padded for sharding
    (padded columns are masked to -inf before the logsumexp).
    """
    b, s, d = h.shape
    v = table.shape[0]
    n_chunks = s // chunk
    assert s % chunk == 0, f"seq {s} not divisible by loss chunk {chunk}"
    h_c = h.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    t_c = targets.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    if mask is None:
        m_c = jnp.ones((n_chunks, b, chunk), dtype=jnp.float32)
    else:
        m_c = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1).astype(jnp.float32)
    pad_mask = None
    if n_valid is not None and n_valid < v:
        pad_mask = jnp.arange(v) >= n_valid  # (V,)

    def step(carry, inp):
        hc, tc, mc = inp
        logits = logits_from_hidden(table, hc, final_cap)  # (b, chunk, V) f32
        if pad_mask is not None:
            logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    (total, count), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)),
                                     (h_c, t_c, m_c))
    return total / jnp.maximum(count, 1.0)
