"""The LM assembler: init / train loss / prefill / decode for every
assigned architecture.

A model is a stack of residual blocks described by ``cfg.layer_kinds``:
    kind          mixer               mlp
    "global"      full GQA attention  dense
    "local"       windowed GQA        dense
    "global_moe"  full GQA            mixture-of-experts
    "mla"         DeepSeek MLA        dense
    "mla_moe"     DeepSeek MLA        mixture-of-experts
    "rec"         RG-LRU recurrence   dense
    "ssd"         Mamba-2 SSD         (none)

The repeating part of the stack (``cfg.pattern`` x ``cfg.repeats``) is
``lax.scan``-ed over stacked per-superblock params (compact HLO, sane
compile times at 48-61 layers) with per-superblock remat; ``cfg.prefix`` /
``cfg.suffix`` layers run unscanned.

Modality frontends are stubs per the assignment: musicgen consumes
(B, S, n_codebooks) EnCodec token grids (sum of codebook embeddings, one
output head per codebook); llava consumes precomputed patch embeddings
(the backbone owns only the projector).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import ShardCtx, constrain
from repro.models import attention as attn
from repro.models import layers, mla, moe, rglru, ssd
from repro.models.layers import (chunked_cross_entropy, init_linear,
                                 init_mlp, linear, mlp, rmsnorm)

Array = jax.Array
PyTree = Any

KIND_TABLE = {
    "global": ("global", "dense"),
    "local": ("local", "dense"),
    "global_moe": ("global", "moe"),
    "mla": ("mla", "dense"),
    "mla_moe": ("mla", "moe"),
    "rec": ("rec", "dense"),
    "ssd": ("ssd", "none"),
}


def _mixer_mlp(kind: str) -> tuple[str, str]:
    return KIND_TABLE[kind]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_block(key: Array, cfg: ModelConfig, kind: str) -> PyTree:
    mixer, mlp_kind = _mixer_mlp(kind)
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {"pre_norm": layers.init_rmsnorm(cfg.d_model)}
    if mixer in ("global", "local"):
        p["mixer"] = attn.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.use_bias, cfg.qk_norm)
    elif mixer == "mla":
        assert cfg.mla is not None
        p["mixer"] = mla.init_mla(k1, cfg.d_model, cfg.n_heads, cfg.mla)
    elif mixer == "rec":
        assert cfg.rglru is not None
        p["mixer"] = rglru.init_rglru_block(k1, cfg.d_model, cfg.rglru)
    elif mixer == "ssd":
        assert cfg.ssm is not None
        p["mixer"] = ssd.init_ssd_block(k1, cfg.d_model, cfg.ssm)
    if cfg.post_norm:
        p["post_mixer_norm"] = layers.init_rmsnorm(cfg.d_model)
    if mlp_kind == "dense":
        p["mlp_norm"] = layers.init_rmsnorm(cfg.d_model)
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                            cfg.use_bias)
        if cfg.post_norm:
            p["post_mlp_norm"] = layers.init_rmsnorm(cfg.d_model)
    elif mlp_kind == "moe":
        assert cfg.moe is not None
        p["mlp_norm"] = layers.init_rmsnorm(cfg.d_model)
        p["mlp"] = moe.init_moe(k2, cfg.d_model, cfg.moe, cfg.gated_mlp)
        if cfg.post_norm:
            p["post_mlp_norm"] = layers.init_rmsnorm(cfg.d_model)
    return p


def init(key: Array, cfg: ModelConfig) -> PyTree:
    keys = jax.random.split(key, cfg.n_layers + 8)
    params: dict[str, Any] = {}
    vocab = cfg.vocab_padded  # padded to 16-divisible for vocab parallelism
    if cfg.family == "audio":
        tables = [layers.init_embedding(keys[-1 - i], vocab,
                                        cfg.d_model)["table"]
                  for i in range(cfg.n_codebooks)]
        params["embed"] = {"table": jnp.stack(tables)}  # (K, V, D)
    else:
        params["embed"] = layers.init_embedding(keys[-1], vocab,
                                                cfg.d_model)
    if cfg.patch_stub is not None:
        params["patch_proj"] = init_linear(keys[-6], cfg.patch_stub.embed_dim,
                                           cfg.d_model)
    ki = iter(range(cfg.n_layers))
    params["prefix"] = [init_block(keys[next(ki)], cfg, k) for k in cfg.prefix]
    blocks: dict[str, Any] = {}
    per_pos: list[list[PyTree]] = [[] for _ in cfg.pattern]
    for _ in range(cfg.repeats):
        for i, kind in enumerate(cfg.pattern):
            per_pos[i].append(init_block(keys[next(ki)], cfg, kind))
    for i, plist in enumerate(per_pos):
        blocks[f"pos{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *plist)
    params["blocks"] = blocks
    params["suffix"] = [init_block(keys[next(ki)], cfg, k) for k in cfg.suffix]
    params["final_norm"] = layers.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        if cfg.family == "audio":
            heads = [init_linear(keys[-2 - i], cfg.d_model, vocab)
                     for i in range(cfg.n_codebooks)]
            params["lm_head"] = {"w": jnp.stack([h["w"] for h in heads])}
        else:
            params["lm_head"] = init_linear(keys[-2], cfg.d_model, vocab)
    if cfg.mtp:
        params["mtp"] = {
            "proj": init_linear(keys[-3], 2 * cfg.d_model, cfg.d_model),
            "h_norm": layers.init_rmsnorm(cfg.d_model),
            "e_norm": layers.init_rmsnorm(cfg.d_model),
            "block": init_block(keys[-4], cfg,
                                "mla" if cfg.mla else "global"),
        }
    return params


# ---------------------------------------------------------------------------
# Block forward (full sequence)
# ---------------------------------------------------------------------------


def _run_mixer(p: PyTree, x: Array, cfg: ModelConfig, kind: str,
               positions: Array, ctx: ShardCtx | None, impl: str) -> Array:
    mixer, _ = _mixer_mlp(kind)
    if mixer in ("global", "local"):
        theta = (cfg.rope_local_theta
                 if (mixer == "local" and cfg.rope_local_theta) else
                 cfg.rope_theta)
        return attn.attention(
            p, x, positions, kind=mixer, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim, window=cfg.window,
            rope_theta=theta, attn_softcap=cfg.attn_softcap,
            qk_norm=cfg.qk_norm, eps=cfg.norm_eps, impl=impl, ctx=ctx)
    if mixer == "mla":
        return mla.mla_attention(p, x, positions, n_heads=cfg.n_heads,
                                 cfg=cfg.mla, rope_theta=cfg.rope_theta,
                                 eps=cfg.norm_eps, impl=impl, ctx=ctx)
    if mixer == "rec":
        return rglru.rglru_block(p, x, cfg.rglru, impl=impl)
    if mixer == "ssd":
        return ssd.ssd_block(p, x, cfg.ssm, impl=impl)
    raise ValueError(kind)


def block_forward(p: PyTree, x: Array, cfg: ModelConfig, kind: str,
                  positions: Array, ctx: ShardCtx | None,
                  impl: str) -> tuple[Array, Array]:
    """One residual block. Returns (x, aux_loss)."""
    _, mlp_kind = _mixer_mlp(kind)
    aux = jnp.float32(0.0)
    h = rmsnorm(p["pre_norm"], x, cfg.norm_eps)
    h = _run_mixer(p["mixer"], h, cfg, kind, positions, ctx, impl)
    if cfg.post_norm:
        h = rmsnorm(p["post_mixer_norm"], h, cfg.norm_eps)
    x = x + h
    x = constrain(x, ctx, ctx.batch if ctx else None,
                  ctx.seq if ctx else None, None)
    if mlp_kind != "none":
        h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
        if mlp_kind == "moe":
            h, aux = _run_moe(p["mlp"], h, cfg, ctx)
        else:
            h = mlp(p["mlp"], h, cfg.mlp_act)
        if cfg.post_norm:
            h = rmsnorm(p["post_mlp_norm"], h, cfg.norm_eps)
        x = x + h
        x = constrain(x, ctx, ctx.batch if ctx else None,
                      ctx.seq if ctx else None, None)
    return x, aux


def _run_moe(p: PyTree, x: Array, cfg: ModelConfig,
             ctx: ShardCtx | None,
             capacity: int | None = None) -> tuple[Array, Array]:
    """MoE layer: plain path on one device; shard_map EP under a mesh."""
    mcfg = cfg.moe
    if ctx is None:
        return moe.moe_mlp(p, x, mcfg, cfg.mlp_act, capacity=capacity)

    from jax.sharding import PartitionSpec as P
    mesh = ctx.mesh
    ep_axes = tuple(ctx.ep_axes)
    e = mcfg.padded_experts
    ep_size = 1
    for a in ep_axes:
        ep_size *= mesh.shape[a]
    e_local = e // ep_size
    batch_axes = ctx.batch if isinstance(ctx.batch, tuple) else (
        (ctx.batch,) if ctx.batch else ())
    seq_axes = ctx.seq if isinstance(ctx.seq, tuple) else (
        (ctx.seq,) if ctx.seq else ())
    # EP axes along which tokens are sharded must be gathered (and the
    # summed outputs scattered back); EP axes with replicated tokens just
    # psum the partial expert outputs.
    gather_axes = [(a, 0 if a in batch_axes else 1) for a in ep_axes
                   if a in batch_axes or a in seq_axes]
    psum_axes = [a for a in ep_axes
                 if a not in batch_axes and a not in seq_axes]

    # the shared (always-on) expert is a plain TP MLP computed OUTSIDE the
    # shard_map — inside it the EP psum would multiply it |ep| x.
    p_routed = {k: v for k, v in p.items() if k != "shared"}

    ep_spec = ep_axes[0] if len(ep_axes) == 1 else ep_axes

    def pspec(path, leaf):
        name = path[0].key if hasattr(path[0], "key") else str(path[0])
        return P() if name == "router" else P(ep_spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(p_routed)
    in_p_specs = jax.tree_util.tree_unflatten(
        treedef, [pspec(path, leaf) for path, leaf in flat])
    x_spec = P(ctx.batch, ctx.seq, None)

    def run(p_local, x_local):
        # flattened EP rank (tuple specs split major-to-minor)
        e_start = jnp.int32(0)
        for a in ep_axes:
            e_start = e_start * mesh.shape[a] + jax.lax.axis_index(a)
        e_start = e_start * e_local
        xg = x_local
        for a, dim in gather_axes:
            xg = jax.lax.all_gather(xg, a, axis=dim, tiled=True)
        y, aux = moe.moe_mlp(p_local, xg, mcfg, cfg.mlp_act,
                             e_start=e_start, e_local=e_local,
                             capacity=capacity)
        for a in psum_axes:
            y = jax.lax.psum(y, a)
        for a, dim in reversed(gather_axes):
            y = jax.lax.psum_scatter(y, a, scatter_dimension=dim, tiled=True)
        aux = jax.lax.psum(aux, ep_axes) / ep_size
        return y, aux

    y, aux = jax.shard_map(
        run, mesh=mesh, in_specs=(in_p_specs, x_spec),
        out_specs=(x_spec, P()), check_vma=False)(p_routed, x)
    if "shared" in p:
        from repro.models.layers import mlp as dense_mlp
        y = y + dense_mlp(p["shared"], x, cfg.mlp_act)
    return y, jnp.mean(aux)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def forward_hidden(params: PyTree, cfg: ModelConfig, x: Array,
                   positions: Array, *, ctx: ShardCtx | None = None,
                   impl: str = "ref") -> tuple[Array, Array]:
    """Embedded inputs -> final hidden states. Returns (h, aux_loss)."""
    aux_total = jnp.float32(0.0)
    for p_blk, kind in zip(params["prefix"], cfg.prefix):
        x, aux = block_forward(p_blk, x, cfg, kind, positions, ctx, impl)
        aux_total += aux

    pattern = cfg.pattern

    def body(carry, blk):
        h = carry
        aux_sb = jnp.float32(0.0)
        for i, kind in enumerate(pattern):
            h, aux = block_forward(blk[f"pos{i}"], h, cfg, kind, positions,
                                   ctx, impl)
            aux_sb += aux
        return h, aux_sb

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else
                  jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)
    if cfg.scan_layers and cfg.repeats > 1:
        x, auxs = jax.lax.scan(body, x, params["blocks"])
        aux_total += jnp.sum(auxs)
    else:
        blocks_list = [jax.tree.map(lambda a, r=r: a[r], params["blocks"])
                       for r in range(cfg.repeats)]
        for blk in blocks_list:
            x, aux = body(x, blk)
            aux_total += aux

    for p_blk, kind in zip(params["suffix"], cfg.suffix):
        x, aux = block_forward(p_blk, x, cfg, kind, positions, ctx, impl)
        aux_total += aux
    return x, aux_total


def embed_inputs(params: PyTree, cfg: ModelConfig, batch: dict[str, Array],
                 ctx: ShardCtx | None):
    """-> (x (B,S,D), positions (B,S), targets, loss_mask)."""
    compute = jnp.bfloat16
    if cfg.family == "audio":
        toks = batch["tokens"]                       # (B, S+1, K)
        inp, tgt = toks[:, :-1], toks[:, 1:]
        tables = params["embed"]["table"]            # (K, V, D)
        x = jnp.zeros((*inp.shape[:2], cfg.d_model), dtype=compute)
        for k in range(cfg.n_codebooks):
            x = x + tables[k][inp[..., k]].astype(compute)
        mask = jnp.ones(tgt.shape[:2], dtype=jnp.float32)
    elif cfg.patch_stub is not None:
        toks = batch["tokens"]                       # (B, S_text+1)
        patches = batch["patches"]                   # (B, P, E)
        inp, tgt_text = toks[:, :-1], toks[:, 1:]
        x_text = params["embed"]["table"][inp].astype(compute)
        x_patch = linear(params["patch_proj"], patches.astype(compute))
        x = jnp.concatenate([x_patch, x_text], axis=1)
        n_p = patches.shape[1]
        tgt = jnp.concatenate(
            [jnp.zeros((toks.shape[0], n_p), dtype=tgt_text.dtype), tgt_text],
            axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((toks.shape[0], n_p), dtype=jnp.float32),
             jnp.ones(tgt_text.shape, dtype=jnp.float32)], axis=1)
    else:
        toks = batch["tokens"]                       # (B, S+1)
        inp, tgt = toks[:, :-1], toks[:, 1:]
        x = params["embed"]["table"][inp].astype(compute)
        mask = jnp.ones(tgt.shape, dtype=jnp.float32)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dtype=compute)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(x, ctx, ctx.batch if ctx else None,
                  ctx.seq if ctx else None, None)
    return x, positions, tgt, mask


def _head_table(params: PyTree, cfg: ModelConfig) -> Array:
    """(V, D) table (or (K, V, D) for audio) used for output logits."""
    if cfg.tie_embeddings:
        return params["embed"]["table"]
    w = params["lm_head"]["w"]
    # lm_head stores (D, V) / (K, D, V); CE wants (V, D) rows
    return jnp.swapaxes(w, -1, -2)


def loss_fn(params: PyTree, cfg: ModelConfig, batch: dict[str, Array], *,
            ctx: ShardCtx | None = None, impl: str = "ref"
            ) -> tuple[Array, dict[str, Array]]:
    x, positions, tgt, mask = embed_inputs(params, cfg, batch, ctx)
    h, aux = forward_hidden(params, cfg, x, positions, ctx=ctx, impl=impl)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    table = _head_table(params, cfg)
    if cfg.family == "audio":
        # small vocab: full logits per codebook
        losses = []
        for k in range(cfg.n_codebooks):
            logits = layers.logits_from_hidden(table[k], h, cfg.final_softcap)
            lse = jax.nn.logsumexp(logits, axis=-1)
            sel = jnp.take_along_axis(logits, tgt[..., k][..., None],
                                      axis=-1)[..., 0]
            losses.append(jnp.mean(lse - sel))
        main = jnp.mean(jnp.stack(losses))
    else:
        chunk = min(512, h.shape[1])
        main = chunked_cross_entropy(table, h, tgt, mask, chunk=chunk,
                                     final_cap=cfg.final_softcap,
                                     n_valid=cfg.vocab_size)
    total = main + aux
    metrics = {"loss": main, "aux_loss": aux}
    if cfg.mtp:
        mtp_loss = _mtp_loss(params, cfg, batch, h, positions, ctx, impl)
        total = total + 0.3 * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    return total, metrics


def _mtp_loss(params: PyTree, cfg: ModelConfig, batch: dict[str, Array],
              h: Array, positions: Array, ctx: ShardCtx | None,
              impl: str) -> Array:
    """DeepSeek-style depth-1 multi-token prediction: predict t+2 from the
    main trunk's hidden state at t combined with the embedding of t+1."""
    toks = batch["tokens"]                 # (B, S+1)
    p = params["mtp"]
    emb_next = params["embed"]["table"][toks[:, 1:-1]].astype(h.dtype)
    h_in = jnp.concatenate(
        [rmsnorm(p["h_norm"], h[:, :-1], cfg.norm_eps),
         rmsnorm(p["e_norm"], emb_next, cfg.norm_eps)], axis=-1)
    x = linear(p["proj"], h_in)            # (B, S-1, D)
    kind = "mla" if cfg.mla else "global"
    x, _ = block_forward(p["block"], x, cfg, kind, positions[:, :-1], ctx,
                         impl)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = _head_table(params, cfg)
    tgt = toks[:, 2:]
    chunk_len = x.shape[1]
    chunk = 512 if chunk_len % 512 == 0 else 1
    for c in (512, 256, 128, 63, 1):
        if chunk_len % c == 0:
            chunk = c
            break
    return chunked_cross_entropy(table, x, tgt, None, chunk=chunk,
                                 final_cap=cfg.final_softcap,
                                 n_valid=cfg.vocab_size)
