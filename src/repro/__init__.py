"""repro: "Low Latency via Redundancy" (Vulimiri et al., 2013) as a
production multi-pod JAX training + serving framework.

See README.md for the tour, DESIGN.md for the paper->system mapping, and
EXPERIMENTS.md for the validation / dry-run / roofline / perf logs.
"""
__version__ = "1.0.0"
